"""The C-step contract (paper §3, §7) across all scheme families:

1. projection idempotency — compressing an already-feasible point
   ``Δ(Θ)`` reproduces it: ``Δ(Π(Δ(Θ))) == Δ(Θ)``;
2. distortion monotonicity — a warm-started C step never increases
   ‖x − Δ(Θ)‖² at fixed x, across a drifting sequence of C steps;

both verified at the scheme level and end-to-end through LCAlgorithm on
BOTH the grouped and the per-task dispatch paths.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsIs, AsVector, CompressionTask, LCAlgorithm,
    exponential_mu_schedule)
from repro.core.schemes import (
    AdaptiveQuantization, AdditiveCombination, Binarize,
    ConstraintL0Pruning, ConstraintL1Pruning, LowRank, PenaltyL0Pruning,
    Ternarize)

KEY = jax.random.PRNGKey(0)
SEEDS = [0, 1, 7]

# (name, factory, needs_matrix) — fresh scheme per test, since some keep
# no state but we never want cross-test aliasing.
PROJECTION_SCHEMES = [
    ("prune-l0", lambda: ConstraintL0Pruning(kappa=50), False),
    ("prune-l1", lambda: ConstraintL1Pruning(kappa=12.0), False),
    ("prune-penalty-l0", lambda: PenaltyL0Pruning(alpha=1e-2), False),
    ("quant-kmeans", lambda: AdaptiveQuantization(k=4, iters=20), False),
    ("quant-binarize", lambda: Binarize(scaled=True), False),
    ("quant-ternarize", lambda: Ternarize(), False),
    ("lowrank", lambda: LowRank(target_rank=4, randomized=False), True),
    ("additive", lambda: AdditiveCombination(
        [ConstraintL0Pruning(kappa=40),
         AdaptiveQuantization(k=2, iters=15)], iters=3), False),
]
# PenaltyL1 (soft threshold) and RankSelection are excluded from
# idempotency: they shrink/trade distortion against the penalty term, so
# re-compressing a feasible point moves it again by design.


def _w(seed, matrix):
    k = jax.random.PRNGKey(seed)
    return jax.random.normal(k, (24, 16) if matrix else (384,))


@pytest.mark.parametrize("name,factory,matrix", PROJECTION_SCHEMES,
                         ids=[s[0] for s in PROJECTION_SCHEMES])
@pytest.mark.parametrize("seed", SEEDS)
def test_projection_idempotent(name, factory, matrix, seed):
    s = factory()
    w = _w(seed, matrix)
    # one real C step first: penalty-form init() deliberately starts
    # unpruned, so Π is only reached after the first compress
    th = s.compress(w, s.init(w), mu=1.0)
    dec = s.decompress(th)
    th2 = s.compress(dec, th, mu=1.0)
    np.testing.assert_allclose(np.asarray(s.decompress(th2)),
                               np.asarray(dec), atol=1e-5,
                               err_msg=f"{name} not idempotent")


# Projection-form schemes minimize plain distortion, so a warm-started C
# step can never increase it. Penalty forms (PenaltyL0/L1, RankSelection)
# minimize distortion PLUS a μ-weighted model-size term instead — plain
# distortion may rise when the penalty buys it, so they get the
# penalized-objective test below rather than this one.
MONOTONE_SCHEMES = [s for s in PROJECTION_SCHEMES
                    if s[0] != "prune-penalty-l0"]


@pytest.mark.parametrize("name,factory,matrix", MONOTONE_SCHEMES,
                         ids=[s[0] for s in MONOTONE_SCHEMES])
@pytest.mark.parametrize("seed", SEEDS)
def test_distortion_never_increases_across_c_steps(name, factory, matrix,
                                                   seed):
    """At each step k: ‖x_k − Δ(Θ_k)‖² ≤ ‖x_k − Δ(Θ_{k−1})‖² — the C
    step, warm-started at Θ_{k−1}, can only improve its own objective."""
    s = factory()
    x = _w(seed, matrix)
    th = s.init(x)
    mu = 1e-2
    for k in range(4):
        # drift the target, as the L step does between C steps
        x = x + 0.02 * jnp.sin(3.0 * x + k)
        d_warm = float(s.distortion(x, th))
        th = s.compress(x, th, mu=mu)
        d_new = float(s.distortion(x, th))
        assert d_new <= d_warm * (1 + 1e-5) + 1e-6, \
            f"{name} step {k}: {d_warm} -> {d_new}"
        mu *= 1.5


@pytest.mark.parametrize("seed", SEEDS)
def test_penalty_l0_minimizes_penalized_objective(seed):
    """Hard thresholding exactly minimizes ‖x−θ‖² + (2α/μ)‖θ‖₀, so the
    new Θ beats the warm start on THAT objective (monotonicity for
    penalty-form schemes)."""
    s = PenaltyL0Pruning(alpha=1e-2)
    x = _w(seed, False)
    mu = 0.5

    def obj(th):
        t = np.asarray(th["theta"])
        return float(((np.asarray(x) - t) ** 2).sum()
                     + (2 * s.alpha / mu) * (t != 0).sum())

    th = s.compress(x, s.init(x), mu=mu)
    for k in range(3):
        x = x + 0.05 * jnp.sin(3.0 * x + k)
        warm = obj(th)
        th = s.compress(x, th, mu=mu)
        assert obj(th) <= warm * (1 + 1e-6) + 1e-6


# ----------------------------------------------------------------------
# end-to-end through LCAlgorithm, grouped AND per-task
# ----------------------------------------------------------------------
FAMILIES = {
    "prune": lambda: ConstraintL0Pruning(kappa=32),
    "quantize": lambda: AdaptiveQuantization(k=4, iters=10),
    "lowrank": lambda: LowRank(target_rank=2, randomized=False),
    "additive": lambda: AdditiveCombination(
        [ConstraintL0Pruning(kappa=32),
         AdaptiveQuantization(k=2, iters=10)], iters=2),
}


def _family_lc(family, group_tasks):
    matrix = family == "lowrank"
    view = AsIs() if matrix else AsVector()
    tasks = [CompressionTask(f"t{i}", f"^p{i}$", view, FAMILIES[family]())
             for i in range(3)]
    return LCAlgorithm(tasks, exponential_mu_schedule(1e-2, 1.5, 4),
                       group_tasks=group_tasks), matrix


@pytest.mark.parametrize("family", list(FAMILIES))
@pytest.mark.parametrize("group_tasks", [True, False],
                         ids=["grouped", "pertask"])
def test_lc_shifted_distortion_monotone(family, group_tasks):
    lc, matrix = _family_lc(family, group_tasks)
    params = {f"p{i}": _w(i, matrix) for i in range(3)}
    st = lc.init(params)
    for k in range(3):
        params = jax.tree_util.tree_map(
            lambda x: x + 0.02 * jnp.sin(5 * x + k), params)
        pre = lc.shifted_distortion(params, st)
        st = lc.c_step(params, st)
        post = lc.shifted_distortion(params, st)
        for n in pre:
            assert float(post[n]) <= float(pre[n]) * (1 + 1e-5) + 1e-6, \
                (family, group_tasks, n, k, float(pre[n]), float(post[n]))
        st = lc.multiplier_step(params, st)


@pytest.mark.parametrize("family", list(FAMILIES))
@pytest.mark.parametrize("group_tasks", [True, False],
                         ids=["grouped", "pertask"])
def test_lc_feasible_state_fixed_point(family, group_tasks):
    """Running a C step on params already equal to Δ(Θ) keeps Θ's
    decompression (idempotency through the full task plumbing)."""
    lc, matrix = _family_lc(family, group_tasks)
    params = {f"p{i}": _w(i, matrix) for i in range(3)}
    st = lc.init(params)
    # overwrite params with the feasible point, zero multipliers
    feas = {n: st["tasks"][n]["a"] for n in st["tasks"]}
    params = dict(params)
    for t in lc.tasks:
        for p in t.paths:
            params[p] = feas[t.name][p].astype(params[p].dtype)
    st2 = lc.c_step(params, st)
    for t in lc.tasks:
        for p in t.paths:
            np.testing.assert_allclose(
                np.asarray(st2["tasks"][t.name]["a"][p]),
                np.asarray(st["tasks"][t.name]["a"][p]), atol=1e-5)
