"""Substrate tests: sharding resolver, checkpoint manager, fault
tolerance, data determinism, gradient compression, HLO analyzer."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro.analysis.hlo_stats import analyze_hlo
from repro.checkpoint import CheckpointManager
from repro.data import TokenStream, teacher_classification
from repro.distributed.compression_comm import (
    compress_tree, ef_compress, init_ef)
from repro.distributed.sharding import resolve_spec
from repro.runtime.fault_tolerance import (
    FaultInjector, RetryPolicy, StragglerMonitor)

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# sharding resolver
# ----------------------------------------------------------------------
class _FakeMesh:
    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        import numpy as _np
        self.devices = _np.empty(tuple(sizes.values()))


def test_resolve_basic_tp():
    mesh = _FakeMesh({"data": 16, "model": 16})
    spec = resolve_spec(("embed", "heads_flat"), (4096, 4096), mesh)
    assert spec == P("data", "model")


def test_resolve_divisibility_fallback():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # vocab 151655 is odd → replicate; embed still shards
    spec = resolve_spec(("vocab", "embed"), (151655, 896), mesh)
    assert spec == P(None, "data")


def test_resolve_priority_kv_heads_over_seq():
    mesh = _FakeMesh({"data": 16, "model": 16})
    # kv_heads=16 divides → takes "model"; kv_seq falls to data
    spec = resolve_spec(("batch", "kv_seq", "kv_heads", None),
                        (1, 32768, 16, 128), mesh)
    assert spec == P(None, "data", "model", None)
    # kv_heads=8 does not divide 16 → seq takes model
    spec2 = resolve_spec(("batch", "kv_seq", "kv_heads", None),
                         (128, 32768, 8, 128), mesh)
    assert spec2[2] is None
    assert spec2[1] == "model"


def test_resolve_multipod_batch():
    mesh = _FakeMesh({"pod": 2, "data": 16, "model": 16})
    spec = resolve_spec(("batch", "seq"), (256, 4096), mesh)
    assert spec == P(("pod", "data"), None)
    # batch=1 → replicated
    spec2 = resolve_spec(("batch", "seq"), (1, 4096), mesh)
    assert spec2 == P(None, None)


# ----------------------------------------------------------------------
# checkpoint
# ----------------------------------------------------------------------
def _state(key):
    return {"params": {"w": jax.random.normal(key, (8, 4)),
                       "b": jnp.zeros((4,))},
            "step": jnp.int32(7)}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state(KEY)
    mgr.save(st, 10)
    restored, step = mgr.restore(st)
    assert step == 10
    np.testing.assert_allclose(np.asarray(restored["params"]["w"]),
                               np.asarray(st["params"]["w"]))


def test_checkpoint_incomplete_ignored(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state(KEY)
    mgr.save(st, 10)
    # fake a crashed write
    d = os.path.join(str(tmp_path), "step_00000020")
    os.makedirs(d)
    assert mgr.latest_step() == 10


def test_checkpoint_keep_last(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep_last=2, async_save=False)
    st = _state(KEY)
    for s in (1, 2, 3, 4):
        mgr.save(st, s)
    assert mgr.steps() == [3, 4]


def test_checkpoint_async(tmp_path):
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    st = _state(KEY)
    mgr.save(st, 5)
    mgr.wait()
    assert mgr.latest_step() == 5


def test_checkpoint_elastic_resharding(tmp_path):
    """Restore with explicit shardings (elastic reload API)."""
    from jax.sharding import NamedSharding
    mgr = CheckpointManager(str(tmp_path), async_save=False)
    st = _state(KEY)
    mgr.save(st, 1)
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    sh = jax.tree_util.tree_map(
        lambda _: NamedSharding(mesh, P()), st)
    restored, _ = mgr.restore(st, shardings=sh)
    assert restored["params"]["w"].sharding == NamedSharding(mesh, P())


# ----------------------------------------------------------------------
# fault tolerance
# ----------------------------------------------------------------------
def test_retry_policy_recovers():
    inj = FaultInjector({3: 2})
    calls = []

    def step():
        calls.append(1)
        inj.maybe_fail(3)
        return "ok"

    rp = RetryPolicy(max_retries=3, backoff_s=0.001)
    assert rp.run(step) == "ok"
    assert len(calls) == 3  # 2 failures + 1 success


def test_retry_policy_exhausts():
    inj = FaultInjector({0: 99})
    rp = RetryPolicy(max_retries=2, backoff_s=0.001)
    with pytest.raises(RuntimeError):
        rp.run(lambda: inj.maybe_fail(0))


def test_straggler_monitor():
    m = StragglerMonitor(factor=3.0)
    for _ in range(10):
        m.observe(0.1)
    assert m.observe(1.0) is True
    assert m.stragglers == 1
    assert m.observe(0.1) is False


def test_straggler_monitor_honors_window():
    # regression: maxlen was hard-coded to 32, silently ignoring window
    m = StragglerMonitor(window=128)
    for _ in range(100):
        m.observe(0.1)
    assert m.times.maxlen == 128
    assert len(m.times) == 100
    m_small = StragglerMonitor(window=8)
    for _ in range(100):
        m_small.observe(0.1)
    assert len(m_small.times) == 8


def test_checkpoint_background_save_error_surfaces(tmp_path):
    """A failed async save must raise on the next wait()/save(), not die
    silently on the daemon thread."""
    mgr = CheckpointManager(str(tmp_path), async_save=True)
    st = _state(KEY)
    mgr.save(st, 1)
    mgr.wait()
    # squat the writer's scratch path with a regular file: the
    # background rmtree/makedirs fails (works even when running as root,
    # unlike permission bits)
    squatter = os.path.join(str(tmp_path), "step_00000002.tmp")
    with open(squatter, "w") as f:
        f.write("not a directory")
    mgr.save(st, 2)
    with pytest.raises(RuntimeError, match="background checkpoint"):
        mgr.wait()
    # the error is consumed: the manager keeps working afterwards
    os.remove(squatter)
    mgr.save(st, 3)
    mgr.wait()
    assert 3 in mgr.steps()


def test_trainer_recovers_from_injected_faults(tmp_path):
    """Full trainer loop with injected transient failures — must finish
    and the loss history must be intact."""
    from repro.configs import get_config, reduced_config
    from repro.core import (CompressionTask, AsVector, LCAlgorithm,
                            exponential_mu_schedule)
    from repro.core.schemes import AdaptiveQuantization
    from repro.data import TokenStream
    from repro.runtime import LCTrainer, TrainerConfig

    cfg = reduced_config(get_config("phi3-mini-3.8b")).with_(
        pattern_reps=1)
    data = TokenStream(cfg.vocab_size, 2, 16)
    lc = LCAlgorithm(
        [CompressionTask("q", r"stages/.*/w_gate$", AsVector(),
                         AdaptiveQuantization(k=2, iters=5))],
        exponential_mu_schedule(1e-4, 1.2, 2))
    trainer = LCTrainer(
        cfg, lc, data,
        tcfg=TrainerConfig(steps_per_l=3, ckpt_every=2,
                           ckpt_dir=str(tmp_path)),
        fault_injector=FaultInjector({1: 1, 4: 2}))
    state, lc_state = trainer.run(KEY)
    assert len(trainer.history) == 2
    assert trainer.faults.injected == 3
    assert np.isfinite(trainer.history[-1]["loss"])


# ----------------------------------------------------------------------
# data determinism
# ----------------------------------------------------------------------
def test_tokenstream_seekable_deterministic():
    ds = TokenStream(vocab_size=512, batch=4, seq_len=32, seed=3)
    b1 = ds.batch_at(17)
    ds2 = TokenStream(vocab_size=512, batch=4, seq_len=32, seed=3)
    b2 = ds2.batch_at(17)
    np.testing.assert_array_equal(np.asarray(b1["inputs"]),
                                  np.asarray(b2["inputs"]))
    b3 = ds.batch_at(18)
    assert not np.array_equal(np.asarray(b1["inputs"]),
                              np.asarray(b3["inputs"]))
    # labels are next-token shifted inputs
    full1 = np.asarray(b1["inputs"])[:, 1:]
    lab1 = np.asarray(b1["labels"])[:, :-1]
    np.testing.assert_array_equal(full1, lab1)


def test_teacher_classification_learnable():
    x, y = teacher_classification(512, d=32, classes=4, seed=1)
    assert x.shape == (512, 32) and y.shape == (512,)
    assert len(np.unique(np.asarray(y))) == 4


# ----------------------------------------------------------------------
# gradient compression
# ----------------------------------------------------------------------
def test_ef_compress_error_feedback_contracts():
    """With EF, the accumulated compression error stays bounded (doesn't
    grow with steps) and the running decompressed mean approaches the
    true gradient direction (Karimireddy et al. 2019 property)."""
    g = jax.random.normal(KEY, (256,))
    e = jnp.zeros((256,))
    acc = jnp.zeros((256,))
    norms = []
    for i in range(100):
        s, sc, e = ef_compress(g, e)
        acc = acc + s.astype(jnp.float32) * sc
        if i in (49, 99):
            norms.append(float(jnp.linalg.norm(e)))
    approx = acc / 100
    cos = float(jnp.dot(approx, g)
                / (jnp.linalg.norm(approx) * jnp.linalg.norm(g)))
    assert cos > 0.98
    # bounded, not growing: steady state by step 50
    assert norms[1] < norms[0] * 1.5


def test_compress_tree_shapes():
    grads = {"a": jax.random.normal(KEY, (8, 4)), "b": jnp.ones((3,))}
    ef = init_ef(grads)
    signs, scales, new_ef = compress_tree(grads, ef)
    assert signs["a"].dtype == jnp.int8
    assert new_ef["a"].shape == (8, 4)


# ----------------------------------------------------------------------
# HLO analyzer calibration
# ----------------------------------------------------------------------
def test_hlo_flops_plain_matmul():
    m, k, n = 128, 64, 32
    c = jax.jit(lambda a, b: a @ b).lower(
        jnp.zeros((m, k)), jnp.zeros((k, n))).compile()
    st = analyze_hlo(c.as_text())
    assert st.flops == 2 * m * n * k


def test_hlo_flops_scan_multiplied():
    def f(x, ws):
        return jax.lax.scan(lambda c, w: (c @ w, None), x, ws)[0]
    c = jax.jit(f).lower(jnp.zeros((32, 16)),
                         jnp.zeros((7, 16, 16))).compile()
    st = analyze_hlo(c.as_text())
    assert st.flops == 7 * 2 * 32 * 16 * 16


def test_hlo_remat_grad_four_passes():
    def loss(ws, x):
        out, _ = jax.lax.scan(
            jax.checkpoint(lambda c, w: (jnp.tanh(c @ w), None)), x, ws)
        return jnp.sum(out ** 2)
    c = jax.jit(jax.grad(loss)).lower(
        jnp.zeros((4, 64, 64)), jnp.zeros((8, 64))).compile()
    st = analyze_hlo(c.as_text())
    fwd = 4 * 2 * 8 * 64 * 64
    assert abs(st.flops - 4 * fwd) / (4 * fwd) < 0.05
