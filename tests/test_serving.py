"""Serving runtime: continuous batching, compressed-form execution,
cache padding, and the LC→serving checkpoint bridge."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import LayerSpec, MLACfg, MambaCfg, ModelConfig
from repro.core import AsIs, AsVector, CompressionTask, LCAlgorithm
from repro.core.schemes import (
    AdaptiveQuantization, ConstraintL0Pruning, LowRank)
from repro.kernels.lowrank import serve as lowrank_serve
from repro.kernels.prune import serve as prune_serve
from repro.kernels.quant_matmul import ops as qops
from repro.models.transformer import (
    decode_step, forward_hidden, init_cache, init_params)
from repro.models.layers import unembed
from repro.runtime import compressed as cforms
from repro.runtime.server import (
    Request, Server, ServingEngine, densified_for_serving,
    load_compressed_for_serving, pad_caches_to, sample_tokens)

KP = jax.random.PRNGKey(0)


def tiny_cfg(*specs, **kw):
    base = dict(name="t", d_model=32, n_heads=2, n_kv_heads=2,
                head_dim=8, d_ff=64, vocab_size=128,
                pattern=tuple(specs), pattern_reps=1,
                attn_chunk_q=4, attn_chunk_kv=4, dtype="float32")
    base.update(kw)
    return ModelConfig(**base)


def gold_decode(cfg, params, prompt, n_new, max_len):
    """Independent reference: scalar-position decode loop from scratch."""
    step = jax.jit(lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
    cache = init_cache(cfg, 1, max_len)
    for i, t in enumerate(prompt):
        logits, cache = step(params, cache,
                             jnp.asarray([[t]], jnp.int32), jnp.int32(i))
    out = [int(jnp.argmax(logits[0, 0]))]
    pos = len(prompt)
    while len(out) < n_new:
        logits, cache = step(params, cache,
                             jnp.asarray([[out[-1]]], jnp.int32),
                             jnp.int32(pos))
        out.append(int(jnp.argmax(logits[0, 0])))
        pos += 1
    return np.asarray(out, np.int32)


# ----------------------------------------------------------------------
# Serving kernels
# ----------------------------------------------------------------------
@pytest.mark.parametrize("k", [16, 17])           # even + odd rows
def test_pack4_roundtrip(k):
    idx = jax.random.randint(KP, (k, 24), 0, 16).astype(jnp.uint8)
    packed = qops.pack4(idx)
    assert packed.shape == ((k + 1) // 2, 24)
    assert np.array_equal(np.asarray(qops.unpack4(packed))[:k], idx)


@pytest.mark.parametrize("use_pallas", [False, True])
def test_matmul_packed_vs_dequant(use_pallas):
    kx, kw, kc = jax.random.split(KP, 3)
    m, k, n = 5, 32, 24
    x = jax.random.normal(kx, (m, k), jnp.float32)
    cb = jnp.sort(jax.random.normal(kc, (16,)))
    idx = qops.pack_quantized(jax.random.normal(kw, (k, n)), cb)
    y = qops.matmul_packed(x, qops.pack4(idx), cb,
                           use_pallas=use_pallas)
    gold = x @ cb[idx.astype(jnp.int32)]
    np.testing.assert_allclose(np.asarray(y), np.asarray(gold),
                               rtol=1e-5, atol=1e-5)


def test_lowrank_matmul_never_materializes():
    kx, ku, kv = jax.random.split(KP, 3)
    x = jax.random.normal(kx, (3, 16))
    u = jax.random.normal(ku, (16, 4))
    vt = jax.random.normal(kv, (4, 12))
    np.testing.assert_allclose(
        np.asarray(lowrank_serve.lowrank_matmul(x, u, vt)),
        np.asarray(x @ (u @ vt)), rtol=1e-5, atol=1e-5)


def test_sparse_matmul_matches_dense():
    kx, kw = jax.random.split(KP)
    x = jax.random.normal(kx, (3, 16))
    w = np.array(jax.random.normal(kw, (16, 12)))
    w[np.abs(w) < 0.8] = 0.0
    rows, cols = np.nonzero(w)
    y = prune_serve.sparse_matmul(
        x, jnp.asarray(w[rows, cols]), jnp.asarray(rows, jnp.int32),
        jnp.asarray(cols, jnp.int32), 12)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x) @ w,
                               rtol=1e-5, atol=1e-5)
    dense = prune_serve.densify(
        jnp.asarray(w[rows, cols]), jnp.asarray(rows, jnp.int32),
        jnp.asarray(cols, jnp.int32), w.shape)
    assert np.array_equal(np.asarray(dense), w)


# ----------------------------------------------------------------------
# pad_caches_to
# ----------------------------------------------------------------------
def _prefill_pad_decode(cfg, s, max_len, n_new):
    """Prefill s tokens, pad caches, decode n_new — must match the
    scalar decode-from-scratch gold."""
    params = init_params(KP, cfg)
    prompt = np.asarray(
        jax.random.randint(KP, (s,), 1, cfg.vocab_size), np.int32)
    hidden, _, caches = forward_hidden(params, jnp.asarray(prompt)[None],
                                       cfg, return_caches=True)
    logits = unembed(params["embed"], hidden[:, -1:], cfg)
    caches = pad_caches_to(caches, cfg, s, max_len)
    out = [int(jnp.argmax(logits[0, 0]))]
    for i in range(n_new - 1):
        logits, caches = decode_step(
            params, caches, jnp.asarray([[out[-1]]], jnp.int32),
            jnp.int32(s + i), cfg)
        out.append(int(jnp.argmax(logits[0, 0])))
    gold = gold_decode(cfg, params, prompt, n_new, max_len)
    assert np.array_equal(np.asarray(out, np.int32), gold)


def test_pad_caches_windowed_ring_roll():
    # cur_len (8) > window (4): the ring must be rolled so position p
    # stays at slot p % window across the prefill→decode handoff
    cfg = tiny_cfg(LayerSpec("attn", "dense", window=4))
    _prefill_pad_decode(cfg, s=8, max_len=16, n_new=5)


def test_pad_caches_mla_seq_padding():
    cfg = tiny_cfg(LayerSpec("mla", "dense"),
                   mla=MLACfg(q_lora_rank=16, kv_lora_rank=8,
                              qk_nope_dim=8, qk_rope_dim=8,
                              v_head_dim=8))
    _prefill_pad_decode(cfg, s=8, max_len=16, n_new=5)


def test_pad_caches_recurrent_passthrough():
    cfg = tiny_cfg(LayerSpec("mamba", "dense"),
                   mamba=MambaCfg(d_state=4, d_conv=4, expand=2,
                                  dt_rank=8))
    params = init_params(KP, cfg)
    x = jax.random.randint(KP, (1, 8), 1, cfg.vocab_size)
    _, _, caches = forward_hidden(params, x, cfg, return_caches=True)
    padded = pad_caches_to(caches, cfg, 8, 32)
    jax.tree_util.tree_map(
        lambda a, b: np.testing.assert_array_equal(np.asarray(a),
                                                   np.asarray(b)),
        caches, padded)


# ----------------------------------------------------------------------
# Continuous batching engine
# ----------------------------------------------------------------------
def test_sample_tokens_greedy_matches_argmax():
    logits = jax.random.normal(KP, (4, 32))
    assert np.array_equal(
        np.asarray(sample_tokens(logits, KP, 0.0)),
        np.asarray(jnp.argmax(logits, -1)))


def test_engine_matches_scalar_decode_mixed_lengths():
    cfg = tiny_cfg(LayerSpec("attn", "dense"))
    params = init_params(KP, cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(1, 127, size=s).astype(np.int32)
               for s in (3, 7, 12, 5)]
    max_news = [4, 6, 3, 5]
    gold = [gold_decode(cfg, params, p, m, 32)
            for p, m in zip(prompts, max_news)]

    eng = ServingEngine(cfg, params, slots=2, max_len=32,
                        prefill_chunk=4)
    reqs = [Request(id=i, prompt=p, max_new=m, arrival=0.0)
            for i, (p, m) in enumerate(zip(prompts, max_news))]
    out = eng.run(reqs)
    fin = {f.id: f.tokens for f in out["finished"]}
    assert len(fin) == len(reqs)
    for i, g in enumerate(gold):
        assert np.array_equal(fin[i], g), i
    # zero recompiles across the mixed-length trace
    assert all(n == 1 for n in eng.trace_counts.values()), \
        eng.trace_counts


def test_engine_rejects_oversized_and_empty():
    cfg = tiny_cfg(LayerSpec("attn", "dense"))
    params = init_params(KP, cfg)
    eng = ServingEngine(cfg, params, slots=2, max_len=16,
                        prefill_chunk=4)
    reqs = [
        Request(id=0, prompt=np.arange(1, 4, dtype=np.int32), max_new=2),
        Request(id=1, prompt=np.arange(1, 30, dtype=np.int32),
                max_new=10),                      # 29 + 10 > 16
        Request(id=2, prompt=np.asarray([], np.int32), max_new=2),
    ]
    out = eng.run(reqs)
    assert sorted(r.id for r in out["rejected"]) == [1, 2]
    assert [f.id for f in out["finished"]] == [0]


def test_server_generate_in_jit_sampling():
    cfg = tiny_cfg(LayerSpec("attn", "dense"))
    params = init_params(KP, cfg)
    srv = Server(cfg, params, max_len=32)
    prompt = np.asarray(
        jax.random.randint(KP, (8,), 1, cfg.vocab_size), np.int32)
    res = srv.generate(jnp.asarray(prompt)[None], 6)
    gold = gold_decode(cfg, params, prompt, 6, 32)
    assert np.array_equal(res.tokens[0], gold)
    # temperature sampling is deterministic under a fixed key
    a = srv.generate(jnp.asarray(prompt)[None], 6, temperature=0.8,
                     key=jax.random.PRNGKey(7))
    b = srv.generate(jnp.asarray(prompt)[None], 6, temperature=0.8,
                     key=jax.random.PRNGKey(7))
    assert np.array_equal(a.tokens, b.tokens)


# ----------------------------------------------------------------------
# LC checkpoint bridge + compressed-form parity
# ----------------------------------------------------------------------
def _bridge(cfg, params, task):
    algo = LCAlgorithm([task], [1e-4])
    state = algo.init(params)
    serving, report = load_compressed_for_serving(params, state,
                                                  algo.tasks)
    reference = densified_for_serving(params, state, algo.tasks)
    return serving, reference, report


def test_bridge_selects_all_three_forms():
    cfg = tiny_cfg(LayerSpec("attn", "dense"))
    params = init_params(KP, cfg)
    _, _, rq = _bridge(cfg, params, CompressionTask(
        "q", r"ffn/w_gate", AsVector(), AdaptiveQuantization(k=16)))
    assert all(v == "quant4" for f in rq.values() for v in f.values())
    _, _, rl = _bridge(cfg, params, CompressionTask(
        "lr", r"ffn/w_up", AsIs(), LowRank(4)))
    assert all(v.startswith("lowrank") for f in rl.values()
               for v in f.values())
    _, _, rp = _bridge(cfg, params, CompressionTask(
        "pr", r"ffn/w_down", AsVector(), ConstraintL0Pruning(kappa=400)))
    assert all(v.startswith("sparse") for f in rp.values()
               for v in f.values())


def test_quantized_parity_tokens_and_logits():
    cfg = tiny_cfg(LayerSpec("attn", "dense"))
    params = init_params(KP, cfg)
    serving, reference, _ = _bridge(cfg, params, CompressionTask(
        "q", r"ffn/w_", AsVector(), AdaptiveQuantization(k=16)))

    # logits parity on one decode step from a fresh cache
    tok = jnp.asarray([[5]], jnp.int32)
    lc_, _ = decode_step(serving, init_cache(cfg, 1, 16), tok,
                         jnp.int32(0), cfg)
    ld_, _ = decode_step(reference, init_cache(cfg, 1, 16), tok,
                         jnp.int32(0), cfg)
    np.testing.assert_allclose(np.asarray(lc_), np.asarray(ld_),
                               rtol=1e-4, atol=1e-4)

    # greedy-token parity over a full generation
    prompt = np.asarray(
        jax.random.randint(KP, (6,), 1, cfg.vocab_size), np.int32)
    assert np.array_equal(gold_decode(cfg, serving, prompt, 8, 32),
                          gold_decode(cfg, reference, prompt, 8, 32))


@pytest.mark.parametrize("task", [
    CompressionTask("lr", r"ffn/w_", AsIs(), LowRank(6)),
    CompressionTask("pr", r"ffn/w_", AsVector(),
                    ConstraintL0Pruning(kappa=1500)),
], ids=["lowrank", "sparse"])
def test_compressed_engine_parity(task):
    cfg = tiny_cfg(LayerSpec("attn", "dense"))
    params = init_params(KP, cfg)
    serving, reference, _ = _bridge(cfg, params, task)
    rng = np.random.default_rng(3)
    reqs = [Request(id=i,
                    prompt=rng.integers(1, 127, size=s).astype(np.int32),
                    max_new=4, arrival=0.0)
            for i, s in enumerate((5, 9))]
    fc = {f.id: f.tokens for f in ServingEngine(
        cfg, serving, slots=2, max_len=32,
        prefill_chunk=4).run(list(reqs))["finished"]}
    fd = {f.id: f.tokens for f in ServingEngine(
        cfg, reference, slots=2, max_len=32,
        prefill_chunk=4).run(list(reqs))["finished"]}
    for i in fc:
        assert np.array_equal(fc[i], fd[i]), i


def test_hbm_accounting_orders_forms():
    cfg = tiny_cfg(LayerSpec("attn", "dense"))
    params = init_params(KP, cfg)
    qs, _, _ = _bridge(cfg, params, CompressionTask(
        "q", r"ffn/w_", AsVector(), AdaptiveQuantization(k=16)))
    dense_bytes = cforms.tree_weight_bytes(params)
    quant_bytes = cforms.tree_weight_bytes(qs)
    assert quant_bytes < dense_bytes
    # 4-bit packing: the ffn matrices shrink 4x vs bf16 modeling
    w = params["stages"]["s0"]["pos0"]["ffn"]["w_gate"]
    assert cforms.weight_form_bytes(
        qs["stages"]["s0"]["pos0"]["ffn"]["w_gate"]) < w.size
