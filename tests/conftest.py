import os

# tests run on the single real CPU device — never force fake devices here
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)


# ----------------------------------------------------------------------
# hypothesis shim: the property tests degrade to a deterministic sweep of
# boundary + pseudorandom examples when hypothesis isn't installed (it is
# listed in requirements-dev.txt; CI installs the real thing).
# ----------------------------------------------------------------------
def _install_hypothesis_shim():
    import random
    import sys
    import types
    import zlib

    _SHIM_CAP = 8  # examples per property when running on the shim

    class _Strategy:
        def __init__(self, boundary, draw):
            self._boundary = list(boundary)
            self._draw = draw

        def examples(self, n, rng):
            vals = list(self._boundary)
            while len(vals) < n:
                vals.append(self._draw(rng))
            return vals[:n]

    def integers(min_value=0, max_value=1 << 30):
        return _Strategy([min_value, max_value],
                         lambda rng: rng.randint(min_value, max_value))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy([min_value, max_value],
                         lambda rng: rng.uniform(min_value, max_value))

    def sampled_from(elements):
        elements = list(elements)
        return _Strategy(elements[:1],
                         lambda rng: rng.choice(elements))

    def given(*strategies):
        def deco(fn):
            def wrapper():
                n = min(getattr(wrapper, "_shim_max_examples", _SHIM_CAP),
                        _SHIM_CAP)
                rng = random.Random(zlib.crc32(fn.__name__.encode()))
                cols = [s.examples(n, rng) for s in strategies]
                for vals in zip(*cols):
                    fn(*vals)
            # deliberately NOT functools.wraps: pytest must see a
            # zero-arg test, not the original's strategy parameters
            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            wrapper._shim_max_examples = _SHIM_CAP
            return wrapper
        return deco

    def settings(max_examples=_SHIM_CAP, deadline=None, **_kw):
        def deco(fn):
            fn._shim_max_examples = max_examples
            return fn
        return deco

    hyp = types.ModuleType("hypothesis")
    st = types.ModuleType("hypothesis.strategies")
    st.integers, st.floats, st.sampled_from = integers, floats, sampled_from
    hyp.given, hyp.settings, hyp.strategies = given, settings, st
    hyp.__version__ = "0.0-shim"
    sys.modules["hypothesis"] = hyp
    sys.modules["hypothesis.strategies"] = st


try:
    import hypothesis  # noqa: F401
except ImportError:
    _install_hypothesis_shim()
