import os

# tests run on the single real CPU device — never force fake devices here
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_enable_x64", False)
