"""Multi-device integration tests.

The pytest process owns one CPU device, so these spawn subprocesses with
``--xla_force_host_platform_device_count`` to exercise real GSPMD
partitioning: sharded train step (data+tensor parallel, MoE shard_map
dispatch), multi-pod mesh, and numerical equivalence between 1-device
and 8-device execution of the same step.
"""
import json
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script: str, devices: int = 8, timeout: int = 500) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


COMMON = """
import jax, jax.numpy as jnp, numpy as np, json
from repro.configs import get_config, reduced_config
from repro.distributed.sharding import use_mesh
from repro.launch.steps import make_train_step, init_train_state
from repro.launch import inputs as specs_mod
"""


def test_sharded_train_step_matches_single_device():
    """Same seed, same batch: loss on a (2,4) mesh must match 1-device
    execution — GSPMD partitioning is numerics-preserving (within fp32
    reduction noise)."""
    script = COMMON + """
import dataclasses
from jax.sharding import NamedSharding, PartitionSpec as P
arch = "jamba-v0.1-52b"   # covers mamba + attention + MoE shard_map
cfg = reduced_config(get_config(arch)).with_(dtype="float32")
# no-drop capacity: per-shard vs global capacity otherwise drops
# different tokens (expected EP semantics, but breaks exact equivalence)
cfg = cfg.with_(moe=dataclasses.replace(cfg.moe, capacity_factor=8.0))
key = jax.random.PRNGKey(0)
batch = {
  "inputs": jax.random.randint(jax.random.fold_in(key,1), (4, 16), 0, cfg.vocab_size),
  "labels": jax.random.randint(jax.random.fold_in(key,2), (4, 16), 0, cfg.vocab_size),
}
losses = {}
for shape, axes in [((1,1),("data","model")), ((2,4),("data","model"))]:
    mesh = jax.make_mesh(shape, axes)
    with use_mesh(mesh):
        state = init_train_state(key, cfg)
        step = jax.jit(make_train_step(cfg))
        with mesh:
            new_state, metrics = step(state, batch)
        losses[str(shape)] = float(metrics["loss"])
print(json.dumps(losses))
assert abs(losses["(1, 1)"] - losses["(2, 4)"]) < 5e-3, losses
"""
    out = _run(script)
    losses = json.loads(out.strip().splitlines()[-1])
    assert abs(losses["(1, 1)"] - losses["(2, 4)"]) < 5e-3


def test_multipod_mesh_step_runs():
    """(pod, data, model) = (2, 2, 2) mesh executes a full LC train step."""
    script = COMMON + """
cfg = reduced_config(get_config("mixtral-8x7b"))
key = jax.random.PRNGKey(0)
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
batch = {
  "inputs": jax.random.randint(jax.random.fold_in(key,1), (8, 16), 0, cfg.vocab_size),
  "labels": jax.random.randint(jax.random.fold_in(key,2), (8, 16), 0, cfg.vocab_size),
}
with use_mesh(mesh):
    state = init_train_state(key, cfg)
    step = jax.jit(make_train_step(cfg))
    with mesh:
        state, metrics = step(state, batch)
assert np.isfinite(float(metrics["loss"]))
print("ok", float(metrics["loss"]))
"""
    out = _run(script)
    assert "ok" in out


def test_dryrun_cell_subprocess():
    """The real dry-run path (512 fake devices) for the cheapest cell."""
    script = """
import sys
sys.argv = ["dryrun", "--arch", "xlstm-125m", "--shape", "decode_32k",
            "--out", "/tmp/test_dryrun_cells", "--force"]
from repro.launch import dryrun
try:
    dryrun.main()
except SystemExit as e:
    assert e.code == 0, "dry-run cell failed"
import json, glob
f = glob.glob("/tmp/test_dryrun_cells/*.json")[0]
d = json.load(open(f))
assert d["status"] == "ok", d
print("bottleneck:", d["bottleneck"])
"""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "bottleneck:" in out.stdout
