"""Async L/C overlap in LCTrainer + restore-correctness regressions.

* ``overlap="off"`` must be step-for-step identical to a hand-written
  serial LC loop built from the same jitted primitives (bit-identity on
  the full train/LC state).
* ``overlap="on"`` must keep the §7 monitors clean (no C-step
  distortion violations) and still drive the constraint violation down.
* Hard-failure restore must rewind the step counter, re-sync the LC
  penalty refs at the current μ, and put restored host arrays back on
  device (kill-and-resume consistency).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, reduced_config
from repro.core import (AsVector, CompressionTask, LCAlgorithm,
                        exponential_mu_schedule)
from repro.core.schemes import AdaptiveQuantization
from repro.data import TokenStream
from repro.runtime import LCTrainer, TrainerConfig
from repro.runtime.fault_tolerance import FaultInjector

KEY = jax.random.PRNGKey(0)

CFG = reduced_config(get_config("phi3-mini-3.8b")).with_(pattern_reps=1)


def _make_trainer(tmp_path=None, overlap="off", n_mu=2, steps_per_l=3,
                  fault_injector=None, swap_after=None, ckpt_every=2,
                  mu0=1e-4, mu_a=1.5, lr=3e-4):
    data = TokenStream(CFG.vocab_size, 2, 16)
    lc = LCAlgorithm(
        [CompressionTask("qg", r"stages/.*/w_gate$", AsVector(),
                         AdaptiveQuantization(k=2, iters=5)),
         CompressionTask("qu", r"stages/.*/w_up$", AsVector(),
                         AdaptiveQuantization(k=2, iters=5))],
        exponential_mu_schedule(mu0, mu_a, n_mu))
    tcfg = TrainerConfig(steps_per_l=steps_per_l, ckpt_every=ckpt_every,
                         ckpt_dir=str(tmp_path) if tmp_path else None,
                         overlap=overlap, swap_after=swap_after, lr=lr)
    return LCTrainer(CFG, lc, data, tcfg=tcfg,
                     fault_injector=fault_injector)


def _assert_trees_equal(a, b, what):
    la = jax.tree_util.tree_leaves(a)
    lb = jax.tree_util.tree_leaves(b)
    assert len(la) == len(lb), what
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=what)


# ----------------------------------------------------------------------
# overlap="off" ≡ the serial reference loop, bit for bit
# ----------------------------------------------------------------------
def test_overlap_off_bit_identical_to_manual_serial_loop():
    trainer = _make_trainer(overlap="off")
    state, lc_state = trainer.run(KEY)

    # the same loop, written out by hand from the trainer's own jitted
    # primitives (same train step, same deterministic data stream)
    ref = _make_trainer(overlap="off")
    st = ref.init_state(KEY)
    lc_st = ref._lc_state
    gs = 0
    for k, mu in enumerate(ref.lc.mu_schedule):
        lc_st = ref.lc.set_mu(lc_st, mu, k)
        st["lc"] = ref._refs_from_lc(st["params"], lc_st)
        for i in range(ref.tcfg.steps_per_l):
            st, _ = ref._train_step(st, ref.data.batch_at(gs + i))
        gs += ref.tcfg.steps_per_l
        lc_st = ref.lc.c_step(st["params"], lc_st)
        lc_st = ref.lc.multiplier_step(st["params"], lc_st)
        st["lc"] = ref._refs_from_lc(st["params"], lc_st)

    _assert_trees_equal(state["params"], st["params"], "params")
    _assert_trees_equal(state["opt"], st["opt"], "opt state")
    _assert_trees_equal(state["lc"], st["lc"], "penalty refs")
    _assert_trees_equal(lc_state, lc_st, "LC state")
    assert int(state["step"]) == gs


# ----------------------------------------------------------------------
# overlapped run: monitors stay clean, constraint violation decreases
# ----------------------------------------------------------------------
def test_overlapped_run_converges_with_clean_monitors():
    # aggressive μ growth + a real learning rate, so the penalty
    # actually pulls w toward Δ(Θ) within the short run
    trainer = _make_trainer(overlap="on", n_mu=4, steps_per_l=6,
                            mu0=0.5, mu_a=4.0, lr=0.05)
    state, lc_state = trainer.run(KEY)

    assert len(trainer.history) == 4
    assert [h["lc_step"] for h in trainer.history] == [0, 1, 2, 3]
    for h in trainer.history:
        # §7: the C step never increases its own shifted distortion
        assert h["c_step_violations"] == []
        assert np.isfinite(h["loss"])
        assert h["c_step_ms"] >= 0.0
    # §7 trend: ‖w − Δ(Θ)‖² decreases across LC steps as μ grows
    dist = [sum(h["distortion"].values()) for h in trainer.history]
    assert all(b < a for a, b in zip(dist, dist[1:])), dist
    assert int(state["step"]) == 24
    assert float(state["lc"]["mu"]) == pytest.approx(
        float(lc_state["mu"]))


def test_overlap_swap_after_forces_fixed_window():
    trainer = _make_trainer(overlap="on", n_mu=3, steps_per_l=3,
                            swap_after=2)
    trainer.run(KEY)
    # boundaries 0 and 1 swap inside L steps 1 and 2 after exactly 2
    # microbatches; the final boundary drains after the loop (None)
    swaps = [h["swap_after_microbatches"] for h in trainer.history]
    assert swaps[:-1] == [2, 2]
    assert swaps[-1] is None


def test_overlap_rejects_bad_mode():
    with pytest.raises(ValueError, match="overlap"):
        _make_trainer(overlap="sometimes")


# ----------------------------------------------------------------------
# hard-failure restore: rewind + re-sync + device placement
# ----------------------------------------------------------------------
def test_hard_failure_restore_rewinds_and_resyncs(tmp_path):
    # step 3 fails 5× — RetryPolicy (3 retries) exhausts after 4, the
    # trainer restores the step-2 checkpoint, replays step 3 (5th
    # failure is consumed by the retry), and finishes the run
    trainer = _make_trainer(tmp_path=tmp_path, n_mu=2, steps_per_l=4,
                            fault_injector=FaultInjector({3: 5}))
    state, lc_state = trainer.run(KEY)

    assert trainer.faults.injected == 5
    assert len(trainer.history) == 2
    # counters: rewound to ckpt step 2, replayed 3, ran through step 7
    assert int(state["step"]) == 8
    # restored leaves went back through device_put, not raw numpy
    leaves = jax.tree_util.tree_leaves(state["params"])
    assert all(isinstance(l, jax.Array) for l in leaves)
    # refs were re-synced from the algorithm's LC state: λ/a in the
    # train state match the final LC state exactly
    for t in trainer.lc.tasks:
        ts = lc_state["tasks"][t.name]
        for p in t.paths:
            np.testing.assert_array_equal(
                np.asarray(state["lc"]["lam"][p]), np.asarray(ts["lam"][p]))
            np.testing.assert_array_equal(
                np.asarray(state["lc"]["a"][p]), np.asarray(ts["a"][p]))
    assert np.isfinite(trainer.history[-1]["loss"])


def test_hard_failure_gives_up_after_max_restores(tmp_path):
    """A deterministic failure must not rewind-and-replay forever: after
    max_restores consecutive restores the error propagates."""
    trainer = _make_trainer(tmp_path=tmp_path, n_mu=1, steps_per_l=4,
                            fault_injector=FaultInjector({3: 10_000}))
    with pytest.raises(RuntimeError, match="injected fault"):
        trainer.run(KEY)
    assert trainer.faults.injected == 4 * (trainer.tcfg.max_restores + 1)


def test_kill_and_resume_restores_consistent_state(tmp_path):
    # session 1: train 1 LC step with checkpointing, then "die"
    t1 = _make_trainer(tmp_path=tmp_path, n_mu=1, steps_per_l=4)
    s1, lc1 = t1.run(KEY)
    assert t1.ckpt.latest_step() == 4  # blocking final save

    # session 2 (fresh process state): init, then restore mid-LC-run
    t2 = _make_trainer(tmp_path=tmp_path, n_mu=2, steps_per_l=4)
    s2 = t2.init_state(KEY)
    mu1 = t2.lc.mu_schedule[1]
    t2._lc_state = t2.lc.set_mu(t2._lc_state, mu1, 1)
    s2["lc"] = t2._refs_from_lc(s2["params"], t2._lc_state)
    restored, next_step = t2._restore_state(s2)

    # step counter rewound to the checkpoint, not the fresh state
    assert next_step == 4
    assert int(restored["step"]) == 4
    # params came back on device with the original shardings
    for new, old in zip(jax.tree_util.tree_leaves(restored["params"]),
                        jax.tree_util.tree_leaves(s2["params"])):
        assert isinstance(new, jax.Array)
        assert new.sharding == old.sharding
    # checkpointed weights, not re-initialized ones
    np.testing.assert_array_equal(
        np.asarray(restored["params"]["final_norm"]),
        np.asarray(s1["params"]["final_norm"]))
    # penalty refs re-synced at the *current* μ (μ_1, not the stale
    # checkpointed μ_0)
    assert float(restored["lc"]["mu"]) == pytest.approx(float(mu1))
    # and a restored state trains: one L step runs without error
    out, _, gs = t2._l_step(restored, 1, next_step)
    assert gs == next_step + 4
    assert int(out["step"]) == next_step + 4


# ----------------------------------------------------------------------
# CPU smoke: the CI job's assertion, kept as a test too
# ----------------------------------------------------------------------
def test_overlap_smoke_two_lc_steps_no_violations():
    trainer = _make_trainer(overlap="on", n_mu=2, steps_per_l=2)
    trainer.run(KEY)
    assert len(trainer.history) == 2
    assert all(h["c_step_violations"] == [] for h in trainer.history)
