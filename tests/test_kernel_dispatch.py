"""Kernel dispatch layer: batched items-grid kernels vs jnp solvers.

Contract under test (docs/architecture.md "The kernel dispatch layer"):

* the ``jnp`` backend is **bit-identical** to the legacy vmapped scheme
  programs (and therefore to the per-task path);
* the ``interpret``/``pallas`` backends run the batched Pallas kernels —
  top-κ masks must select the identical support (exact threshold), the
  k-means Lloyd loop must agree to documented float tolerance (the
  kernel's grid-sequential moment accumulation orders sums differently);
* both dispatch paths (grouped and per-task) go through the same named
  solvers;
* κ is a traced per-item operand, so mixed-κ tasks share one group —
  the grouping that used to be impossible with κ baked into the trace.

Everything runs in Pallas interpret mode on CPU; compiled-kernel
differentials are TPU-only and skipped cleanly elsewhere.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsStacked, AsVector, CompressionTask, LCAlgorithm, build_groups,
    describe_groups)
from repro.core.grouping import solve_task
from repro.core.schemes import AdaptiveQuantization, ConstraintL0Pruning
from repro.data import Prefetcher, TokenStream
from repro.kernels import dispatch
from repro.kernels.kmeans import ops as kops
from repro.kernels.kmeans import ref as kref
from repro.kernels.prune import ops as pops
from repro.kernels.prune import ref as pref

KEY = jax.random.PRNGKey(0)

# documented tolerance for kernel-vs-jnp k-means codebooks: the batched
# kernel accumulates moments tile-sequentially, the jnp solver as one
# masked reduce — identical assignments, float-order-different sums
KMEANS_CB_ATOL = 1e-3


# ----------------------------------------------------------------------
# batched kmeans kernel vs oracle (incl. ragged last tiles)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("i,p,k", [
    (1, 2048, 4), (3, 8192, 16), (5, 5000, 8),     # 5000: ragged tile
    (2, 1023, 4), (4, 1024, 32),
])
def test_batched_kmeans_assign_moments_vs_ref(i, p, k):
    kw, kc = jax.random.split(jax.random.fold_in(KEY, i * p * k))
    w = jax.random.normal(kw, (i, p))
    cb = jnp.sort(jax.random.normal(kc, (i, k)), axis=-1)
    a1, s1, c1 = kops.assign_moments_batched(w, cb, interpret=True)
    a2, s2, c2 = kref.kmeans_assign_moments_batched_ref(w, cb)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=3e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)


def test_batched_kmeans_matches_item_loop():
    """The batched kernel is the unbatched kernel per item — batch
    composition must not leak between items."""
    w = jax.random.normal(KEY, (4, 4096))
    cb = jnp.sort(jax.random.normal(jax.random.fold_in(KEY, 7), (4, 8)),
                  axis=-1)
    ab, sb, cb_ = kops.assign_moments_batched(w, cb, interpret=True)
    for i in range(4):
        ai, si, ci = kops.assign_moments(w[i], cb[i], use_pallas=True)
        np.testing.assert_array_equal(np.asarray(ab[i]), np.asarray(ai))
        np.testing.assert_allclose(np.asarray(sb[i]), np.asarray(si),
                                   rtol=1e-6)
        np.testing.assert_allclose(np.asarray(cb_[i]), np.asarray(ci),
                                   rtol=1e-6)


def test_batched_kmeans_lloyd_loop_vs_jnp_solver():
    w = jax.random.normal(KEY, (3, 8192))
    cb0 = jnp.sort(jax.random.normal(jax.random.fold_in(KEY, 3), (3, 8)),
                   axis=-1)
    cb_k, as_k = kops.kmeans_batched(w, cb0, iters=15, impl="interpret")
    cb_j, as_j = kops.kmeans_batched(w, cb0, iters=15, impl="jnp")
    np.testing.assert_allclose(np.asarray(cb_k), np.asarray(cb_j),
                               atol=KMEANS_CB_ATOL)
    # assignment disagreements only where the drifted codebooks are
    # genuinely ambiguous — distortion must match to the same tolerance
    d_k = jnp.sum((w - jnp.take_along_axis(cb_k, as_k, axis=-1)) ** 2)
    d_j = jnp.sum((w - jnp.take_along_axis(cb_j, as_j, axis=-1)) ** 2)
    np.testing.assert_allclose(float(d_k), float(d_j), rtol=1e-4)


def test_jnp_kmeans_solver_is_vmap_of_core_solver():
    """The dispatch layer's jnp backend IS the legacy solver — bitwise."""
    from repro.core.schemes.quantize import kmeans_1d
    w = jax.random.normal(KEY, (3, 2048))
    cb0 = jax.random.normal(jax.random.fold_in(KEY, 9), (3, 4))
    cb_b, as_b = kops.kmeans_batched(w, cb0, iters=5, impl="jnp")
    for i in range(3):
        cb_i, as_i = kmeans_1d(w[i], cb0[i], iters=5)
        np.testing.assert_array_equal(np.asarray(cb_b[i]),
                                      np.asarray(cb_i))
        np.testing.assert_array_equal(np.asarray(as_b[i]),
                                      np.asarray(as_i))


# ----------------------------------------------------------------------
# batched prune kernels vs oracle (incl. mixed κ, ragged tiles)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("t_vals", [(0.1, 0.7), (0.0, 2.5), (1.0, 1.0)])
def test_batched_count_mask_kernels_vs_ref(t_vals):
    from repro.kernels.prune.prune import (
        LANES, ROWS, count_above_batched, mask_apply_batched)
    w = jax.random.normal(jax.random.fold_in(KEY, 11),
                          (2, 4 * ROWS * LANES))
    t = jnp.array(t_vals, jnp.float32)
    counts = count_above_batched(w, t, interpret=True)
    masks = mask_apply_batched(w, t, interpret=True)
    for i in range(2):
        np.testing.assert_allclose(
            float(counts[i]), float(pref.count_above_ref(w[i], t[i])),
            rtol=0)
        np.testing.assert_allclose(
            np.asarray(masks[i]),
            np.asarray(pref.mask_apply_ref(w[i], t[i])), rtol=0)


@pytest.mark.parametrize("p", [3000, 4096, 1023])  # 3000/1023: ragged
def test_batched_topk_kernel_vs_jnp_mixed_kappa(p):
    w = jax.random.normal(jax.random.fold_in(KEY, p), (4, p))
    kappa = jnp.array([1, 17, p // 3, p - 1], jnp.int32)
    mj = pops.topk_mask_batched(w, kappa, impl="jnp")
    mi = pops.topk_mask_batched(w, kappa, impl="interpret")
    # identical support (exact order-statistic threshold), exact values
    np.testing.assert_array_equal(np.asarray(mj != 0),
                                  np.asarray(mi != 0))
    np.testing.assert_array_equal(np.asarray(mj), np.asarray(mi))
    for i in range(4):
        assert int(jnp.sum(mi[i] != 0)) == int(kappa[i])


def test_jnp_topk_solver_matches_pertask_scheme_bitwise():
    """sort+gather threshold == lax.top_k threshold — the bit-exactness
    the default (CPU auto→jnp) dispatch path relies on."""
    w = jax.random.normal(jax.random.fold_in(KEY, 21), (3, 777))
    kappa = jnp.array([5, 50, 500], jnp.int32)
    mj = pops.topk_mask_batched(w, kappa, impl="jnp")
    ref_scheme = [ConstraintL0Pruning(kappa=int(k)) for k in kappa]
    for i, s in enumerate(ref_scheme):
        exp = s.compress(w[i], None)["theta"]
        np.testing.assert_array_equal(np.asarray(mj[i]), np.asarray(exp))


def test_batched_topk_kernel_threshold_ties_keep_exactly_kappa():
    """Exact-magnitude ties at the κ boundary (±w pairs) keep *exactly*
    κ weights, lowest index first — never the whole tied class (that θ
    is infeasible for the ℓ0 constraint and trips the §7 monitor once
    the ties break) and never fewer (a strict > mask at the converged
    threshold would prune the largest weights entirely)."""
    w = jnp.array([[2.0, -2.0, 1.0, 0.5],
                   [3.0, 3.0, -3.0, 0.1]], jnp.float32)
    kappa = jnp.array([1, 2], jnp.int32)
    mj = pops.topk_mask_batched(w, kappa, impl="jnp")
    mi = pops.topk_mask_batched(w, kappa, impl="interpret")
    np.testing.assert_array_equal(np.asarray(mj), np.asarray(mi))
    # row 0: only the first of the tied ±2.0 pair survives (κ=1);
    # row 1: the first two of the three tied 3.0s survive (κ=2)
    np.testing.assert_array_equal(np.asarray(mi[0]),
                                  np.asarray([2.0, 0.0, 0.0, 0.0]))
    np.testing.assert_array_equal(np.asarray(mi[1]),
                                  np.asarray([3.0, 3.0, 0.0, 0.0]))
    # same support as the per-task scheme solver (lax.top_k order)
    for i, k in enumerate((1, 2)):
        exp = ConstraintL0Pruning(kappa=k).compress(w[i], None)["theta"]
        np.testing.assert_array_equal(np.asarray(mj[i]), np.asarray(exp))


def test_topk_traced_kappa_under_jit():
    """κ is a traced operand: one compiled program serves every κ."""
    w = jax.random.normal(KEY, (2, 1024))
    f = jax.jit(lambda w_, k_: pops.topk_mask_batched(w_, k_, impl="jnp"))
    for ks in ((3, 900), (64, 64)):
        out = f(w, jnp.array(ks, jnp.int32))
        assert [int(jnp.sum(out[i] != 0)) for i in range(2)] == list(ks)


# ----------------------------------------------------------------------
# registry + backend resolution (honest fallbacks)
# ----------------------------------------------------------------------
def test_registry_has_builtin_solvers():
    table = dispatch.solver_table()
    assert table["kmeans_lloyd"] == ("interpret", "jnp", "pallas")
    assert table["topk_mask"] == ("interpret", "jnp", "pallas")


def test_backend_resolution():
    on_tpu = jax.default_backend() == "tpu"
    assert dispatch.resolve_backend(None) is None
    assert dispatch.resolve_backend("off") is None
    assert dispatch.resolve_backend("jnp") == "jnp"
    assert dispatch.resolve_backend("interpret") == "interpret"
    assert dispatch.resolve_backend("auto") == (
        "pallas" if on_tpu else "jnp")
    # an explicit pallas request off-TPU degrades to interpret — the
    # same kernel, emulated — never to a silent algorithm switch
    assert dispatch.resolve_backend("pallas") == (
        "pallas" if on_tpu else "interpret")
    with pytest.raises(ValueError):
        dispatch.resolve_backend("cuda")


def test_algorithm_validates_backend_eagerly():
    """A typo'd backend must fail at construction, not minutes later
    inside the first C-step jit trace."""
    tasks = [CompressionTask("a", "^a$", AsVector(),
                             ConstraintL0Pruning(kappa=4))]
    with pytest.raises(ValueError, match="cstep_backend"):
        LCAlgorithm(tasks, [1e-2], cstep_backend="pallsa")
    with pytest.raises(ValueError, match="cstep_backend"):
        LCAlgorithm(tasks, [1e-2]).set_backend("gpu")
    # the eager allowlist must track the dispatch registry's REQUESTS
    assert set(dispatch.REQUESTS) == {"auto", "jnp", "interpret",
                                      "pallas", "off"}


def test_core_import_does_not_pull_pallas():
    """`import repro.core` with dispatch off must not eagerly import
    the Pallas kernel modules (they load lazily on first solver
    lookup)."""
    import subprocess
    import sys
    code = ("import sys; import repro.core; "
            "assert not any('pallas' in m for m in sys.modules), "
            "[m for m in sys.modules if 'pallas' in m]")
    proc = subprocess.run([sys.executable, "-c", code],
                          capture_output=True, text=True,
                          env={**__import__('os').environ})
    assert proc.returncode == 0, proc.stderr


def test_theta_dtype_stable_across_backends_for_bf16_params():
    """Views cast every compressible to f32 before a scheme sees it, so
    Θ keeps one dtype from init through every C step on every backend —
    no mid-run retrace/reshard from a dtype flip, bf16 params included."""
    params = {n: jax.random.normal(jax.random.fold_in(KEY, i),
                                   (256,)).astype(jnp.bfloat16)
              for i, n in enumerate(("a", "b"))}
    for backend in ("off", "jnp", "interpret"):
        lc = LCAlgorithm(
            [CompressionTask(n, f"^{n}$", AsVector(),
                             ConstraintL0Pruning(kappa=16))
             for n in ("a", "b")], [1e-2], cstep_backend=backend)
        st0 = lc.init(params)
        st1 = lc.c_step(params, st0)
        for st in (st0, st1):
            assert st["tasks"]["a"]["theta"]["theta"].dtype == \
                jnp.float32, backend


def test_lookup_unknown_solver_falls_back_to_vmap_path():
    fn, backend = dispatch.lookup("no_such_solver", "auto")
    assert fn is None and backend is None
    fn, backend = dispatch.lookup(None, "auto")
    assert fn is None and backend is None


def test_describe_groups_reports_solver_and_backend():
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(KEY, i),
                                         (256,)) for i in range(3)}
    tasks = [CompressionTask(f"pr{i}", f"^l{i}$", AsVector(),
                             ConstraintL0Pruning(kappa=8 * (i + 1)))
             for i in range(3)]
    for i, t in enumerate(tasks):
        t.paths = [f"l{i}"]
    xs = {t.name: params[f"l{i}"] for i, t in enumerate(tasks)}
    # off: three κ-distinct groups, no solver
    off = describe_groups(tasks, xs, backend="off")
    assert len(off) == 3
    assert all(g["solver"] is None and g["backend"] is None for g in off)
    # interpret: one mixed-κ group, solver + actual backend reported
    on = describe_groups(tasks, xs, backend="interpret")
    assert len(on) == 1
    assert on[0]["solver"] == "topk_mask"
    assert on[0]["backend"] == "interpret"
    assert on[0]["grouped"] and on[0]["items"] == 3
    # a pallas request reports what actually runs
    hw = describe_groups(tasks, xs, backend="pallas")
    assert hw[0]["backend"] == (
        "pallas" if jax.default_backend() == "tpu" else "interpret")


# ----------------------------------------------------------------------
# mixed-κ grouping through the full C step
# ----------------------------------------------------------------------
def _mixed_kappa_setup(n=4, p=512):
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(KEY, 31 + i),
                                         (p,)) for i in range(n)}
    tasks = lambda: [CompressionTask(f"pr{i}", f"^l{i}$", AsVector(),
                                     ConstraintL0Pruning(kappa=16 * (i + 1)))
                     for i in range(n)]
    return params, tasks


def test_mixed_kappa_tasks_share_one_group_and_launch():
    """κ∈{16,32,48,64} → four groups without dispatch (κ is static in
    group_key), ONE group with it (κ rides as a per-item operand)."""
    params, tasks = _mixed_kappa_setup()
    lc_off = LCAlgorithm(tasks(), [1e-2], cstep_backend="off")
    lc_on = LCAlgorithm(tasks(), [1e-2], cstep_backend="interpret")
    assert len(lc_off.group_summary(params)) == 4
    summary = lc_on.group_summary(params)
    assert len(summary) == 1 and summary[0]["grouped"]

    st_off = lc_off.c_step(params, lc_off.init(params))
    st_on = lc_on.c_step(params, lc_on.init(params))
    for name in st_off["tasks"]:
        np.testing.assert_array_equal(
            np.asarray(st_off["tasks"][name]["theta"]["theta"]),
            np.asarray(st_on["tasks"][name]["theta"]["theta"]),
            err_msg=name)


def test_mixed_kappa_jnp_backend_bitwise_vs_off():
    """The default CPU backend (auto→jnp) must not move a single bit
    relative to the pre-dispatch engine, mixed κ included."""
    params, tasks = _mixed_kappa_setup()
    lc_off = LCAlgorithm(tasks(), [1e-2, 1.5e-2], cstep_backend="off")
    lc_jnp = LCAlgorithm(tasks(), [1e-2, 1.5e-2], cstep_backend="jnp")
    s_off, s_jnp = lc_off.init(params), lc_jnp.init(params)
    for _ in range(2):
        s_off = lc_off.multiplier_step(params, lc_off.c_step(params, s_off))
        s_jnp = lc_jnp.multiplier_step(params, lc_jnp.c_step(params, s_jnp))
    flat_o = jax.tree_util.tree_leaves_with_path(s_off)
    flat_j = jax.tree_util.tree_leaves_with_path(s_jnp)
    assert len(flat_o) == len(flat_j)
    for (ko, vo), (kj, vj) in zip(flat_o, flat_j):
        assert ko == kj
        np.testing.assert_array_equal(np.asarray(vo), np.asarray(vj),
                                      err_msg=jax.tree_util.keystr(ko))


# ----------------------------------------------------------------------
# both dispatch paths (grouped + per-task) hit the kernels
# ----------------------------------------------------------------------
def _quant_prune_tasks():
    return ([CompressionTask(f"q{i}", f"^q{i}$", AsVector(),
                             AdaptiveQuantization(k=4, iters=5))
             for i in range(2)]
            + [CompressionTask(f"p{i}", f"^p{i}$", AsVector(),
                               ConstraintL0Pruning(kappa=32))
               for i in range(2)]
            + [CompressionTask("st", r"^stack$", AsStacked("vector"),
                               ConstraintL0Pruning(kappa=20))])


def _quant_prune_params():
    return {
        **{f"q{i}": jax.random.normal(jax.random.fold_in(KEY, 61 + i),
                                      (512,)) for i in range(2)},
        **{f"p{i}": jax.random.normal(jax.random.fold_in(KEY, 71 + i),
                                      (384,)) for i in range(2)},
        "stack": jax.random.normal(jax.random.fold_in(KEY, 81), (3, 384)),
    }


@pytest.mark.parametrize("group_tasks", [True, False])
def test_kernel_path_differential_both_dispatch_modes(group_tasks):
    """interpret (kernel) vs jnp backends on the full LC state, grouped
    AND per-task dispatch: prune exact, quantize within tolerance."""
    params = _quant_prune_params()
    lc_j = LCAlgorithm(_quant_prune_tasks(), [1e-2],
                       group_tasks=group_tasks, cstep_backend="jnp")
    lc_k = LCAlgorithm(_quant_prune_tasks(), [1e-2],
                       group_tasks=group_tasks, cstep_backend="interpret")
    st_j = lc_j.c_step(params, lc_j.init(params))
    st_k = lc_k.c_step(params, lc_k.init(params))
    for i in range(2):
        np.testing.assert_array_equal(
            np.asarray(st_j["tasks"][f"p{i}"]["theta"]["theta"]),
            np.asarray(st_k["tasks"][f"p{i}"]["theta"]["theta"]))
        np.testing.assert_allclose(
            np.asarray(st_j["tasks"][f"q{i}"]["theta"].codebook),
            np.asarray(st_k["tasks"][f"q{i}"]["theta"].codebook),
            atol=KMEANS_CB_ATOL)
    np.testing.assert_array_equal(
        np.asarray(st_j["tasks"]["st"]["theta"]["theta"]),
        np.asarray(st_k["tasks"]["st"]["theta"]["theta"]))


def test_solve_task_routes_stacked_view_through_solver():
    """Per-task kernel dispatch flattens a stacked view into the item
    stack the batched solver expects."""
    x = jax.random.normal(KEY, (3, 300))
    task = CompressionTask("st", "^w$", AsStacked("vector"),
                           ConstraintL0Pruning(kappa=10))
    task.paths = ["w"]
    theta = task.scheme_init(x)
    out_k = solve_task(task, x, theta, mu=None, backend="interpret")
    out_v = solve_task(task, x, theta, mu=None, backend=None)
    np.testing.assert_array_equal(np.asarray(out_k["theta"] != 0),
                                  np.asarray(out_v["theta"] != 0))
    assert out_k["theta"].shape == (3, 300)


def test_subclass_compress_override_falls_back_to_vmap():
    """A subclass overriding compress() but inheriting compress_batched
    must NOT be kernel-dispatched (it would run the parent's math)."""
    calls = []

    class TracedPrune(ConstraintL0Pruning):
        def compress(self, w, theta, mu=None):
            calls.append(1)
            return super().compress(w, theta, mu=mu)

    assert not TracedPrune(kappa=4).kernel_dispatch_ready()
    assert ConstraintL0Pruning(kappa=4).kernel_dispatch_ready()

    params = {"a": jax.random.normal(KEY, (128,)),
              "b": jax.random.normal(jax.random.fold_in(KEY, 1), (128,))}
    tasks = [CompressionTask("a", "^a$", AsVector(), TracedPrune(kappa=4)),
             CompressionTask("b", "^b$", AsVector(), TracedPrune(kappa=4))]
    lc = LCAlgorithm(tasks, [1e-2], cstep_backend="interpret")
    calls.clear()
    jax.block_until_ready(lc.c_step(params, lc.init(params)))
    assert calls  # compress() was traced — the vmap path ran


def test_unregistered_solver_keeps_per_value_grouping():
    """A scheme naming a solver that isn't in the registry must NOT
    switch to batch_key grouping: the vmap fallback would solve a
    mixed-κ group with group[0]'s κ. It falls back to the legacy
    per-value groups with correct per-task numerics instead."""

    class TypoPrune(ConstraintL0Pruning):
        solver = "my_topk_not_registered"

    params = {"a": jax.random.normal(KEY, (256,)),
              "b": jax.random.normal(jax.random.fold_in(KEY, 1), (256,))}
    tasks = [CompressionTask("a", "^a$", AsVector(), TypoPrune(kappa=4)),
             CompressionTask("b", "^b$", AsVector(), TypoPrune(kappa=8))]
    lc = LCAlgorithm(tasks, [1e-2], cstep_backend="jnp")
    summary = lc.group_summary(params)
    assert len(summary) == 2           # κ stays in the grouping identity
    assert all(g["solver"] is None for g in summary)
    st = lc.c_step(params, lc.init(params))
    assert int((st["tasks"]["a"]["theta"]["theta"] != 0).sum()) == 4
    assert int((st["tasks"]["b"]["theta"]["theta"] != 0).sum()) == 8


def test_trainer_config_does_not_clobber_explicit_algorithm_backend():
    """TrainerConfig.cstep_backend=None (default) inherits the
    algorithm's backend; an explicit trainer value overrides it."""
    from repro.configs import get_config, reduced_config
    from repro.data import TokenStream
    from repro.runtime import LCTrainer, TrainerConfig

    cfg = reduced_config(get_config("phi3-mini-3.8b")).with_(
        pattern_reps=1)

    def make(tcfg):
        lc = LCAlgorithm(
            [CompressionTask("qg", r"stages/.*/w_gate$", AsVector(),
                             AdaptiveQuantization(k=2, iters=3))],
            [1e-3], cstep_backend="interpret")
        LCTrainer(cfg, lc, TokenStream(cfg.vocab_size, 2, 8), tcfg=tcfg)
        return lc

    assert TrainerConfig().cstep_backend is None
    assert make(TrainerConfig()).cstep_backend == "interpret"
    assert make(TrainerConfig(cstep_backend="jnp")).cstep_backend == "jnp"


def test_build_groups_backend_none_keeps_legacy_signatures():
    params = {"a": jax.random.normal(KEY, (128,)),
              "b": jax.random.normal(KEY, (128,))}
    tasks = [CompressionTask("a", "^a$", AsVector(),
                             ConstraintL0Pruning(kappa=16)),
             CompressionTask("b", "^b$", AsVector(),
                             ConstraintL0Pruning(kappa=32))]
    for t in tasks:
        t.paths = [t.name]
    assert len(build_groups(tasks, params)) == 2
    assert len(build_groups(tasks, params, backend="jnp")) == 1


# ----------------------------------------------------------------------
# grouped Θ^DC init
# ----------------------------------------------------------------------
def test_grouped_init_bitwise_matches_legacy_loop():
    params = _quant_prune_params()
    lc_g = LCAlgorithm(_quant_prune_tasks(), [1e-2], group_tasks=True)
    lc_p = LCAlgorithm(_quant_prune_tasks(), [1e-2], group_tasks=False)
    sg, sp = lc_g.init(params), lc_p.init(params)
    flat_g = jax.tree_util.tree_leaves_with_path(sg)
    flat_p = jax.tree_util.tree_leaves_with_path(sp)
    assert len(flat_g) == len(flat_p)
    for (kg, vg), (kp, vp) in zip(flat_g, flat_p):
        assert kg == kp
        np.testing.assert_array_equal(np.asarray(vg), np.asarray(vp),
                                      err_msg=jax.tree_util.keystr(kg))


def test_grouped_init_splits_init_only_hyperparams():
    """use_dp_init/dp_bins change init() but not compress(): the C step
    may group across them, grouped init must NOT (or group[0]'s warm
    start would silently apply to every member)."""
    params = {"a": jax.random.normal(KEY, (2048,)),
              "b": jax.random.normal(jax.random.fold_in(KEY, 1), (2048,))}

    def tasks():
        return [CompressionTask("a", "^a$", AsVector(),
                                AdaptiveQuantization(k=4, iters=5,
                                                     use_dp_init=True)),
                CompressionTask("b", "^b$", AsVector(),
                                AdaptiveQuantization(k=4, iters=5))]

    lc_g = LCAlgorithm(tasks(), [1e-2], group_tasks=True)
    lc_p = LCAlgorithm(tasks(), [1e-2], group_tasks=False)
    # C-step grouping still merges them (same compress program)...
    (g,) = lc_g.group_summary(params)
    assert set(g["tasks"]) == {"a", "b"}
    # ...but Θ^DC must match the per-task loop bit for bit
    sg, sp = lc_g.init(params), lc_p.init(params)
    for name in ("a", "b"):
        np.testing.assert_array_equal(
            np.asarray(sg["tasks"][name]["theta"].codebook),
            np.asarray(sp["tasks"][name]["theta"].codebook),
            err_msg=name)


def test_kernels_package_does_not_shadow_subpackages():
    """`repro.kernels.kmeans` must stay the subpackage, not a re-exported
    function (attribute-style module access would break)."""
    import importlib
    import types

    import repro.kernels as pk
    importlib.import_module("repro.kernels.kmeans.ops")
    assert isinstance(pk.kmeans, types.ModuleType)
    assert isinstance(pk.prune, types.ModuleType)
    assert pk.kmeans.ops.kmeans_batched is not None


def test_grouped_init_is_one_jitted_call():
    """Cold start compiles one program (O(groups) traces inside it),
    not one eager op stream per task."""
    params = _quant_prune_params()
    lc = LCAlgorithm(_quant_prune_tasks(), [1e-2])
    lc.resolve(params)
    lowered = jax.jit(lc._init_grouped_impl).lower(params)
    assert lowered.compile() is not None


# ----------------------------------------------------------------------
# sharded path composes with kernel dispatch (1-device mesh on CPU;
# multi-device bit-identity lives in test_sharded_cstep subprocesses)
# ----------------------------------------------------------------------
def test_dispatch_under_mesh_matches_no_mesh():
    from repro.launch.mesh import make_cstep_mesh
    params, tasks = _mixed_kappa_setup()
    lc0 = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp")
    lcm = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp",
                      mesh=make_cstep_mesh())
    s0 = lc0.c_step(params, lc0.init(params))
    sm = lcm.c_step(params, lcm.init(params))
    for (k0, v0), (km, vm) in zip(
            jax.tree_util.tree_leaves_with_path(s0),
            jax.tree_util.tree_leaves_with_path(sm)):
        assert k0 == km
        np.testing.assert_array_equal(np.asarray(v0), np.asarray(vm),
                                      err_msg=jax.tree_util.keystr(k0))


# ----------------------------------------------------------------------
# TPU-only: compiled kernels (the interpret differentials above pin the
# math; this pins the mosaic compilation)
# ----------------------------------------------------------------------
@pytest.mark.skipif(jax.default_backend() != "tpu",
                    reason="compiled Pallas kernels need a TPU")
def test_compiled_pallas_backend_matches_interpret():
    w = jax.random.normal(KEY, (4, 4096))
    kappa = jnp.array([8, 64, 512, 2048], jnp.int32)
    mi = pops.topk_mask_batched(w, kappa, impl="interpret")
    mp = pops.topk_mask_batched(w, kappa, impl="pallas")
    np.testing.assert_array_equal(np.asarray(mi), np.asarray(mp))
    cb0 = jnp.sort(jax.random.normal(KEY, (4, 8)), axis=-1)
    ci, _ = kops.kmeans_batched(w, cb0, iters=10, impl="interpret")
    cp, _ = kops.kmeans_batched(w, cb0, iters=10, impl="pallas")
    np.testing.assert_allclose(np.asarray(ci), np.asarray(cp),
                               atol=KMEANS_CB_ATOL)


# ----------------------------------------------------------------------
# data prefetcher (the C step overlaps data loading too)
# ----------------------------------------------------------------------
def test_prefetcher_matches_direct_batches():
    data = TokenStream(vocab_size=64, batch=2, seq_len=8)
    pf = Prefetcher(data)
    pf.prefetch(3)
    direct = data.batch_at(3)
    fetched = pf.batch_at(3)
    np.testing.assert_array_equal(np.asarray(fetched["inputs"]),
                                  np.asarray(direct["inputs"]))
    # miss path computes directly; repeat fetch of a consumed step too
    np.testing.assert_array_equal(np.asarray(pf.batch_at(5)["inputs"]),
                                  np.asarray(data.batch_at(5)["inputs"]))
    np.testing.assert_array_equal(np.asarray(pf.batch_at(3)["inputs"]),
                                  np.asarray(direct["inputs"]))


def test_prefetcher_wraps_callable_sources_and_caps_slots():
    calls = []

    def source(step):
        calls.append(step)
        return {"step": step}

    pf = Prefetcher(source)
    for s in range(8):
        pf.prefetch(s)
    pf.prefetch(3)  # idempotent per step — no duplicate fetch
    assert pf.batch_at(7)["step"] == 7
    assert len(pf._pending) <= Prefetcher.MAX_SLOTS
    assert calls.count(3) <= 2  # dropped slot may refetch, never dupes
