"""Retrace-count regression for the overlapped trainer path.

Every LC boundary reruns the same jitted ``c_step``/``multiplier_step``
on identically-shaped state, so each must compile exactly once no
matter how many boundaries run. The overlapped trainer
(``overlap="on"``) is the path most at risk: it drives the async entry
points and re-syncs penalty refs at every μ change, so anything
non-hashable leaking into those calls (a Python-float μ, a rebuilt
mesh, Θ shape drift) turns each boundary into a fresh multi-second
compile. Layer 3's trace counter is the detector; this file pins the
trainer to it.
"""
import jax
import jax.numpy as jnp

from repro.analysis.lint.trace_count import (check_retraces, instrument,
                                             run_boundaries)
from repro.configs import get_config, reduced_config
from repro.core import (AsVector, CompressionTask, LCAlgorithm,
                        exponential_mu_schedule)
from repro.core.schemes import AdaptiveQuantization
from repro.core.schemes.prune import ConstraintL0Pruning
from repro.core.tasks import CompressionTask as Task
from repro.core.views import AsStacked
from repro.data import TokenStream
from repro.runtime import LCTrainer, TrainerConfig

KEY = jax.random.PRNGKey(0)

CFG = reduced_config(get_config("phi3-mini-3.8b")).with_(pattern_reps=1)


def _make_overlapped_trainer(n_mu=3, steps_per_l=2):
    data = TokenStream(CFG.vocab_size, 2, 16)
    lc = LCAlgorithm(
        [CompressionTask("qg", r"stages/.*/w_gate$", AsVector(),
                         AdaptiveQuantization(k=2, iters=5)),
         CompressionTask("qu", r"stages/.*/w_up$", AsVector(),
                         AdaptiveQuantization(k=2, iters=5))],
        exponential_mu_schedule(1e-4, 1.5, n_mu))
    tcfg = TrainerConfig(steps_per_l=steps_per_l, overlap="on", lr=3e-4)
    return LCTrainer(CFG, lc, data, tcfg=tcfg)


def _toy_algo():
    params = {
        "qa": jnp.linspace(-1.0, 1.0, 32).reshape(2, 16),
        "pb": jnp.linspace(1.0, -1.0, 32).reshape(2, 16),
    }
    tasks = [
        Task("lint/quant", "qa", AsStacked("vector"),
             AdaptiveQuantization(k=2, iters=2)),
        Task("lint/prune", "pb", AsStacked("vector"),
             ConstraintL0Pruning(kappa=8)),
    ]
    algo = LCAlgorithm(tasks, mu_schedule=[1e-3, 1e-2, 1e-1])
    return algo, params, algo.init(params)


# ----------------------------------------------------------------------
# The satellite: overlapped trainer, 3 boundaries, one compile each
# ----------------------------------------------------------------------
def test_overlapped_trainer_compiles_each_step_once_across_3_boundaries():
    trainer = _make_overlapped_trainer(n_mu=3)
    counters = instrument(trainer.lc)
    trainer.run(KEY)
    assert counters["c_step"] == 1, (
        f"c_step traced {counters['c_step']}× across 3 overlapped LC "
        "boundaries — every boundary is paying compile time")
    assert counters["multiplier_step"] == 1, (
        f"multiplier_step traced {counters['multiplier_step']}× across "
        "3 overlapped LC boundaries")


def test_async_entry_points_share_the_sync_compile_cache():
    # On CPU donate="auto" resolves to off, so the async entry points
    # must be the *same* executables — mixing sync and async calls
    # across boundaries still compiles once.
    algo, params, lc = _toy_algo()
    counters = instrument(algo)
    mu = float(algo.mu_schedule[0])
    for k in range(3):
        lc = algo.set_mu(lc, mu, k)
        if k % 2 == 0:
            lc = algo.c_step_async(params, lc)
            lc = algo.multiplier_step_async(params, lc)
        else:
            lc = algo.c_step(params, lc)
            lc = algo.multiplier_step(params, lc)
    assert counters == {"c_step": 1, "multiplier_step": 1}


def test_run_boundaries_overlap_counts_once_and_flags_nothing():
    algo, params, lc = _toy_algo()
    counts = run_boundaries(algo, params, lc, boundaries=3, overlap=True)
    assert counts == {"c_step": 1, "multiplier_step": 1}

    algo, params, lc = _toy_algo()
    assert check_retraces(algo, params, lc, boundaries=3,
                          overlap=True) == []


# ----------------------------------------------------------------------
# Positive control: the counter must actually catch the bug class
# ----------------------------------------------------------------------
class _RejittingAlgo:
    """Faithful stub of the bug class: rebuilds the jit wrappers at
    every μ change (e.g. calling ``_build_steps``/``set_mesh`` per
    boundary), so every boundary is a cache miss."""

    mu_schedule = [0.1, 0.2]

    def __init__(self):
        self._build_steps()

    def _c_step_impl(self, params, lc):
        return jax.tree_util.tree_map(lambda x: x * 2.0, lc)

    def _multiplier_step_impl(self, params, lc):
        return jax.tree_util.tree_map(lambda x: x + 1.0, lc)

    def _build_steps(self):
        # fresh closures → fresh jit cache keys (jitting the *same*
        # function object twice would still hit jax's global cache)
        impl_c, impl_m = self._c_step_impl, self._multiplier_step_impl
        self._c_jit = jax.jit(lambda params, lc: impl_c(params, lc))
        self._m_jit = jax.jit(lambda params, lc: impl_m(params, lc))

    def set_mu(self, lc, mu, k):
        self._build_steps()  # the bug: rebuilt closures every boundary
        return lc

    def c_step(self, params, lc):
        return self._c_jit(params, lc)

    def multiplier_step(self, params, lc):
        return self._m_jit(params, lc)


def test_rejitting_boundary_trips_boundary_retrace():
    algo = _RejittingAlgo()
    params = {"w": jnp.ones((4,))}
    lc = {"theta": jnp.zeros((4,))}
    findings = check_retraces(algo, params, lc, boundaries=3)
    assert sorted(f.context for f in findings) == [
        "lc-boundaries:c_step", "lc-boundaries:multiplier_step"]
    for f in findings:
        assert f.rule == "boundary-retrace"
        assert "traced 3×" in f.message


def test_instrument_counts_legitimate_shape_retraces():
    # sanity: the counter is a trace counter, not a call counter —
    # same shapes twice is one trace, a new shape is a second.
    algo, params, lc = _toy_algo()
    counters = instrument(algo)
    lc = algo.set_mu(lc, 1e-3, 0)
    algo.c_step(params, lc)
    algo.c_step(params, lc)
    assert counters["c_step"] == 1
