"""C-step scheme correctness: projection properties, known optima,
distortion monotonicity (paper §7 monitors), and hypothesis properties."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.schemes import (
    AdaptiveQuantization, AdditiveCombination, Binarize,
    ConstraintL0Pruning, ConstraintL1Pruning, LowRank, PenaltyL0Pruning,
    PenaltyL1Pruning, RankSelection, Ternarize, optimal_codebook_dp,
    project_l1_ball)

KEY = jax.random.PRNGKey(0)


def _w(n=4096, key=KEY):
    return jax.random.normal(key, (n,))


# ----------------------------------------------------------------------
# Quantization
# ----------------------------------------------------------------------
def test_quant_distortion_decreases_with_k():
    w = _w()
    prev = np.inf
    for k in (2, 4, 8, 32):
        s = AdaptiveQuantization(k=k, iters=30)
        d = float(s.distortion(w, s.init(w)))
        assert d < prev
        prev = d


def test_quant_beats_fixed_binarization():
    w = _w()
    q = AdaptiveQuantization(k=2, iters=40)
    b = Binarize(scaled=True)
    assert float(q.distortion(w, q.init(w))) <= \
        float(b.distortion(w, b.init(w))) + 1e-3


def test_binarize_scale_is_mean_abs():
    w = _w()
    b = Binarize(scaled=True)
    th = b.init(w)
    np.testing.assert_allclose(float(th["scale"]),
                               float(jnp.mean(jnp.abs(w))), rtol=1e-6)


def test_ternarize_optimal_vs_sweep():
    """Joint (support, scale) optimum must beat any manual support size."""
    w = _w(512)
    t = Ternarize()
    d_opt = float(t.distortion(w, t.init(w)))
    a = np.sort(np.abs(np.asarray(w)))[::-1]
    for s in (16, 64, 128, 256, 511):
        c = a[:s].mean()
        d = float(((a[:s] - c) ** 2).sum() + (a[s:] ** 2).sum())
        assert d_opt <= d + 1e-3


def test_dp_matches_kmeans_at_convergence():
    w = _w(8192)
    cb_dp = optimal_codebook_dp(w, 4, bins=1024)
    s = AdaptiveQuantization(k=4, iters=60)
    cb_km = s.init(w).codebook
    np.testing.assert_allclose(np.asarray(cb_dp), np.asarray(cb_km),
                               atol=0.05)


def test_kmeans_warm_start_monotone():
    """compress() warm-started at previous Θ never increases distortion."""
    w = _w()
    s = AdaptiveQuantization(k=8, iters=3)
    th = s.init(w)
    d0 = float(s.distortion(w, th))
    th2 = s.compress(w, th)
    assert float(s.distortion(w, th2)) <= d0 + 1e-4


# ----------------------------------------------------------------------
# Pruning
# ----------------------------------------------------------------------
def test_l0_constraint_exact_support():
    w = _w()
    kappa = 123
    s = ConstraintL0Pruning(kappa)
    th = s.init(w)
    assert int(jnp.sum(th["theta"] != 0)) == kappa
    # kept entries are the κ largest
    kept = np.sort(np.abs(np.asarray(th["theta"]))[
        np.asarray(th["theta"]) != 0])
    top = np.sort(np.abs(np.asarray(w)))[-kappa:]
    np.testing.assert_allclose(kept, top)


def test_l0_penalty_threshold():
    w = _w()
    s = PenaltyL0Pruning(alpha=1e-2)
    mu = 0.5
    th = s.compress(w, None, mu=mu)
    t = np.sqrt(2 * s.alpha / mu)
    mask = np.abs(np.asarray(w)) > t
    np.testing.assert_array_equal(np.asarray(th["theta"] != 0), mask)


def test_l1_penalty_soft_threshold():
    w = _w()
    s = PenaltyL1Pruning(alpha=0.05)
    th = s.compress(w, None, mu=0.5)
    expect = np.sign(np.asarray(w)) * np.maximum(
        np.abs(np.asarray(w)) - 0.1, 0.0)
    np.testing.assert_allclose(np.asarray(th["theta"]), expect, atol=1e-6)


def test_l1_ball_projection():
    w = _w(256)
    r = 10.0
    p = project_l1_ball(w, r)
    assert float(jnp.sum(jnp.abs(p))) <= r * (1 + 1e-5)
    # projection optimality: for any other feasible point, ||w-p|| smaller
    q = p * 0.9
    assert float(jnp.sum((w - p) ** 2)) <= float(jnp.sum((w - q) ** 2))


def test_l1_ball_inside_is_identity():
    w = jnp.array([0.1, -0.2, 0.3])
    p = project_l1_ball(w, 10.0)
    np.testing.assert_allclose(np.asarray(p), np.asarray(w))


# ----------------------------------------------------------------------
# Low-rank
# ----------------------------------------------------------------------
def test_lowrank_matches_tail_energy():
    w = jax.random.normal(KEY, (48, 32))
    for r in (1, 4, 16):
        s = LowRank(target_rank=r, randomized=False)
        d = float(s.distortion(w, s.init(w)))
        sv = np.linalg.svd(np.asarray(w), compute_uv=False)
        np.testing.assert_allclose(d, float((sv[r:] ** 2).sum()),
                                   rtol=1e-4)


def test_randomized_svd_close_to_exact():
    w = jax.random.normal(KEY, (256, 128))
    s_ex = LowRank(target_rank=8, randomized=False)
    s_r = LowRank(target_rank=8, randomized=True)
    d_ex = float(s_ex.distortion(w, s_ex.init(w)))
    d_r = float(s_r.distortion(w, s_r.init(w)))
    assert d_r <= d_ex * 1.05  # oversampled + power iters ⇒ near-exact


def test_rank_selection_monotone_in_alpha():
    w = jax.random.normal(KEY, (64, 48))
    ranks = []
    for alpha in (1e-6, 1e-3, 1e-1, 10.0):
        s = RankSelection(alpha=alpha)
        th = s.compress(w, None, mu=1.0)
        ranks.append(int(th["rank"]))
    assert ranks == sorted(ranks, reverse=True)  # higher α ⇒ lower rank
    assert ranks[0] > 0


def test_rank_selection_mu_drives_rank_up():
    w = jax.random.normal(KEY, (64, 48))
    s = RankSelection(alpha=1e-3)
    r_lo = int(s.compress(w, None, mu=0.01)["rank"])
    r_hi = int(s.compress(w, None, mu=100.0)["rank"])
    assert r_hi >= r_lo


def test_rank_selection_bits_uses_selected_rank():
    """Regression: bits() returned (m+n)·float_bits per *unit* rank,
    ignoring θ["rank"] — inflating compression ratios by ~rank×."""
    import math
    w = jax.random.normal(KEY, (64, 48))
    s = RankSelection(alpha=0.1)
    th = s.compress(w, None, mu=1.0)
    r = int(th["rank"])
    assert 0 < r < min(w.shape)  # a genuinely partial rank
    r_max = th["u"].shape[1]
    expect = r * (64 + 48) * 32 + math.ceil(math.log2(r_max + 1))
    assert s.bits(th) == pytest.approx(expect)
    # and it is rank-dependent: a cheaper α keeps more rank ⇒ more bits
    th_hi = RankSelection(alpha=1e-2).compress(w, None, mu=1.0)
    assert int(th_hi["rank"]) > r
    assert RankSelection(alpha=1e-2).bits(th_hi) > s.bits(th)


# ----------------------------------------------------------------------
# Additive combinations
# ----------------------------------------------------------------------
def test_additive_beats_components():
    w = _w(2048)
    q = AdaptiveQuantization(k=2, iters=20)
    p = ConstraintL0Pruning(kappa=64)
    a = AdditiveCombination([p, q], iters=3)
    d_a = float(a.distortion(w, a.init(w)))
    d_q = float(q.distortion(w, q.init(w)))
    d_p = float(p.distortion(w, p.init(w)))
    assert d_a <= min(d_q, d_p) + 1e-3


def test_additive_alternation_monotone():
    w = _w(1024)
    a = AdditiveCombination(
        [ConstraintL0Pruning(kappa=32), AdaptiveQuantization(k=2)],
        iters=1)
    th = a.init(w)
    d0 = float(a.distortion(w, th))
    th = a.compress(w, th, mu=1.0)
    assert float(a.distortion(w, th)) <= d0 + 1e-4


def test_l0_prune_exact_kappa_under_magnitude_ties():
    """Regression (scenario matrix, jamba × additive): mamba ``A_log``
    repeats each value 128× at init, so the top-κ boundary is a wide
    tied class. A threshold mask (``|w| >= kth``) keeps the whole class
    — ‖θ‖₀ ≫ κ, infeasible, with under-reported distortion and a fake
    ``bits()`` ratio. The projection must keep *exactly* κ."""
    a_log = jnp.log(jnp.arange(1, 5, dtype=jnp.float32))
    w = a_log[None, :].repeat(128, 0).ravel()        # 4 values × 128
    th = ConstraintL0Pruning(kappa=64).compress(w, None)
    assert int(jnp.sum(th["theta"] != 0)) == 64


def test_additive_monotone_across_c_steps_on_tied_weights():
    """Regression (scenario matrix, jamba × additive): with the tied
    init above, the over-kept infeasible θ^DC made the *next* C step —
    on weights whose L-step noise broke the ties — measure a distortion
    increase (9.3 → 55 on the real cell), tripping the §7 monitor. With
    an exact-κ projection the alternation stays monotone: the new θ
    must beat the old θ on the new weights."""
    a_log = jnp.log(jnp.arange(1, 5, dtype=jnp.float32))
    w0 = a_log[None, :].repeat(128, 0).ravel()
    sch = AdditiveCombination(
        [AdaptiveQuantization(k=2, iters=5),
         ConstraintL0Pruning(kappa=w0.size // 8)], iters=2)
    th = sch.init(w0)
    assert int(jnp.sum(th["parts"][1]["theta"] != 0)) <= w0.size // 8
    w1 = w0 + 0.05 * jax.random.normal(jax.random.PRNGKey(0), w0.shape)
    pre = float(sch.distortion(w1, th))
    post = float(sch.distortion(w1, sch.compress(w1, th)))
    assert post <= pre * (1 + 1e-5) + 1e-8


# ----------------------------------------------------------------------
# Hypothesis property tests
# ----------------------------------------------------------------------
@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=2, max_value=16),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_quant_projection_idempotent(k, seed):
    """Π(Δ(Θ)) reproduces Θ's decompression exactly (projection)."""
    w = jax.random.normal(jax.random.PRNGKey(seed), (512,))
    s = AdaptiveQuantization(k=k, iters=15)
    th = s.init(w)
    dec = s.decompress(th)
    th2 = s.compress(dec, th)
    np.testing.assert_allclose(np.asarray(s.decompress(th2)),
                               np.asarray(dec), atol=1e-5)


@settings(max_examples=20, deadline=None)
@given(st.integers(min_value=1, max_value=400),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_l0_distortion_is_tail(kappa, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (512,))
    s = ConstraintL0Pruning(kappa)
    d = float(s.distortion(w, s.init(w)))
    a = np.sort(np.abs(np.asarray(w)))
    np.testing.assert_allclose(d, float((a[:-kappa] ** 2).sum()
                                        if kappa < 512 else 0.0),
                               rtol=1e-4, atol=1e-5)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_ternary_scale_nonneg(seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (256,))
    t = Ternarize()
    th = t.init(w)
    assert float(th["scale"]) >= 0.0
    d = float(t.distortion(w, th))
    assert d <= float(jnp.sum(w**2)) + 1e-5  # never worse than all-zero


# ----------------------------------------------------------------------
# Per-expert views: AsStacked(stack_ndim=2) over MoE-shaped leaves
# (scenario-matrix regression: a scanned expert tensor (L, E, m, n)
# must compress per (layer, expert), not as L flattened expert blocks)
# ----------------------------------------------------------------------
def test_stacked_view_per_expert_task_roundtrip():
    from repro.core.tasks import CompressionTask
    from repro.core.views import AsStacked

    key = jax.random.PRNGKey(3)
    params = {"ffn": {"w_up": jax.random.normal(key, (2, 3, 16, 8))}}
    t = CompressionTask("experts", r"^ffn/w_up$",
                        AsStacked("matrix", stack_ndim=2),
                        LowRank(2, randomized=False)).resolve(params)
    x = t.compressible(params)
    assert x.shape == (6, 16, 8)          # L·E items, each (m, n)
    theta = t.scheme_init(x)
    assert theta["u"].shape == (6, 16, 2)  # one rank-2 factor per expert
    a = t.scatter_decompressed(t.scheme_decompress(theta), params)
    assert a["ffn/w_up"].shape == (2, 3, 16, 8)
    # per-expert truncated SVD must beat one shared flattened solve:
    # each item's distortion is the item's own tail energy
    for i in range(6):
        wi = np.asarray(x)[i]
        s = np.linalg.svd(wi, compute_uv=False)
        di = float(np.sum(
            (wi - np.asarray(theta["u"][i] @ theta["v"][i].T)) ** 2))
        np.testing.assert_allclose(di, float((s[2:] ** 2).sum()),
                                   rtol=1e-4, atol=1e-5)


def test_stacked_view_vector_domain_multi_axis():
    from repro.core.views import AsStacked

    leaf = jnp.arange(2 * 3 * 8, dtype=jnp.float32).reshape(2, 3, 8)
    v = AsStacked("vector", stack_ndim=2)
    x = v.to_compressible([leaf])
    assert x.shape == (6, 8)
    s = ConstraintL0Pruning(2)
    theta = jax.vmap(lambda xi: s.init(xi))(x)
    # per-item support: exactly κ survivors in every (layer, expert) row
    nnz = np.asarray(jnp.sum(theta["theta"] != 0, axis=1))
    assert (nnz == 2).all()
    (back,) = v.from_compressible(x, [leaf])
    np.testing.assert_array_equal(np.asarray(back), np.asarray(leaf))
