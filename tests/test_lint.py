"""The linter linted: every rule must trip on its deliberately-broken
fixture — and only that rule — with a message that says what to do.

Layer 1 fixtures are source strings written to tmp_path (the AST pass
never imports); layer 2/3 fixtures are real classes/registries passed
explicitly. The seeded-regression checks from the issue are mirrored
here: re-introducing the PR-5 ``float(θ["rank"])`` bug and a
bare-GSPMD LAPACK custom-call must both fail the CLI, naming the
file and rule.
"""
import json
import re

import jax.numpy as jnp
import pytest

from repro.analysis.lint import Baseline, Finding, Report
from repro.analysis.lint.ast_rules import lint_file, lint_paths
from repro.analysis.lint.cli import main as lint_main, repo_root
from repro.analysis.lint.contract import check_schemes
from repro.analysis.lint.hlo_rules import (check_scheme_lowerings,
                                           check_solvers)
from repro.core.schemes.base import CompressionScheme
from repro.core.schemes.lowrank import LowRank
from repro.core.schemes.prune import ConstraintL0Pruning


# ----------------------------------------------------------------------
# Layer 1: AST fixtures
# ----------------------------------------------------------------------
def _lint_source(tmp_path, source: str):
    f = tmp_path / "fixture.py"
    f.write_text(source)
    return lint_file(str(f), str(tmp_path))


def _rules(findings):
    return sorted({f.rule for f in findings})


SCHEME_HEADER = """\
import numpy as np
import jax
import jax.numpy as jnp
from repro.core.schemes.base import CompressionScheme
"""


def test_traced_cast_fixture_trips_exactly_that_rule(tmp_path):
    findings = _lint_source(tmp_path, SCHEME_HEADER + """
class F(CompressionScheme):
    def compress(self, w, theta, mu=None):
        r = float(theta["rank"])
        return {"theta": w * r}
""")
    assert _rules(findings) == ["traced-cast"]
    (f,) = findings
    assert f.context == "F.compress"
    assert "ConcretizationTypeError" in f.message
    assert "jnp scalar" in f.message  # actionable: what to do instead


def test_np_in_jit_fixture(tmp_path):
    findings = _lint_source(tmp_path, SCHEME_HEADER + """
@jax.jit
def step(x):
    return np.mean(x) + 1.0
""")
    assert _rules(findings) == ["np-in-jit"]
    assert "jnp equivalent" in findings[0].message


def test_shape_derived_key_fixture(tmp_path):
    findings = _lint_source(tmp_path, SCHEME_HEADER + """
class F(CompressionScheme):
    def compress(self, w, theta, mu=None):
        m, n = w.shape
        key = jax.random.PRNGKey(m * 7919 + n)
        return {"theta": w + jax.random.normal(key, w.shape)}
""")
    assert _rules(findings) == ["shape-derived-key"]
    assert "item_keys" in findings[0].message


def test_mutable_default_fixture(tmp_path):
    findings = _lint_source(tmp_path, SCHEME_HEADER + """
class F(CompressionScheme):
    cache = {}
""")
    assert _rules(findings) == ["mutable-default"]
    assert "default_factory" in findings[0].message


def test_guard_bypass_fixture(tmp_path):
    findings = _lint_source(tmp_path, SCHEME_HEADER + """
class F(CompressionScheme):
    solver = "topk_mask"

    def compress(self, w, theta, mu=None):
        return {"theta": w}

    def kernel_dispatch_ready(self):
        return True
""")
    assert _rules(findings) == ["guard-bypass"]
    assert "compress_batched" in findings[0].message


def test_static_shape_accesses_are_exempt(tmp_path):
    # the PR-5 *fix* shape: float() over .shape-derived values is fine
    findings = _lint_source(tmp_path, SCHEME_HEADER + """
class F(CompressionScheme):
    def bits(self, theta, float_bits: int = 32):
        m = theta["u"].shape[0]
        n = theta["v"].shape[0]
        return theta["rank"] * float((m + n) * float_bits)

@jax.jit
def g(x):
    return float(x.shape[0]) + int(x.ndim) + jnp.sum(x)
""")
    assert findings == []


def test_inline_suppression_comment(tmp_path):
    findings = _lint_source(tmp_path, SCHEME_HEADER + """
class F(CompressionScheme):
    def compress(self, w, theta, mu=None):
        r = float(theta["r"])  # lint: disable=traced-cast
        s = float(theta["s"])  # lint: disable=np-in-jit (wrong rule)
        return {"theta": w * r * s}
""")
    # the matching disable silences line 1; the wrong-rule one does not
    assert _rules(findings) == ["traced-cast"]
    assert len(findings) == 1


def test_lint_paths_walks_directories(tmp_path):
    (tmp_path / "pkg").mkdir()
    (tmp_path / "pkg" / "a.py").write_text(
        SCHEME_HEADER + "class A(CompressionScheme):\n    cache = []\n")
    (tmp_path / "pkg" / "b.py").write_text("x = 1\n")
    findings = lint_paths([str(tmp_path / "pkg")], str(tmp_path))
    assert [f.rule for f in findings] == ["mutable-default"]
    assert findings[0].file == "pkg/a.py"


# ----------------------------------------------------------------------
# Layer 2: contract fixtures (explicit classes/registry)
# ----------------------------------------------------------------------
def test_pallas_without_interpret_registration(tmp_path):
    registry = {"mysolver": {"jnp": lambda w, kappa: w,
                             "pallas": lambda w, kappa: w}}
    findings = check_schemes(classes=[], registry=registry)
    assert _rules(findings) == ["pallas-no-interpret"]
    assert findings[0].context == "mysolver"
    assert "interpret=True" in findings[0].message


def test_unregistered_solver():
    class Ghost(ConstraintL0Pruning):
        solver = "no_such_solver"

        def compress_batched(self, solve, w, theta, operands, mu=None):
            return {"theta": w}

        @classmethod
        def contract_examples(cls):
            return (cls(kappa=2),)

    findings = check_schemes(classes=[Ghost], registry={})
    assert _rules(findings) == ["unregistered-solver"]
    assert "no_such_solver" in findings[0].message


def test_operand_name_mismatch():
    class WrongName(ConstraintL0Pruning):
        solver = "topk_mask"
        solver_operands = ("k_items",)  # solver's param is "kappa"

        @classmethod
        def contract_examples(cls):
            return (cls(kappa=2),)

    findings = check_schemes(classes=[WrongName])
    assert _rules(findings) == ["operand-mismatch"]
    assert "k_items" in findings[0].message


def test_operand_count_mismatch():
    class TooMany(ConstraintL0Pruning):
        solver = "topk_mask"
        solver_operands = ("kappa", "iters")  # batch_operands yields 1

        @classmethod
        def contract_examples(cls):
            return (cls(kappa=2),)

    findings = check_schemes(classes=[TooMany])
    assert _rules(findings) == ["operand-mismatch"]


def test_solver_without_compress_batched():
    class Declared(CompressionScheme):
        solver = "topk_mask"

        def group_key(self):
            return ("declared",)

        @classmethod
        def contract_examples(cls):
            return (cls(),)

    findings = check_schemes(classes=[Declared])
    assert _rules(findings) == ["solver-no-compress-batched"]


def test_solver_with_group_key_none():
    class Exotic(CompressionScheme):
        solver = "topk_mask"

        def compress_batched(self, solve, w, theta, operands, mu=None):
            return theta

        @classmethod
        def contract_examples(cls):
            return (cls(),)

    findings = check_schemes(classes=[Exotic])
    assert _rules(findings) == ["solver-without-group-key"]


def test_init_only_hyperparam_without_init_key():
    class DPStart(ConstraintL0Pruning):
        def __init__(self, kappa, warm_bins=64):
            super().__init__(kappa)
            self.warm_bins = warm_bins

        def init(self, w, key=None):
            b = self.warm_bins  # init-only hyperparameter
            return {"theta": w * 0.0 + b * 0}

        @classmethod
        def contract_examples(cls):
            return (cls(kappa=2),)

    findings = check_schemes(classes=[DPStart])
    assert _rules(findings) == ["init-key-missing"]
    assert "warm_bins" in findings[0].message


def test_current_tree_contract_is_clean():
    assert check_schemes() == []


# ----------------------------------------------------------------------
# Layer 3: lowered-HLO fixtures
# ----------------------------------------------------------------------
class BadGspmdLowRank(LowRank):
    """Claims gspmd_safe but its batched solve calls the LAPACK SVD —
    the exact PR-2 miscompile shape."""

    def compress_batched(self, solve, w, theta, operands, mu=None):
        u, s, vt = jnp.linalg.svd(w, full_matrices=False)
        r = theta["u"].shape[-1]
        rs = jnp.sqrt(s[..., :r])
        return {"u": u[..., :, :r] * rs[..., None, :],
                "v": jnp.swapaxes(vt, -1, -2)[..., :, :r]
                * rs[..., None, :]}

    @classmethod
    def contract_examples(cls):
        return (cls(target_rank=2),)


def test_gspmd_safe_claim_with_lapack_custom_call():
    findings = check_scheme_lowerings(classes=[BadGspmdLowRank])
    assert _rules(findings) == ["gspmd-unsafe-custom-call"]
    (f,) = findings
    assert "lapack" in f.message.lower()
    assert "shard_map" in f.message  # actionable remediation


class ShapeChangingScheme(ConstraintL0Pruning):
    """Consumes the donated Θ but returns a different-shaped Θ, so the
    donation can never alias."""

    def compress_batched(self, solve, w, theta, operands, mu=None):
        half = theta["theta"][..., : theta["theta"].shape[-1] // 2]
        return {"theta": half * 2.0}

    @classmethod
    def contract_examples(cls):
        return (cls(kappa=2),)


def test_donation_violation_detected():
    findings = check_scheme_lowerings(classes=[ShapeChangingScheme])
    assert "donation-unaliased" in _rules(findings)
    f = next(f for f in findings if f.rule == "donation-unaliased")
    assert "2× Θ memory" in f.message or "shapes" in f.message


def test_current_solver_registry_lowers_clean():
    assert check_solvers() == []


# ----------------------------------------------------------------------
# Seeded regressions through the CLI (issue acceptance criteria)
# ----------------------------------------------------------------------
def test_seeded_pr5_float_rank_bug_fails_cli(tmp_path, capsys):
    src = (repo_root() + "/src/repro/core/schemes/lowrank.py")
    bugged = re.sub(
        r'return theta\["rank"\] \*',
        'return float(theta["rank"]) *',
        open(src).read())
    assert 'float(theta["rank"])' in bugged  # the seed applied
    bad = tmp_path / "lowrank_bugged.py"
    bad.write_text(bugged)

    rc = lint_main([str(bad), "--layers", "ast",
                    "--baseline", str(tmp_path / "empty.json")])
    out = capsys.readouterr().out
    assert rc == 1
    assert "[traced-cast]" in out
    assert "lowrank_bugged.py" in out


def test_clean_tree_passes_cli_ast_contract(capsys):
    rc = lint_main(["--layers", "ast,contract"])
    assert rc == 0
    assert "lint: clean" in capsys.readouterr().out


# ----------------------------------------------------------------------
# Baseline / report plumbing
# ----------------------------------------------------------------------
def test_baseline_suppresses_by_rule_file_context(tmp_path):
    f1 = Finding("traced-cast", "a.py", "F.compress", "msg", 10)
    f2 = Finding("np-in-jit", "a.py", "F.compress", "msg", 11)
    Baseline.write(str(tmp_path / "b.json"), [f1])
    report = Report(findings=[f1, f2])
    report.apply_baseline(Baseline.load(str(tmp_path / "b.json")))
    # line-insensitive identity: same (rule, file, context) suppresses
    assert [f.rule for f in report.findings] == ["np-in-jit"]
    assert [f.rule for f in report.suppressed] == ["traced-cast"]


def test_json_report_shape(tmp_path, capsys):
    out = tmp_path / "report.json"
    rc = lint_main(["--layers", "ast", "--json", str(out)])
    assert rc == 0
    data = json.loads(out.read_text())
    assert data["version"] == 1
    assert data["layers"] == ["ast"]
    assert data["counts"] == {"new": 0, "suppressed": 0}


def test_committed_baseline_has_zero_suppressions():
    data = json.loads(
        open(repo_root() + "/lint_baseline.json").read())
    assert data["suppressions"] == []


def test_unknown_layer_rejected():
    with pytest.raises(SystemExit):
        lint_main(["--layers", "nope"])
