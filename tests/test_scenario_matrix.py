"""Scenario-matrix tests.

Two tiers in one file:

* ``@pytest.mark.matrix`` (opt-in, tier-2): one test per (arch, family)
  cell, running literally the same ``run_cell`` the bench artifact is
  built from — ``pytest -m matrix`` and ``benchmarks.run --only matrix``
  cannot drift apart.
* unmarked (tier-1, fast): the task-derivation rules from shapes only
  (no training), and the monitor plumbing — a deliberately-broken
  scheme must make the cell runner fail loudly, so the §7 assertions
  can't silently rot into no-ops.
"""
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

# benchmarks/ is a plain directory under the repo root (no package
# install); `python -m pytest` from the root puts it on sys.path, a bare
# `pytest` binary does not — make both work.
ROOT = Path(__file__).resolve().parent.parent
if str(ROOT) not in sys.path:
    sys.path.insert(0, str(ROOT))

from benchmarks.matrix_common import (  # noqa: E402
    FAMILIES, MonitorViolation, build_tasks, enumerate_cells, leaf_plan,
    run_cell, run_lc_cell)
from repro.configs import ARCHS, get_config, reduced_config  # noqa: E402
from repro.core.schemes.base import CompressionScheme  # noqa: E402
from repro.core.tasks import CompressionTask, check_disjoint  # noqa: E402
from repro.core.views import AsStacked, AsVector  # noqa: E402


# ----------------------------------------------------------------------
# Tier-2: the matrix itself (opt-in marker)
# ----------------------------------------------------------------------
@pytest.mark.matrix
@pytest.mark.parametrize("arch,family", enumerate_cells())
def test_matrix_cell(arch, family):
    row = run_cell(arch, family)
    if row["status"] == "skipped":
        pytest.skip(row["reason"])
    assert row["status"] == "ok"
    assert row["compression_ratio"] > 1.0
    assert row["ce_final"] < row["ce_init"]


# ----------------------------------------------------------------------
# Tier-1: task-derivation rules (shapes only, no training)
# ----------------------------------------------------------------------
def _shape_params(cfg):
    import jax
    from repro.models import init_params
    return jax.eval_shape(lambda: init_params(jax.random.PRNGKey(0), cfg))


@pytest.mark.parametrize("arch", ARCHS)
def test_derived_tasks_cover_every_family(arch):
    """Each family derives ≥1 task; resolved tasks are disjoint (no
    leaf claimed twice) and every pattern matches exactly one leaf."""
    cfg = reduced_config(get_config(arch))
    shapes = _shape_params(cfg)
    for family in FAMILIES:
        tasks = build_tasks(cfg, family)
        assert tasks, f"{arch}/{family}: no tasks derived"
        resolved = [t.resolve(shapes) for t in tasks]
        check_disjoint(resolved)  # raises on overlap
        assert all(len(t.paths) == 1 for t in resolved)


def test_ssm_thin_leaves_never_matrix_eligible():
    """Jamba's mamba conv kernels / gate stacks are thin 2-D items —
    they must classify as vector-only, so LowRank/AsMatrix never sees a
    non-matrix SSM leaf (the crash class this matrix exists to catch)."""
    cfg = reduced_config(get_config("jamba-v0.1-52b"))
    plan = {i.path: i for i in leaf_plan(cfg)}
    conv = [i for p, i in plan.items() if p.endswith("conv_w")]
    assert conv, "expected mamba conv kernels in the param tree"
    assert all(i.kind == "vector" for i in conv)
    # and every low-rank task's item really is a fat-enough matrix
    import re
    by_pattern = {"^" + re.escape(i.path) + "$": i
                  for i in leaf_plan(cfg)}
    lowrank = build_tasks(cfg, "lowrank")
    assert not any("conv_w" in t.pattern for t in lowrank)
    from benchmarks.matrix_common import MATRIX_MIN_DIM
    for t in lowrank:
        info = by_pattern[t.pattern]
        assert info.kind == "matrix"
        assert len(info.item_shape) == 2
        assert min(info.item_shape) >= MATRIX_MIN_DIM


def test_moe_expert_leaves_get_per_expert_views():
    """Scanned MoE weights (L, E, m, n) must compress per expert: the
    derived view stacks BOTH leading axes (stack_ndim=2)."""
    cfg = reduced_config(get_config("mixtral-8x7b"))
    tasks = build_tasks(cfg, "lowrank")
    expert = [t for t in tasks if "w_up" in t.pattern]
    assert expert, "expected expert w_up tasks"
    for t in expert:
        assert isinstance(t.view, AsStacked)
        assert t.view.stack_ndim == 2


def test_tied_embeddings_counted_once():
    """gemma3 ties embeddings: one tokens leaf, claimed by exactly one
    task — no double-counting in compression_ratio by construction."""
    cfg = reduced_config(get_config("gemma3-27b"))
    assert cfg.tie_embeddings
    tasks = build_tasks(cfg, "quantize")
    embed_tasks = [t for t in tasks if "embed" in t.pattern]
    assert len(embed_tasks) == 1
    resolved = [t.resolve(_shape_params(cfg)) for t in tasks]
    check_disjoint(resolved)
    embed_paths = [p for t in resolved for p in t.paths
                   if p.startswith("embed/")]
    assert embed_paths == ["embed/tokens"]


def test_unsupported_cells_surface_as_skips(monkeypatch):
    """A cell in UNSUPPORTED must come back as an explicit skip row with
    the reason string — never silently dropped."""
    import benchmarks.matrix_common as mc
    monkeypatch.setitem(mc.UNSUPPORTED,
                        ("phi3-mini-3.8b", "prune"), "test reason")
    row = run_cell("phi3-mini-3.8b", "prune")
    assert row["status"] == "skipped"
    assert row["reason"] == "test reason"
    assert "SKIP" in row["derived"]


# ----------------------------------------------------------------------
# Tier-1: monitor plumbing must fail loudly
# ----------------------------------------------------------------------
def _tiny_cfg():
    """Smallest config that runs the full trainer path: one unrolled
    transformer block."""
    cfg = reduced_config(get_config("phi3-mini-3.8b"))
    return cfg.with_(pattern_reps=1)


class _WorseningScheme(CompressionScheme):
    """Deliberately broken: the 'projection' overshoots to 3w, so the
    C step INCREASES its own objective ‖(w−λ/μ)−Δ(Θ)‖² — exactly what
    the §7 shifted-distortion monitor exists to catch."""

    domain = "vector"

    def group_key(self):
        return None  # exotic scheme: per-task path

    def init(self, w, key=None):
        return {"theta": w}

    def compress(self, w, theta, mu=None):
        return {"theta": 3.0 * w}

    def decompress(self, theta):
        return theta["theta"]

    def bits(self, theta, float_bits: int = 32):
        return theta["theta"].size  # 1 bit/weight: ratio monitor green


class _BloatedScheme(CompressionScheme):
    """Valid projection (identity ⇒ distortion 0, never increases) whose
    storage accounting is worse than dense — must trip ONLY the
    compression_ratio monitor."""

    domain = "vector"

    def group_key(self):
        return None

    def init(self, w, key=None):
        return {"theta": w}

    def compress(self, w, theta, mu=None):
        return {"theta": w}

    def decompress(self, theta):
        return theta["theta"]

    def bits(self, theta, float_bits: int = 32):
        return theta["theta"].size * 64 * float_bits


def _one_task(scheme):
    return [CompressionTask("broken", r"^embed/tokens$", AsVector(),
                            scheme)]


def test_broken_scheme_fails_loudly():
    with pytest.raises(MonitorViolation) as ei:
        run_lc_cell(_tiny_cfg(), _one_task(_WorseningScheme()),
                    cell="plumbing/worsen", steps_per_l=2)
    assert any("c_step_shifted_distortion" in v
               for v in ei.value.violations)


def test_ratio_monitor_fails_loudly():
    with pytest.raises(MonitorViolation) as ei:
        run_lc_cell(_tiny_cfg(), _one_task(_BloatedScheme()),
                    cell="plumbing/bloat", steps_per_l=2)
    assert any("compression_ratio" in v for v in ei.value.violations)
    # the projection itself is sound: distortion monitor stays green
    assert not any("shifted_distortion" in v
                   for v in ei.value.violations)


# ----------------------------------------------------------------------
# Tier-1: AsStacked stack_ndim regression (per-expert views)
# ----------------------------------------------------------------------
def test_asstacked_multi_axis_roundtrip():
    leaf = jnp.arange(2 * 3 * 4 * 5, dtype=jnp.float32).reshape(2, 3, 4, 5)
    for domain, item_shape in (("vector", (20,)), ("matrix", (4, 5))):
        v = AsStacked(domain, stack_ndim=2)
        x = v.to_compressible([leaf])
        assert x.shape == (6,) + item_shape
        assert v.item_count(x) == 6 and v.item_shape(x) == item_shape
        (back,) = v.from_compressible(x, [leaf])
        np.testing.assert_array_equal(np.asarray(back), np.asarray(leaf))


def test_asstacked_default_unchanged():
    leaf = jnp.ones((3, 4, 5))
    v = AsStacked("matrix")
    assert v.stack_ndim == 1
    assert v.to_compressible([leaf]).shape == (3, 4, 5)
    v2 = AsStacked("vector")
    assert v2.to_compressible([leaf]).shape == (3, 20)
