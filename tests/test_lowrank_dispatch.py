"""Batched low-rank C-step engine + the matmul-only dispatch solvers.

Contract under test (docs/architecture.md "The batched low-rank
solver"):

* ``lowrank_rsvd``/``rank_select`` solve a packed (items, m, n) group
  with matmuls + the Jacobi finisher only — no LAPACK custom call — so
  the group shards under plain GSPMD (``shard_mode == "gspmd"``, no
  shard_map workaround);
* rank and α are traced per-item operands: mixed-rank LowRank tasks and
  mixed-α RankSelection tasks pack into ONE group, factors padded to
  the group R_max (``pack_thetas_padded``) and sliced back per task;
* per-item sketch keys come from ``CompressionTask.item_keys`` —
  identical on the grouped and per-task paths, distinct per item,
  stable across reruns;
* the batched ℓ1 solvers (``project_l1_ball``, ``soft_threshold``) and
  mixed-K k-means (padded codebooks + per-item valid counts) are
  bit-identical to the legacy per-value paths on the jnp backend.
"""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import AsIs, AsStacked, AsVector, CompressionTask, \
    LCAlgorithm
from repro.core.grouping import solve_task
from repro.core.schemes import (
    AdaptiveQuantization, ConstraintL1Pruning, LowRank, PenaltyL1Pruning,
    RankSelection, project_l1_ball)
from repro.kernels import dispatch
from repro.kernels.lowrank import lowrank as lk
from repro.kernels.lowrank import ops as lops
from repro.kernels.lowrank import ref as lref
from repro.kernels.prune import ops as pops

KEY = jax.random.PRNGKey(0)
REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _decaying_stack(n_items, m, n, base=0.85, floor=3e-2, seed=7):
    """Random matrices with a controlled decaying spectrum — the regime
    randomized SVD is built for (and the bench suite uses)."""
    ks = jax.random.split(jax.random.fold_in(KEY, seed), 2)
    u, _ = jnp.linalg.qr(jax.random.normal(ks[0], (n_items, m, m)))
    v, _ = jnp.linalg.qr(jax.random.normal(ks[1], (n_items, n, n)))
    k = min(m, n)
    sig = base ** jnp.arange(k, dtype=jnp.float32) + floor
    return jnp.einsum("imk,k,ink->imn", u[:, :, :k], sig, v[:, :, :k])


def _item_keys(n, seed=3):
    base = jax.random.fold_in(KEY, seed)
    return jax.vmap(lambda j: jax.random.fold_in(base, j))(jnp.arange(n))


# ----------------------------------------------------------------------
# registry
# ----------------------------------------------------------------------
def test_registry_has_matmul_only_solvers():
    table = dispatch.solver_table()
    for name in ("lowrank_rsvd", "rank_select", "project_l1_ball",
                 "soft_threshold"):
        assert table[name] == ("jnp",), (name, table.get(name))


def test_backend_gap_serves_interpret_requests_with_jnp():
    """jnp-only solvers have no kernel to emulate: an interpret/pallas
    request resolves to the same batched jnp program (honest gap rule),
    never to the vmap fallback."""
    for req in ("interpret", "pallas", "jnp", "auto"):
        fn, backend = dispatch.lookup("lowrank_rsvd", req)
        assert fn is lops.lowrank_rsvd_batched and backend == "jnp", req


# ----------------------------------------------------------------------
# Jacobi finisher (the matmul-only eigh)
# ----------------------------------------------------------------------
def test_jacobi_eigh_matches_lapack():
    a = jax.random.normal(jax.random.fold_in(KEY, 1), (3, 18, 30))
    g = jnp.einsum("ikn,iln->ikl", a, a)
    lam, v = lk.jacobi_eigh_batched(g, sweeps=10)
    lam_ref = np.sort(np.linalg.eigvalsh(np.asarray(g)),
                      axis=-1)[:, ::-1]
    scale = lam_ref.max()
    np.testing.assert_allclose(np.asarray(lam), lam_ref,
                               atol=1e-4 * scale)
    # eigenvector quality: V diag(λ) Vᵀ reconstructs G
    rec = jnp.einsum("ikl,il,iml->ikm", v, lam, v)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(g),
                               atol=1e-4 * scale)


def test_jacobi_eigh_zero_matrix_and_odd_k_are_safe():
    lam, v = lk.jacobi_eigh_batched(jnp.zeros((2, 7, 7)), sweeps=4)
    assert not bool(jnp.any(jnp.isnan(lam)))
    assert not bool(jnp.any(jnp.isnan(v)))
    np.testing.assert_array_equal(np.asarray(lam), 0.0)


def test_newton_schulz_orthonormalizes():
    """The alternative (orth="newton_schulz") range-finder
    orthogonalization: QᵀQ ≈ I, zero items stay zero, and the rsvd
    driver reaches the same reconstruction quality ballpark."""
    y = jax.random.normal(jax.random.fold_in(KEY, 5), (3, 120, 24))
    q = lk.newton_schulz_orthonormalize(y)
    g = jnp.einsum("imk,iml->ikl", q, q)
    assert float(jnp.max(jnp.abs(g - jnp.eye(24)))) < 1e-4
    qz = lk.newton_schulz_orthonormalize(jnp.zeros((2, 16, 4)))
    assert not bool(jnp.any(jnp.isnan(qz)))
    assert float(jnp.sum(qz ** 2)) == 0.0

    w = _decaying_stack(3, 96, 72, seed=19)
    rank = jnp.array([4, 8, 16], jnp.int32)
    u, v = lops.lowrank_rsvd_batched(w, rank, _item_keys(3), r_max=16,
                                     orth="newton_schulz")
    d = jnp.sum((w - jnp.einsum("imk,ink->imn", u, v)) ** 2,
                axis=(1, 2))
    d_exact = lref.tail_distortion_ref(w, rank)
    rel = (np.asarray(d) - np.asarray(d_exact)) / np.asarray(d_exact)
    assert np.all(rel <= 1e-3), rel      # documented: looser than jacobi


# ----------------------------------------------------------------------
# batched rsvd vs the exact-SVD oracle
# ----------------------------------------------------------------------
def test_rsvd_batched_distortion_within_1e4_of_exact():
    w = _decaying_stack(4, 96, 72)
    rank = jnp.array([4, 8, 12, 16], jnp.int32)
    u, v = lops.lowrank_rsvd_batched(w, rank, _item_keys(4), r_max=16)
    d = jnp.sum((w - jnp.einsum("imk,ink->imn", u, v)) ** 2,
                axis=(1, 2))
    d_exact = lref.tail_distortion_ref(w, rank)
    rel = (np.asarray(d) - np.asarray(d_exact)) / np.asarray(d_exact)
    assert np.all(rel <= 1e-4), rel
    # factors are masked: columns at/after each item's rank are zero
    mask = np.arange(16)[None, :] >= np.asarray(rank)[:, None]
    assert float(jnp.sum(jnp.abs(u) * mask[:, None, :])) == 0.0


def test_rsvd_batched_recovers_exactly_lowrank_matrices():
    ks = jax.random.split(KEY, 2)
    a = jax.random.normal(ks[0], (3, 64, 6))
    b = jax.random.normal(ks[1], (3, 6, 48))
    w = a @ b
    rank = jnp.array([6, 8, 12], jnp.int32)
    u, v = lops.lowrank_rsvd_batched(w, rank, _item_keys(3), r_max=12)
    rec = jnp.einsum("imk,ink->imn", u, v)
    np.testing.assert_allclose(np.asarray(rec), np.asarray(w),
                               atol=2e-3)


def test_rsvd_batched_zero_item_yields_zero_factors():
    w = _decaying_stack(3, 40, 30).at[1].set(0.0)
    u, v = lops.lowrank_rsvd_batched(w, jnp.array([4, 4, 4]),
                                     _item_keys(3), r_max=4)
    assert not bool(jnp.any(jnp.isnan(u))) and \
        not bool(jnp.any(jnp.isnan(v)))
    assert float(jnp.sum(u[1] ** 2) + jnp.sum(v[1] ** 2)) == 0.0


# ----------------------------------------------------------------------
# mixed-rank LowRank groups through the full C step
# ----------------------------------------------------------------------
def _lowrank_setup(ranks=(4, 8, 12, 16), m=96, n=72):
    w = _decaying_stack(len(ranks), m, n, seed=11)
    params = {f"l{i}": w[i] for i in range(len(ranks))}
    tasks = lambda: [CompressionTask(f"lr{i}", f"^l{i}$", AsIs(),
                                     LowRank(r))
                     for i, r in enumerate(ranks)]
    return params, tasks


def test_mixed_rank_tasks_pack_into_one_group():
    """rank ∈ {4,8,12,16} → four groups without dispatch (rank is in
    group_key), ONE group with it (rank rides as a per-item operand,
    factors pad to R_max=16 and slice back per task)."""
    params, tasks = _lowrank_setup()
    lc_off = LCAlgorithm(tasks(), [1e-2], cstep_backend="off")
    lc_on = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp")
    assert len(lc_off.group_summary(params)) == 4
    (g,) = lc_on.group_summary(params)
    assert g["grouped"] and g["solver"] == "lowrank_rsvd"
    assert g["backend"] == "jnp" and g["items"] == 4

    st = lc_on.c_step(params, lc_on.init(params))
    for i, r in enumerate((4, 8, 12, 16)):
        th = st["tasks"][f"lr{i}"]["theta"]
        # Θ keeps each task's own shapes (padding sliced back off)
        assert th["u"].shape == (96, r) and th["v"].shape == (72, r)
        d = float(jnp.sum((params[f"l{i}"] - th["u"] @ th["v"].T) ** 2))
        d_exact = float(lref.tail_distortion_ref(
            params[f"l{i}"][None], jnp.array([r]))[0])
        assert d <= d_exact * (1 + 1e-4), (i, d, d_exact)


def test_lowrank_grouped_matches_pertask_dispatch():
    """Uniform-rank tasks: the grouped launch and the per-task solver
    path see the same R_max and the same per-item keys, so the factors
    agree to float tolerance (batched-vs-single matmul ordering)."""
    params, _ = _lowrank_setup(ranks=(8, 8, 8), m=64, n=48)
    tasks = lambda: [CompressionTask(f"lr{i}", f"^l{i}$", AsIs(),
                                     LowRank(8)) for i in range(3)]
    lcg = LCAlgorithm(tasks(), [1e-2], group_tasks=True,
                      cstep_backend="jnp")
    lcp = LCAlgorithm(tasks(), [1e-2], group_tasks=False,
                      cstep_backend="jnp")
    sg = lcg.c_step(params, lcg.init(params))
    sp = lcp.c_step(params, lcp.init(params))
    for i in range(3):
        np.testing.assert_allclose(
            np.asarray(sg["tasks"][f"lr{i}"]["theta"]["u"]),
            np.asarray(sp["tasks"][f"lr{i}"]["theta"]["u"]),
            atol=2e-5, err_msg=f"lr{i}")


def test_lowrank_randomized_false_keeps_exact_path():
    params, _ = _lowrank_setup(ranks=(4, 8), m=32, n=24)
    tasks = [CompressionTask(f"lr{i}", f"^l{i}$", AsIs(),
                             LowRank(4 * (i + 1), randomized=False))
             for i in range(2)]
    lc = LCAlgorithm(tasks, [1e-2], cstep_backend="jnp")
    summary = lc.group_summary(params)
    assert len(summary) == 2                 # rank stays in the identity
    assert all(g["solver"] is None for g in summary)


# ----------------------------------------------------------------------
# sketch keys: deterministic, per-item, path-stable
# ----------------------------------------------------------------------
def test_item_keys_distinct_and_deterministic():
    t1 = CompressionTask("a", "^a$", AsIs(), LowRank(4))
    t2 = CompressionTask("b", "^b$", AsIs(), LowRank(4))
    k1, k2 = t1.item_keys(3), t2.item_keys(3)
    # distinct across tasks and across items within a task
    seen = {tuple(np.asarray(k)) for k in list(k1) + list(k2)}
    assert len(seen) == 6
    # stable across calls (reruns are reproducible)
    np.testing.assert_array_equal(np.asarray(k1),
                                  np.asarray(t1.item_keys(3)))


def test_lowrank_cstep_rerun_is_bit_identical():
    """The sketch is keyed, not clocked: re-running the same C step on
    the same inputs reproduces Θ bit-for-bit."""
    params, tasks = _lowrank_setup()
    lc = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp")
    st = lc.init(params)
    s1 = lc.c_step(params, st)
    s2 = lc.c_step(params, st)
    for name in s1["tasks"]:
        np.testing.assert_array_equal(
            np.asarray(s1["tasks"][name]["theta"]["u"]),
            np.asarray(s2["tasks"][name]["theta"]["u"]), err_msg=name)


# ----------------------------------------------------------------------
# rank selection: mixed α, one group, identical ranks
# ----------------------------------------------------------------------
def test_rank_select_mixed_alpha_one_group_identical_ranks():
    w = _decaying_stack(4, 80, 60, seed=13)
    params = {f"l{i}": w[i] for i in range(4)}
    alphas = (1e-4, 3e-4, 1e-3, 3e-3)

    def tasks():
        return [CompressionTask(f"rs{i}", f"^l{i}$", AsIs(),
                                RankSelection(alpha=a, max_rank=24))
                for i, a in enumerate(alphas)]

    lc_on = LCAlgorithm(tasks(), [1.0], cstep_backend="jnp")
    lc_off = LCAlgorithm(tasks(), [1.0], cstep_backend="off")
    (g,) = lc_on.group_summary(params)
    assert g["solver"] == "rank_select" and g["items"] == 4
    assert len(lc_off.group_summary(params)) == 4   # α splits legacy

    s_on = lc_on.c_step(params, lc_on.init(params))
    s_off = lc_off.c_step(params, lc_off.init(params))
    for i in range(4):
        r_on = int(s_on["tasks"][f"rs{i}"]["theta"]["rank"])
        r_off = int(s_off["tasks"][f"rs{i}"]["theta"]["rank"])
        assert r_on == r_off, (i, r_on, r_off)
        # ‖W − ΔΘ‖ parity at the (identical) selected rank
        d_on = float(jnp.sum((params[f"l{i}"]
                              - lc_on.tasks[i].scheme_decompress(
                                  s_on["tasks"][f"rs{i}"]["theta"])) ** 2))
        d_off = float(jnp.sum((params[f"l{i}"]
                               - lc_off.tasks[i].scheme_decompress(
                                   s_off["tasks"][f"rs{i}"]["theta"])) ** 2))
        assert d_on <= d_off * (1 + 1e-4) + 1e-6, (i, d_on, d_off)


def test_rank_select_zero_item_selects_rank_zero():
    """A zero matrix in a stacked rank-selection task must come back
    rank 0 with zero factors — and no NaNs anywhere (the mesh-padding
    lanes hit the same code path)."""
    w = jnp.stack([_decaying_stack(1, 32, 24, seed=17)[0],
                   jnp.zeros((32, 24))])
    params = {"w": w}
    lc = LCAlgorithm(
        [CompressionTask("rs", "^w$", AsStacked("matrix"),
                         RankSelection(alpha=2e-3, max_rank=12))],
        [1.0], cstep_backend="jnp")
    st = lc.c_step(params, lc.init(params))
    th = st["tasks"]["rs"]["theta"]
    assert not bool(jnp.any(jnp.isnan(th["u"])))
    assert int(th["rank"][1]) == 0
    assert float(jnp.sum(th["u"][1] ** 2)) == 0.0
    assert int(th["rank"][0]) > 0


def test_rank_select_unbounded_keeps_exact_path():
    """max_rank=None needs the full spectrum — the batched sketch
    solver must not engage (describe_groups reports the vmap path)."""
    params = {"l0": jax.random.normal(KEY, (32, 24))}
    lc = LCAlgorithm(
        [CompressionTask("rs", "^l0$", AsIs(), RankSelection(alpha=1e-3))],
        [1.0], cstep_backend="jnp")
    (g,) = lc.group_summary(params)
    assert g["solver"] is None


def test_rank_selection_bits_flops_traced_safe():
    """Regression: bits()/flops() called float() on θ["rank"] — a
    traced device scalar inside jitted reporting paths — and crashed
    with a TracerConversionError. They must be jnp-traceable AND still
    agree with the host-side values."""
    s = RankSelection(alpha=1e-3, max_rank=12)
    w = jax.random.normal(KEY, (32, 24))
    th = s.compress(w, None, mu=1.0)

    @jax.jit
    def report(theta):
        return s.bits(theta), s.flops(theta, (32, 24))

    bits_t, flops_t = report(th)              # must not raise
    r = int(th["rank"])
    assert float(bits_t) == pytest.approx(
        r * (32 + 24) * 32 + np.ceil(np.log2(12 + 1)))
    assert float(flops_t) == pytest.approx(2.0 * r * (32 + 24))


# ----------------------------------------------------------------------
# batched ℓ1 solvers
# ----------------------------------------------------------------------
def test_project_l1_ball_batched_matches_pertask():
    w = jax.random.normal(jax.random.fold_in(KEY, 31), (4, 257))
    # row 3 is inside its ball → must pass through bit-identically
    radius = jnp.array([3.0, 10.0, 50.0, 1e6], jnp.float32)
    out = pops.project_l1_ball_batched(w, radius)
    for i in range(4):
        np.testing.assert_array_equal(
            np.asarray(out[i]),
            np.asarray(project_l1_ball(w[i], float(radius[i]))),
            err_msg=f"row {i}")
    np.testing.assert_array_equal(np.asarray(out[3]), np.asarray(w[3]))


def test_l1_constraint_mixed_radius_one_group_bitwise():
    params = {f"v{i}": jax.random.normal(jax.random.fold_in(KEY, 41 + i),
                                         (300,)) for i in range(3)}
    tasks = lambda: [CompressionTask(f"c{i}", f"^v{i}$", AsVector(),
                                     ConstraintL1Pruning(kappa=3.0 * (i + 1)))
                     for i in range(3)]
    lc_on = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp")
    lc_off = LCAlgorithm(tasks(), [1e-2], cstep_backend="off")
    assert len(lc_on.group_summary(params)) == 1
    assert lc_on.group_summary(params)[0]["solver"] == "project_l1_ball"
    assert len(lc_off.group_summary(params)) == 3
    s_on = lc_on.c_step(params, lc_on.init(params))
    s_off = lc_off.c_step(params, lc_off.init(params))
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(s_on["tasks"][f"c{i}"]["theta"]["theta"]),
            np.asarray(s_off["tasks"][f"c{i}"]["theta"]["theta"]),
            err_msg=f"c{i}")


def test_penalty_l1_mixed_alpha_one_group_bitwise():
    params = {f"v{i}": jax.random.normal(jax.random.fold_in(KEY, 51 + i),
                                         (256,)) for i in range(3)}
    tasks = lambda: [CompressionTask(f"p{i}", f"^v{i}$", AsVector(),
                                     PenaltyL1Pruning(alpha=0.02 * (i + 1)))
                     for i in range(3)]
    lc_on = LCAlgorithm(tasks(), [0.5], cstep_backend="jnp")
    lc_off = LCAlgorithm(tasks(), [0.5], cstep_backend="off")
    assert len(lc_on.group_summary(params)) == 1
    s_on = lc_on.c_step(params, lc_on.init(params))
    s_off = lc_off.c_step(params, lc_off.init(params))
    for i in range(3):
        np.testing.assert_array_equal(
            np.asarray(s_on["tasks"][f"p{i}"]["theta"]["theta"]),
            np.asarray(s_off["tasks"][f"p{i}"]["theta"]["theta"]),
            err_msg=f"p{i}")


# ----------------------------------------------------------------------
# mixed-K quantization groups (padded codebooks + valid counts)
# ----------------------------------------------------------------------
def _mixed_k_setup():
    params = {f"v{i}": jax.random.normal(jax.random.fold_in(KEY, 61 + i),
                                         (512,)) for i in range(3)}
    tasks = lambda: [CompressionTask(f"q{i}", f"^v{i}$", AsVector(),
                                     AdaptiveQuantization(k=2 ** (i + 1),
                                                          iters=8))
                     for i in range(3)]
    return params, tasks


def test_mixed_k_quant_one_group_bitwise_vs_off():
    """K ∈ {2,4,8} → one group under dispatch (padded codebooks,
    per-item valid counts); each task's codebook/assignments must be
    bit-identical to the per-value legacy path on the jnp backend —
    the masked (K_max + inf-padding) Lloyd loop IS the K_i loop."""
    params, tasks = _mixed_k_setup()
    lc_on = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp")
    lc_off = LCAlgorithm(tasks(), [1e-2], cstep_backend="off")
    assert len(lc_on.group_summary(params)) == 1
    assert len(lc_off.group_summary(params)) == 3
    s_on = lc_on.c_step(params, lc_on.init(params))
    s_off = lc_off.c_step(params, lc_off.init(params))
    for i in range(3):
        th_on = s_on["tasks"][f"q{i}"]["theta"]
        th_off = s_off["tasks"][f"q{i}"]["theta"]
        assert th_on.codebook.shape == (2 ** (i + 1),)  # sliced back
        np.testing.assert_array_equal(np.asarray(th_on.codebook),
                                      np.asarray(th_off.codebook),
                                      err_msg=f"q{i} codebook")
        np.testing.assert_array_equal(np.asarray(th_on.assign),
                                      np.asarray(th_off.assign),
                                      err_msg=f"q{i} assign")


def test_mixed_k_kmeans_interpret_kernel_masks_levels():
    """The items-grid kernel path must honor the per-item valid counts
    too: padded (+inf) levels never get assignments, and the live
    codebook entries agree with the jnp solve within the documented
    tolerance."""
    w = jax.random.normal(jax.random.fold_in(KEY, 71), (3, 4096))
    k_max = 8
    cb0 = jnp.sort(jax.random.normal(jax.random.fold_in(KEY, 72),
                                     (3, k_max)), axis=-1)
    kvalid = jnp.array([2, 4, 8], jnp.int32)
    from repro.kernels.kmeans import ops as kops
    cb_j, as_j = kops.kmeans_batched(w, cb0, kvalid, iters=6, impl="jnp")
    cb_k, as_k = kops.kmeans_batched(w, cb0, kvalid, iters=6,
                                     impl="interpret")
    for i, kv in enumerate((2, 4, 8)):
        assert int(jnp.max(as_j[i])) < kv
        assert int(jnp.max(as_k[i])) < kv
        np.testing.assert_allclose(np.asarray(cb_j[i, :kv]),
                                   np.asarray(cb_k[i, :kv]), atol=1e-3)
        assert bool(jnp.all(jnp.isinf(cb_j[i, kv:])))


# ----------------------------------------------------------------------
# mesh: low-rank groups shard under plain GSPMD (no shard_map
# workaround) — 1-device in-process, 4 real devices in a subprocess
# ----------------------------------------------------------------------
def test_lowrank_group_under_mesh_uses_gspmd_and_matches_no_mesh():
    from repro.launch.mesh import make_cstep_mesh
    params, tasks = _lowrank_setup()
    lc0 = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp")
    lcm = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp",
                      mesh=make_cstep_mesh())
    (g,) = lcm.group_summary(params)
    assert g["spec"] is not None
    assert g["shard_mode"] == "gspmd"        # workaround bypassed
    s0 = lc0.c_step(params, lc0.init(params))
    sm = lcm.c_step(params, lcm.init(params))
    for name in s0["tasks"]:
        np.testing.assert_allclose(
            np.asarray(s0["tasks"][name]["theta"]["u"]),
            np.asarray(sm["tasks"][name]["theta"]["u"]),
            atol=1e-5, err_msg=name)


def test_quant_group_under_mesh_still_reports_shard_map():
    """The honest counterpoint: kernel-dispatched schemes whose solver
    is NOT custom-call-free keep the shard_map workaround."""
    from repro.launch.mesh import make_cstep_mesh
    params, tasks = _mixed_k_setup()
    lcm = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp",
                      mesh=make_cstep_mesh())
    (g,) = lcm.group_summary(params)
    assert g["shard_mode"] == "shard_map"


def test_lowrank_gspmd_multidevice_subprocess():
    """A packed mixed-rank group on a real 4-device data mesh — sharded
    under plain GSPMD (incl. a padded 6→8 lane) — matches mesh=None."""
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import AsIs, CompressionTask, LCAlgorithm
from repro.core.schemes import LowRank
from jax.sharding import PartitionSpec as P

assert jax.device_count() == 4, jax.device_count()
KEY = jax.random.PRNGKey(0)
ks = jax.random.split(KEY, 2)
u, _ = jnp.linalg.qr(jax.random.normal(ks[0], (6, 48, 48)))
v, _ = jnp.linalg.qr(jax.random.normal(ks[1], (6, 36, 36)))
sig = 0.85 ** jnp.arange(36, dtype=jnp.float32) + 3e-2
w = jnp.einsum("imk,k,ink->imn", u[:, :, :36], sig, v)
params = {f"l{i}": w[i] for i in range(6)}
ranks = (2, 4, 6, 8, 10, 12)

def tasks():
    return [CompressionTask(f"lr{i}", f"^l{i}$", AsIs(), LowRank(r))
            for i, r in enumerate(ranks)]

mesh = jax.make_mesh((4,), ("data",))
lcm = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp", mesh=mesh)
lc0 = LCAlgorithm(tasks(), [1e-2], cstep_backend="jnp")
(g,) = lcm.group_summary(params)
assert g["spec"] == P("data"), g
assert g["padding"] == 2, g                  # 6 items -> 8 lanes
assert g["shard_mode"] == "gspmd", g
sm = lcm.c_step(params, lcm.init(params))
s0 = lc0.c_step(params, lc0.init(params))
for name in s0["tasks"]:
    np.testing.assert_allclose(
        np.asarray(sm["tasks"][name]["theta"]["u"]),
        np.asarray(s0["tasks"][name]["theta"]["u"]),
        atol=1e-5, err_msg=name)
print("OK")
"""
    env = dict(os.environ)
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "")
                        + " --xla_force_host_platform_device_count=4")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", script],
                         capture_output=True, text=True, env=env,
                         timeout=500)
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
