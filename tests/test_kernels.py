"""Pallas kernels vs pure-jnp oracles — interpret mode on CPU, with
shape/dtype sweeps per the kernel-testing contract."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.kmeans import ops as kops
from repro.kernels.kmeans import ref as kref
from repro.kernels.prune import ops as pops
from repro.kernels.prune import ref as pref
from repro.kernels.quant_matmul import ops as qops
from repro.kernels.quant_matmul import ref as qref

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# kmeans
# ----------------------------------------------------------------------
@pytest.mark.parametrize("p,k", [
    (8192, 2), (8192, 16), (5000, 8),      # padded case
    (1024, 256), (65536, 64), (1023, 4),
])
def test_kmeans_assign_moments_vs_ref(p, k):
    kw, kc = jax.random.split(jax.random.fold_in(KEY, p * k))
    w = jax.random.normal(kw, (p,))
    cb = jnp.sort(jax.random.normal(kc, (k,)))
    a1, s1, c1 = kops.assign_moments(w, cb, use_pallas=True)
    a2, s2, c2 = kref.kmeans_assign_moments_ref(w, cb)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))
    np.testing.assert_allclose(np.asarray(s1), np.asarray(s2),
                               rtol=3e-4, atol=1e-2)
    np.testing.assert_allclose(np.asarray(c1), np.asarray(c2), rtol=1e-6)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_kmeans_dtypes(dtype):
    w = jax.random.normal(KEY, (4096,)).astype(dtype)
    cb = jnp.linspace(-2, 2, 8)
    a1, _, _ = kops.assign_moments(w.astype(jnp.float32), cb,
                                   use_pallas=True)
    a2, _, _ = kref.kmeans_assign_moments_ref(
        w.astype(jnp.float32), cb)
    np.testing.assert_array_equal(np.asarray(a1), np.asarray(a2))


def test_kmeans_full_loop_matches_core_solver():
    """Kernel-backed Lloyd loop lands at the same codebook as the
    searchsorted-based core solver."""
    from repro.core.schemes.quantize import kmeans_1d, quantile_init
    w = jax.random.normal(KEY, (8192,))
    cb0 = quantile_init(w, 8)
    cb_kernel, _ = kops.kmeans(w, cb0, iters=20, use_pallas=True)
    cb_core, _ = kmeans_1d(w, cb0, iters=20)
    np.testing.assert_allclose(np.asarray(cb_kernel),
                               np.asarray(cb_core), atol=1e-3)


# ----------------------------------------------------------------------
# quant_matmul
# ----------------------------------------------------------------------
@pytest.mark.parametrize("m,k,n,c", [
    (8, 256, 128, 4), (64, 512, 256, 16), (17, 300, 129, 8),
    (1, 1024, 512, 2), (128, 128, 128, 16),
])
def test_quant_matmul_vs_ref(m, k, n, c):
    kx, ki, kc = jax.random.split(jax.random.fold_in(KEY, m * n + k), 3)
    x = jax.random.normal(kx, (m, k), jnp.float32)
    idx = jax.random.randint(ki, (k, n), 0, c).astype(jnp.uint8)
    cb = jnp.sort(jax.random.normal(kc, (c,)))
    y1 = qops.matmul(x, idx, cb, use_pallas=True)
    y2 = qref.quant_matmul_ref(x, idx, cb)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=1e-5, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_quant_matmul_x_dtypes(dtype):
    x = jax.random.normal(KEY, (16, 256)).astype(dtype)
    idx = jax.random.randint(KEY, (256, 64), 0, 4).astype(jnp.uint8)
    cb = jnp.array([-1.0, -0.3, 0.3, 1.0])
    y1 = qops.matmul(x, idx, cb, use_pallas=True)
    y2 = qref.quant_matmul_ref(x, idx, cb)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-2, atol=1e-1)


def test_pack_quantized_roundtrip():
    w = jax.random.normal(KEY, (64, 32))
    cb = jnp.array([-1.5, -0.5, 0.5, 1.5])
    idx = qops.pack_quantized(w, cb)
    deq = cb[idx.astype(jnp.int32)]
    # every entry maps to its nearest codebook value
    d_direct = jnp.abs(w[..., None] - cb).min(-1)
    np.testing.assert_allclose(np.asarray(jnp.abs(w - deq)),
                               np.asarray(d_direct), atol=1e-6)


# ----------------------------------------------------------------------
# prune
# ----------------------------------------------------------------------
@pytest.mark.parametrize("p,kappa", [
    (8192, 100), (5000, 2500), (1025, 1), (4096, 4095), (1024, 512),
])
def test_prune_topk_vs_ref(p, kappa):
    w = jax.random.normal(jax.random.fold_in(KEY, p + kappa), (p,))
    out = pops.topk_mask(w, kappa, use_pallas=True)
    t = float(pref.topk_threshold_ref(w, kappa))
    assert int(jnp.sum(out != 0)) == kappa
    kept = np.abs(np.asarray(out))[np.asarray(out) != 0]
    dropped = np.abs(np.asarray(w))[np.asarray(out) == 0]
    # kept set is exactly the top-κ magnitudes (float-exact threshold)
    assert kept.min() >= t * (1 - 1e-6)
    assert dropped.max() <= t * (1 + 1e-6)


@settings(max_examples=15, deadline=None)
@given(st.integers(min_value=1, max_value=999),
       st.integers(min_value=0, max_value=2**31 - 1))
def test_prop_prune_kappa_exact(kappa, seed):
    w = jax.random.normal(jax.random.PRNGKey(seed), (1000,))
    out = pops.topk_mask(w, kappa, use_pallas=True)
    assert int(jnp.sum(out != 0)) == kappa


def test_prune_matrix_shape_preserved():
    w = jax.random.normal(KEY, (32, 48))
    out = pops.topk_mask(w, 100, use_pallas=True)
    assert out.shape == w.shape
    assert int(jnp.sum(out != 0)) == 100


# ----------------------------------------------------------------------
# flash attention
# ----------------------------------------------------------------------
from repro.kernels.flash_attention import ops as fops
from repro.kernels.flash_attention import ref as fref


@pytest.mark.parametrize("b,s,h,kvh,d,w,qc,kc", [
    (2, 64, 4, 2, 16, 0, 16, 16),
    (1, 128, 8, 8, 32, 24, 32, 16),
    (2, 96, 6, 3, 16, 7, 32, 32),
    (1, 32, 2, 1, 8, 0, 8, 8),
])
def test_flash_attention_vs_ref(b, s, h, kvh, d, w, qc, kc):
    kq, kk, kv_ = jax.random.split(jax.random.fold_in(KEY, s + h), 3)
    q = jax.random.normal(kq, (b, s, h, d), jnp.float32)
    k = jax.random.normal(kk, (b, s, kvh, d), jnp.float32)
    v = jax.random.normal(kv_, (b, s, kvh, d), jnp.float32)
    out = fops.attention(q, k, v, window=w, q_chunk=qc, kv_chunk=kc,
                         use_pallas=True)
    exp = fops.attention(q, k, v, window=w, use_pallas=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = jax.random.normal(KEY, (1, 32, 4, 16)).astype(dtype)
    k = jax.random.normal(KEY, (1, 32, 2, 16)).astype(dtype)
    v = jax.random.normal(KEY, (1, 32, 2, 16)).astype(dtype)
    out = fops.attention(q, k, v, q_chunk=16, kv_chunk=16,
                         use_pallas=True)
    exp = fops.attention(q.astype(jnp.float32), k.astype(jnp.float32),
                         v.astype(jnp.float32), use_pallas=False)
    assert out.dtype == dtype
    np.testing.assert_allclose(np.asarray(out, dtype=np.float32),
                               np.asarray(exp), rtol=3e-2, atol=3e-2)


@pytest.mark.parametrize("window", [0, 24])
def test_flash_attention_kernel_vs_ref_direct(window):
    """The Pallas kernel (interpret mode) against the pure-jnp oracle in
    the kernel's own (B, KV, G, S, D) layout — no wrapper in between."""
    from repro.kernels.flash_attention.flash_attention import \
        flash_attention
    kq, kk, kv_ = jax.random.split(jax.random.fold_in(KEY, 77), 3)
    q = jax.random.normal(kq, (2, 2, 3, 64, 16), jnp.float32)
    k = jax.random.normal(kk, (2, 2, 64, 16), jnp.float32)
    v = jax.random.normal(kv_, (2, 2, 64, 16), jnp.float32)
    out = flash_attention(q, k, v, window=window, q_chunk=16, kv_chunk=16,
                          interpret=True)
    exp = fref.flash_attention_ref(q, k, v, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(exp),
                               rtol=2e-4, atol=2e-4)


def test_kmeans_lloyd_step_vs_ref():
    w = jax.random.normal(KEY, (8192,))
    cb = jnp.sort(jax.random.normal(jax.random.fold_in(KEY, 3), (16,)))
    new_k = kops.lloyd_step(w, cb, use_pallas=True)
    new_r = kref.lloyd_step_ref(w, cb)
    np.testing.assert_allclose(np.asarray(new_k), np.asarray(jnp.sort(new_r)),
                               rtol=3e-4, atol=1e-5)


@pytest.mark.parametrize("t", [0.1, 0.7, 2.5])
def test_prune_count_mask_kernels_vs_ref(t):
    from repro.kernels.prune.prune import (
        LANES, ROWS, count_above, mask_apply)
    w = jax.random.normal(jax.random.fold_in(KEY, 11),
                          (4 * ROWS * LANES,))
    tj = jnp.float32(t)
    np.testing.assert_allclose(
        float(count_above(w, tj, interpret=True)),
        float(pref.count_above_ref(w, tj)), rtol=0)
    np.testing.assert_allclose(
        np.asarray(mask_apply(w, tj, interpret=True)),
        np.asarray(pref.mask_apply_ref(w, tj)), rtol=0)


def test_flash_attention_matches_model_blockwise():
    """Kernel == the model's jnp blockwise path (the dry-run's fused-
    scope accounting assumes identical math)."""
    from repro.models.attention import blockwise_attention
    kq, kk, kv_ = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (2, 64, 4, 16), jnp.float32)
    k = jax.random.normal(kk, (2, 64, 2, 16), jnp.float32)
    v = jax.random.normal(kv_, (2, 64, 2, 16), jnp.float32)
    pos = jnp.arange(64)
    a = fops.attention(q, k, v, q_chunk=16, kv_chunk=16, use_pallas=True)
    b_ = blockwise_attention(q, k, v, pos, pos, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b_),
                               rtol=2e-4, atol=2e-4)
