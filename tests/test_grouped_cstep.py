"""Grouped C-step engine: grouped and per-task paths must produce
numerically identical Θ/λ/a state; the grouped path must trace one
scheme program per group (not per task); non-groupable schemes must
fall through; Θ packing helpers must round-trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsIs, AsStacked, AsVector, CompressionTask, LCAlgorithm, build_groups,
    exponential_mu_schedule)
from repro.core.schemes import (
    AdaptiveQuantization, AdditiveCombination, ConstraintL0Pruning,
    LowRank, Ternarize, add_leading_axis, drop_leading_axis, pack_thetas,
    unpack_thetas)

KEY = jax.random.PRNGKey(0)


def _mixed_params(key=KEY, n_layers=4):
    params = {
        f"l{i}": {
            "w": jax.random.normal(jax.random.fold_in(key, i), (32, 16)),
            "p": jax.random.normal(jax.random.fold_in(key, 100 + i),
                                   (512,)),
        } for i in range(n_layers)}
    params["stack"] = {
        "w": jax.random.normal(jax.random.fold_in(key, 999), (3, 512))}
    return params


def _mixed_tasks():
    return (
        [CompressionTask(f"q{i}", rf"l{i}/w$", AsVector(),
                         AdaptiveQuantization(k=4, iters=5))
         for i in range(2)]
        + [CompressionTask(f"pr{i}", rf"l{i}/p$", AsVector(),
                           ConstraintL0Pruning(kappa=64))
           for i in range(4)]
        + [CompressionTask("lr", r"l[23]/w$", AsIs(),
                           LowRank(2, randomized=False))]
        + [CompressionTask("st", r"stack/w$", AsStacked("vector"),
                           AdaptiveQuantization(k=4, iters=5))])


def _make_lc(group_tasks):
    return LCAlgorithm(_mixed_tasks(), exponential_mu_schedule(1e-2, 1.5, 3),
                       group_tasks=group_tasks)


# ----------------------------------------------------------------------
# equivalence (acceptance criterion: identical Θ/λ/a on the same inputs)
# ----------------------------------------------------------------------
def test_grouped_equals_pertask_full_state():
    params = _mixed_params()
    lcg, lcp = _make_lc(True), _make_lc(False)
    sg, sp = lcg.init(params), lcp.init(params)
    # drift w so the C step actually moves Θ, then run C + multiplier
    params2 = jax.tree_util.tree_map(
        lambda x: x + 0.01 * jnp.sin(7 * x), params)
    for _ in range(2):
        sg = lcg.multiplier_step(params2, lcg.c_step(params2, sg))
        sp = lcp.multiplier_step(params2, lcp.c_step(params2, sp))
    flat_g = jax.tree_util.tree_leaves_with_path(sg)
    flat_p = jax.tree_util.tree_leaves_with_path(sp)
    assert len(flat_g) == len(flat_p)
    for (kg, vg), (kp, vp) in zip(flat_g, flat_p):
        assert kg == kp
        np.testing.assert_array_equal(np.asarray(vg), np.asarray(vp),
                                      err_msg=jax.tree_util.keystr(kg))


def test_grouped_equals_pertask_stacked_only():
    """A stacked view merged with singleton tasks of the same item shape
    lands in one group and still matches the per-task vmap exactly."""
    params = {"stack": jax.random.normal(KEY, (5, 256)),
              "solo": jax.random.normal(jax.random.fold_in(KEY, 1), (256,))}
    tasks = [
        CompressionTask("st", r"^stack$", AsStacked("vector"),
                        Ternarize()),
        CompressionTask("so", r"^solo$", AsVector(), Ternarize()),
    ]
    lcg = LCAlgorithm(tasks, [1e-2], group_tasks=True)
    lcp = LCAlgorithm([CompressionTask(t.name, t.pattern, t.view, t.scheme)
                       for t in tasks], [1e-2], group_tasks=False)
    sg = lcg.c_step(params, lcg.init(params))
    sp = lcp.c_step(params, lcp.init(params))
    for (kg, vg), (kp, vp) in zip(
            jax.tree_util.tree_leaves_with_path(sg),
            jax.tree_util.tree_leaves_with_path(sp)):
        assert kg == kp
        np.testing.assert_array_equal(np.asarray(vg), np.asarray(vp))


# ----------------------------------------------------------------------
# grouping structure
# ----------------------------------------------------------------------
def test_build_groups_merges_compatible_tasks():
    params = _mixed_params()
    lc = _make_lc(True)
    summary = lc.group_summary(params)
    # q0, q1 (one (512,) item each) + st (3 stacked items) share scheme
    # config and item shape → one 5-item group
    by_scheme = {g["scheme"]: g for g in summary}
    assert by_scheme["AdaptiveQuantization"]["items"] == 5
    assert set(by_scheme["AdaptiveQuantization"]["tasks"]) == \
        {"q0", "q1", "st"}
    assert by_scheme["ConstraintL0Pruning"]["items"] == 4
    # the AsIs LowRank task was split per leaf at resolve, then regrouped
    assert by_scheme["LowRank"]["items"] == 2
    assert len(summary) == 3


def test_different_hyperparams_do_not_group():
    params = {"a": jax.random.normal(KEY, (128,)),
              "b": jax.random.normal(KEY, (128,))}
    tasks = [CompressionTask("a", "^a$", AsVector(),
                             ConstraintL0Pruning(kappa=16)),
             CompressionTask("b", "^b$", AsVector(),
                             ConstraintL0Pruning(kappa=32))]
    xs = {t.name: params[t.name] for t in tasks}
    for t in tasks:
        t.paths = [t.name]
    groups = build_groups(tasks, xs)
    assert len(groups) == 2


def test_subclass_does_not_group_with_parent():
    """A subclass overriding compress() but inheriting group_key() must
    not merge with its parent class — the group runs ONE scheme for all
    members."""
    class TunedPrune(ConstraintL0Pruning):
        def compress(self, w, theta, mu=None):  # different math
            return {"theta": jnp.zeros_like(w)}

    tasks = [CompressionTask("a", "^a$", AsVector(),
                             ConstraintL0Pruning(kappa=16)),
             CompressionTask("b", "^b$", AsVector(), TunedPrune(kappa=16))]
    for t in tasks:
        t.paths = [t.name]
    xs = {"a": jax.random.normal(KEY, (128,)),
          "b": jax.random.normal(KEY, (128,))}
    assert len(build_groups(tasks, xs)) == 2


def test_non_groupable_scheme_falls_through():
    """group_key() defaults to None → singleton group, per-task trace,
    identical numerics."""
    class OptOutPrune(ConstraintL0Pruning):
        def group_key(self):
            return None

    params = {"a": jax.random.normal(KEY, (128,)),
              "b": jax.random.normal(jax.random.fold_in(KEY, 1), (128,))}
    tasks = [CompressionTask("a", "^a$", AsVector(), OptOutPrune(kappa=16)),
             CompressionTask("b", "^b$", AsVector(), OptOutPrune(kappa=16))]
    lc = LCAlgorithm(tasks, [1e-2], group_tasks=True)
    assert all(len(g["tasks"]) == 1 for g in lc.group_summary(params))
    st = lc.c_step(params, lc.init(params))
    ref = ConstraintL0Pruning(kappa=16)
    np.testing.assert_array_equal(
        np.asarray(st["tasks"]["a"]["theta"]["theta"]),
        np.asarray(ref.compress(params["a"], None)["theta"]))


def test_additive_group_key_composes():
    a1 = AdditiveCombination(
        [ConstraintL0Pruning(8), AdaptiveQuantization(k=2, iters=3)])
    a2 = AdditiveCombination(
        [ConstraintL0Pruning(8), AdaptiveQuantization(k=2, iters=3)])
    a3 = AdditiveCombination(
        [ConstraintL0Pruning(9), AdaptiveQuantization(k=2, iters=3)])
    assert a1.group_key() == a2.group_key()
    assert a1.group_key() != a3.group_key()

    class Exotic(ConstraintL0Pruning):
        def group_key(self):
            return None

    assert AdditiveCombination(
        [Exotic(8), AdaptiveQuantization(k=2)]).group_key() is None


# ----------------------------------------------------------------------
# single-jit / single-trace property
# ----------------------------------------------------------------------
def test_grouped_traces_scheme_once_per_group():
    """Four same-config prune tasks: grouped path traces compress once
    (inside one vmap); per-task traces it four times."""
    class CountingPrune(ConstraintL0Pruning):
        traces = 0

        def compress(self, w, theta, mu=None):
            CountingPrune.traces += 1
            return super().compress(w, theta, mu=mu)

    params = {f"p{i}": jax.random.normal(jax.random.fold_in(KEY, i), (64,))
              for i in range(4)}

    def run(group_tasks):
        scheme = CountingPrune(kappa=8)
        tasks = [CompressionTask(f"t{i}", f"^p{i}$", AsVector(), scheme)
                 for i in range(4)]
        lc = LCAlgorithm(tasks, [1e-2], group_tasks=group_tasks)
        st = lc.init(params)
        CountingPrune.traces = 0
        jax.block_until_ready(lc.c_step(params, st))
        return CountingPrune.traces

    assert run(group_tasks=True) == 1
    assert run(group_tasks=False) == 4


def test_c_step_is_single_jitted_callable():
    lc = _make_lc(True)
    params = _mixed_params()
    st = lc.init(params)
    # one compiled executable serves the whole C step
    lowered = jax.jit(lc._c_step_impl).lower(params, st)
    assert lowered.compile() is not None


# ----------------------------------------------------------------------
# Θ packing helpers
# ----------------------------------------------------------------------
def test_pack_unpack_theta_roundtrip():
    mk = lambda i, n: {"u": jnp.full((n, 3), float(i)),
                       "r": jnp.arange(n) + 10 * i}
    thetas = [mk(1, 2), mk(2, 1), mk(3, 3)]
    packed = pack_thetas(thetas)
    assert packed["u"].shape == (6, 3)
    back = unpack_thetas(packed, [2, 1, 3])
    for orig, rt in zip(thetas, back):
        np.testing.assert_array_equal(np.asarray(orig["u"]),
                                      np.asarray(rt["u"]))
        np.testing.assert_array_equal(np.asarray(orig["r"]),
                                      np.asarray(rt["r"]))


def test_add_drop_leading_axis_roundtrip():
    th = {"a": jnp.ones((4, 2)), "b": jnp.zeros((3,))}
    up = add_leading_axis(th)
    assert up["a"].shape == (1, 4, 2) and up["b"].shape == (1, 3)
    down = drop_leading_axis(up)
    np.testing.assert_array_equal(np.asarray(down["a"]),
                                  np.asarray(th["a"]))


def test_namedtuple_theta_packs():
    """QuantTheta (NamedTuple) must survive pack/unpack — the grouped
    engine relies on Θ being an arbitrary pytree."""
    s = AdaptiveQuantization(k=2, iters=3)
    w1 = jax.random.normal(KEY, (64,))
    w2 = jax.random.normal(jax.random.fold_in(KEY, 1), (64,))
    t1, t2 = s.init(w1), s.init(w2)
    packed = pack_thetas([add_leading_axis(t1), add_leading_axis(t2)])
    assert packed.codebook.shape == (2, 2)
    back = [drop_leading_axis(t) for t in unpack_thetas(packed, [1, 1])]
    np.testing.assert_array_equal(np.asarray(back[0].assign),
                                  np.asarray(t1.assign))
    np.testing.assert_array_equal(np.asarray(back[1].codebook),
                                  np.asarray(t2.codebook))
