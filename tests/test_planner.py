"""Cost-model group planner: planner-on must be bit-identical to
planner-off for every scheme family (incl. chunked launches), plans and
AOT executables must cache across LC boundaries and jit rebuilds, and
the warm-started low-rank sketches must stay inside the documented
≤1e-4 relative-distortion budget."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis import cost
from repro.analysis.lint.contract import discover_scheme_classes
from repro.core import AsStacked, CompressionTask, LCAlgorithm
from repro.core.grouping import (
    _plan_multi_group, _task_solver, compile_group, describe_groups,
    grouped_compress)
from repro.core.schemes import AdaptiveQuantization, ConstraintL0Pruning

KEY = jax.random.PRNGKey(7)


def _family_cases():
    """(id, scheme) for the first contract example of every registered
    scheme class — the same sweep the lint layers run."""
    cases = []
    for cls in discover_scheme_classes():
        for i, ex in enumerate(cls.contract_examples()):
            cases.append(pytest.param(ex, id=f"{cls.__name__}[{i}]"))
    return cases


def _real_group(scheme, n_tasks=2, n_items=4):
    """A concrete multi-task group for one scheme instance: real
    arrays, engine-derived Θ — the executable twin of
    ``lint.hlo_rules.representative_group``."""
    item = (12, 8) if scheme.domain == "matrix" else (64,)
    group, xs, thetas = [], {}, {}
    for i in range(n_tasks):
        name = f"plan/{type(scheme).__name__}/{i}"
        t = CompressionTask(name, pattern=".",
                            view=AsStacked(scheme.domain), scheme=scheme)
        x = jax.random.normal(jax.random.fold_in(KEY, i),
                              (n_items,) + item, jnp.float32)
        group.append(t)
        xs[name] = x
        thetas[name] = t.scheme_init(x)
    return group, xs, thetas


def _compress(group, xs, thetas, planner, backend="auto", mu=1e-2):
    @jax.jit
    def step(xs, thetas):
        return grouped_compress(group, xs, thetas, jnp.float32(mu),
                                backend=backend, planner=planner)
    return step(xs, thetas)


def _assert_tree_equal(a, b, msg):
    la, ta = jax.tree_util.tree_flatten(a)
    lb, tb = jax.tree_util.tree_flatten(b)
    assert ta == tb, msg
    for x, y in zip(la, lb):
        np.testing.assert_array_equal(np.asarray(x), np.asarray(y),
                                      err_msg=msg)


@pytest.mark.parametrize("scheme", _family_cases())
@pytest.mark.parametrize("backend", ["auto", "off"])
def test_planner_parity_every_family(scheme, backend):
    """planner="on" must be bitwise planner-off for every scheme family
    on both dispatch modes — the planner only re-derives the static
    rule's choices off-TPU."""
    group, xs, thetas = _real_group(scheme)
    on = _compress(group, xs, thetas, "on", backend=backend)
    off = _compress(group, xs, thetas, None, backend=backend)
    _assert_tree_equal(on, off,
                       f"planner parity broken: {scheme} {backend}")


@pytest.mark.parametrize("scheme", _family_cases())
def test_chunked_solve_bit_identical(scheme):
    """A chunk budget small enough to split every group into per-item
    launches must not change a single bit: packing happens group-wide
    before the split and the solvers are per-item independent."""
    group, xs, thetas = _real_group(scheme, n_items=4)
    baseline = _compress(group, xs, thetas, None)
    old = cost.CHUNK_BUDGET_BYTES
    cost.CHUNK_BUDGET_BYTES = 1      # budget is NOT in the plan key:
    cost.clear_caches()              # drop plans made under the default
    try:
        chunked = _compress(group, xs, thetas, "on")
        counts = [t.view.item_count(xs[t.name]) for t in group]
        solver_fn, _ = _task_solver(group[0].scheme, "auto")
        plan = _plan_multi_group(group, xs, thetas, counts, solver_fn,
                                 None, None, "auto")
        assert plan.n_chunks > 1, "budget override never forced a split"
    finally:
        cost.CHUNK_BUDGET_BYTES = old
        cost.clear_caches()
    _assert_tree_equal(chunked, baseline,
                       f"chunked solve diverged: {scheme}")


def _probe_algo():
    params = {
        "qa": jnp.linspace(-1.0, 1.0, 64).reshape(4, 16),
        "qb": jnp.linspace(-3.0, 3.0, 64).reshape(4, 16),
        "pa": jnp.linspace(1.0, -1.0, 64).reshape(4, 16),
        "pb": jnp.linspace(2.0, -2.0, 64).reshape(4, 16),
    }
    tasks = [
        CompressionTask("qa", "qa", AsStacked("vector"),
                        AdaptiveQuantization(k=2, iters=2)),
        CompressionTask("qb", "qb", AsStacked("vector"),
                        AdaptiveQuantization(k=2, iters=2)),
        CompressionTask("pa", "pa", AsStacked("vector"),
                        ConstraintL0Pruning(kappa=8)),
        CompressionTask("pb", "pb", AsStacked("vector"),
                        ConstraintL0Pruning(kappa=4)),
    ]
    algo = LCAlgorithm(tasks, [1e-3, 2e-3, 4e-3], planner="on")
    return algo, params


def test_plan_cache_across_boundaries_and_rebuild():
    """≥3 identical LC boundaries + a forced jit rebuild: the plan is
    computed once per group and every later lookup hits the cache
    (zero re-plans) — the lint probe's assertion, exercised directly."""
    from repro.analysis.lint.trace_count import check_planner_cache

    cost.clear_caches()
    algo, params = _probe_algo()
    lc = algo.init(params)
    findings = check_planner_cache(algo, params, lc, boundaries=3)
    assert findings == [], [f.format() for f in findings]
    stats = cost.cache_stats()
    assert stats["plan_entries"] == 2          # quant group + prune group
    assert stats["plan_misses"] == 2
    assert stats["plan_hits"] >= 2             # the rebuild's re-trace


def test_full_lc_loop_parity_planner_on():
    """Multi-boundary LC loop (c step + multiplier step at rising μ):
    planner-on state must equal planner-off state bitwise."""
    def run(planner):
        algo, params = _probe_algo()
        algo.set_planner(planner)
        lc = algo.init(params)
        for k, mu in enumerate(algo.mu_schedule):
            lc = algo.set_mu(lc, mu, k)
            lc = algo.c_step(params, lc)
            lc = algo.multiplier_step(params, lc)
        return lc

    _assert_tree_equal(run("on"), run("off"), "LC loop planner parity")


def test_plan_key_sensitivity():
    """The cache key must miss on any signature/shape/backend/mesh/
    item-count change — and only on those."""
    sds = jax.ShapeDtypeStruct((4, 64), jnp.float32)
    base = cost.plan_key(("quant", 4), 4, (sds,), None, "auto")
    assert base == cost.plan_key(("quant", 4), 4, (sds,), None, "auto")
    assert base != cost.plan_key(("quant", 8), 4, (sds,), None, "auto")
    assert base != cost.plan_key(("quant", 4), 8, (sds,), None, "auto")
    assert base != cost.plan_key(("quant", 4), 4, (sds,), None, "jnp")
    other = jax.ShapeDtypeStruct((4, 32), jnp.float32)
    assert base != cost.plan_key(("quant", 4), 4, (other,), None, "auto")
    mesh = jax.sharding.Mesh(np.array(jax.devices()[:1]), ("data",))
    assert base != cost.plan_key(("quant", 4), 4, (sds,), mesh, "auto")


def test_exec_cache_zero_retrace_across_boundaries():
    """compile_group: one compile, then cache hits only — and the
    executable's output at each μ matches the jitted engine path."""
    scheme = AdaptiveQuantization(k=2, iters=2)
    group, xs, thetas = _real_group(scheme)
    cost.clear_caches()
    compiled, arrays = compile_group(group, xs, thetas, backend="auto")
    for _ in range(3):
        compiled2, _ = compile_group(group, xs, thetas, backend="auto")
        assert compiled2 is compiled
    stats = cost.cache_stats()
    assert stats["exec_misses"] == 1
    assert stats["exec_hits"] == 3
    for mu in (1e-3, 2e-3):
        theta_packed, a_packed = compiled(jnp.float32(mu), *arrays)
        ref = _compress(group, xs, thetas, None, mu=mu)
        packed_ref = jnp.concatenate(
            [ref[t.name][1] for t in group], axis=0)
        np.testing.assert_array_equal(np.asarray(a_packed),
                                      np.asarray(packed_ref))


def test_exec_cache_miss_on_shape_and_backend_change():
    scheme = AdaptiveQuantization(k=2, iters=2)
    cost.clear_caches()
    group, xs, thetas = _real_group(scheme, n_items=2)
    compile_group(group, xs, thetas, backend="auto")
    assert cost.cache_stats()["exec_misses"] == 1
    compile_group(group, xs, thetas, backend="off")       # backend change
    assert cost.cache_stats()["exec_misses"] == 2
    group3, xs3, thetas3 = _real_group(scheme, n_items=3)  # shape change
    compile_group(group3, xs3, thetas3, backend="auto")
    assert cost.cache_stats()["exec_misses"] == 3


def test_describe_groups_reports_plan():
    algo, params = _probe_algo()
    rows = describe_groups(algo.tasks,
                           {t.name: params[t.name] for t in algo.tasks},
                           backend="auto", planner="on")
    planned = [r for r in rows if r["plan"] is not None]
    assert len(planned) == 2
    for r in planned:
        plan = r["plan"]
        assert plan["backend"] == r["backend"] == "jnp"   # CPU static rule
        assert plan["n_chunks"] == 1
        assert plan["source"] == "hlo"
        assert plan["bottleneck"] in ("compute", "memory", "collective")
        assert plan["modeled_ms"] > 0.0
    # planner-off: the field is present but unpopulated
    rows_off = describe_groups(algo.tasks,
                               {t.name: params[t.name]
                                for t in algo.tasks}, backend="auto")
    assert all(r["plan"] is None for r in rows_off)


def test_planner_arg_validation():
    algo, _ = _probe_algo()
    with pytest.raises(ValueError, match="planner"):
        LCAlgorithm(algo.tasks, [1e-3], planner="bogus")
    with pytest.raises(ValueError, match="planner"):
        algo.set_planner("maybe")


def test_detect_hardware_and_tiles():
    hw = cost.detect_hardware()
    assert hw.name == "cpu"                    # CI runs on CPU
    assert hw.ridge_intensity > 0
    # the old roofline literals survived the HardwareSpec refactor
    from repro.analysis import roofline
    assert roofline.PEAK_FLOPS == cost.TPU_V5E.peak_flops
    assert roofline.HBM_BW == cost.TPU_V5E.hbm_bw
    assert roofline.LINK_BW == cost.TPU_V5E.link_bw
    tiles = cost.gemm_tiles(4, 2048, 512, packed=True)
    assert set(tiles) == {"block_m", "block_n", "block_k"}
    assert all(v >= 8 for v in tiles.values())


def test_chunk_and_backend_choosers():
    hw = cost.CPU
    assert cost.choose_chunks(100, 8, hw) == 1
    old = cost.CHUNK_BUDGET_BYTES
    cost.CHUNK_BUDGET_BYTES = 10
    try:
        assert cost.choose_chunks(35, 8, hw) == 4
        assert cost.choose_chunks(1 << 30, 8, hw) == 8   # ≤ n_items
    finally:
        cost.CHUNK_BUDGET_BYTES = old
    terms = {"flops": 1.0, "bytes": 1e9, "working_set_bytes": 1 << 22}
    # explicit requests are honored verbatim
    assert cost.choose_backend("interpret", "kmeans_lloyd",
                               ("jnp", "interpret"), terms, hw)[0] \
        == "interpret"
    # "auto" off-TPU is the static rule: jnp
    assert cost.choose_backend("auto", "kmeans_lloyd",
                               ("jnp", "pallas"), terms, hw)[0] == "jnp"
    # on TPU, a memory-bound group with a registered pallas kernel
    # gets the fused kernel; a compute-bound one stays on XLA
    b, _ = cost.choose_backend("auto", "kmeans_lloyd",
                               ("jnp", "pallas"), terms, cost.TPU_V5E)
    assert b == "pallas"
    hot = dict(terms, flops=1e15)
    b, fb = cost.choose_backend("auto", "kmeans_lloyd",
                                ("jnp", "pallas"), hot, cost.TPU_V5E)
    assert b == "jnp" and fb
    # off-TPU tiles stay default (bit-parity contract)
    rows, _ = cost.choose_block_rows("kmeans_lloyd", "interpret", 4,
                                     4096, 0, hw)
    assert rows is None
    rows, _ = cost.choose_block_rows("kmeans_lloyd", "pallas", 4,
                                     4096, 0, cost.TPU_V5E)
    assert rows in cost.BLOCK_ROWS_CANDIDATES


def test_warm_started_sketch_distortion_bound():
    """Warm-started range finder (previous U + thin fresh sketch, fewer
    power iterations) must stay within 1e-4 relative distortion of the
    exact truncated SVD — the budget LowRank documents."""
    from repro.kernels.lowrank.ops import (
        _warm_iters, lowrank_rsvd_batched)

    assert _warm_iters(3) == 2
    assert _warm_iters(1) == 1

    items, m, n, r = 3, 64, 48, 4
    rng = np.random.default_rng(0)
    ws = []
    for _ in range(items):
        u, _ = np.linalg.qr(rng.standard_normal((m, n)))
        v, _ = np.linalg.qr(rng.standard_normal((n, n)))
        s = 2.0 ** -np.arange(n)                 # decaying spectrum
        ws.append((u[:, :n] * s) @ v.T)
    w1 = jnp.asarray(np.stack(ws), jnp.float32)

    rank = jnp.full((items,), r, jnp.int32)
    keys = jnp.stack([jax.random.fold_in(KEY, i)
                      for i in range(items)])
    u_prev, _ = lowrank_rsvd_batched(w1, rank, keys, r_max=r)

    # "late μ": the target barely moves between C steps
    w2 = w1 + 1e-4 * jnp.asarray(
        rng.standard_normal(w1.shape), jnp.float32)
    u2, v2 = lowrank_rsvd_batched(w2, rank, keys, r_max=r, u0=u_prev)

    w2np = np.asarray(w2, np.float64)
    approx = np.asarray(u2, np.float64) @ \
        np.asarray(v2, np.float64).transpose(0, 2, 1)
    err_warm = np.sum((w2np - approx) ** 2, axis=(1, 2))
    sv = np.linalg.svd(w2np, compute_uv=False)
    err_exact = np.sum(sv[:, r:] ** 2, axis=1)
    total = np.sum(w2np ** 2, axis=(1, 2))
    excess = (err_warm - err_exact) / total
    assert np.all(excess <= 1e-4), excess
