"""Mesh-sharded grouped C step.

The grouped engine may shard each group's packed item axis over the
mesh's data axis (``"items"`` rule in distributed/sharding.py). The
contract is strict: ``mesh=None`` and every mesh configuration —
including item counts that need padding, singleton groups, and
non-groupable schemes — produce bit-identical LC state.

The pytest process owns one CPU device, so the real multi-device runs
spawn subprocesses with ``--xla_force_host_platform_device_count=8``
(same pattern as test_distributed_integration).
"""
import os
import subprocess
import sys

import jax
import numpy as np

from repro.core import AsVector, CompressionTask, LCAlgorithm
from repro.core.grouping import describe_groups
from repro.core.schemes import AdaptiveQuantization, ConstraintL0Pruning
from repro.distributed.sharding import items_partition
from jax.sharding import PartitionSpec as P

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
KEY = jax.random.PRNGKey(0)


class _FakeMesh:
    """Shape-only mesh stand-in (items_partition reads names + sizes)."""

    def __init__(self, sizes: dict):
        self.axis_names = tuple(sizes)
        self.devices = np.empty(tuple(sizes.values()))


# ----------------------------------------------------------------------
# items_partition: divisibility, padding, fallback
# ----------------------------------------------------------------------
def test_items_partition_divisible():
    mesh = _FakeMesh({"data": 4, "model": 2})
    assert items_partition(8, mesh) == ("data", 0)
    assert items_partition(4, mesh) == ("data", 0)


def test_items_partition_pads_to_axis():
    mesh = _FakeMesh({"data": 4, "model": 2})
    assert items_partition(5, mesh) == ("data", 3)
    assert items_partition(2, mesh) == ("data", 2)
    # already-divisible counts never pad
    assert items_partition(12, mesh) == ("data", 0)


def test_items_partition_no_pad_requires_divisibility():
    mesh = _FakeMesh({"data": 4, "model": 2})
    assert items_partition(5, mesh, allow_pad=False) == (None, 0)
    assert items_partition(8, mesh, allow_pad=False) == ("data", 0)


def test_items_partition_missing_axis_replicates():
    mesh = _FakeMesh({"model": 4})
    assert items_partition(8, mesh) == (None, 0)


def test_items_partition_respects_custom_rules():
    mesh = _FakeMesh({"pod": 2, "data": 2, "model": 2})
    rules = {"items": [("pod", "data"), ("data",), ()]}
    assert items_partition(8, mesh, rules) == (("pod", "data"), 0)
    assert items_partition(3, mesh, rules) == (("pod", "data"), 1)


# ----------------------------------------------------------------------
# describe_groups: resolved spec + padding fields
# ----------------------------------------------------------------------
def _four_prune_tasks(n=4, p=64):
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(KEY, i), (p,))
              for i in range(n)}
    tasks = [CompressionTask(f"pr{i}", f"^l{i}$", AsVector(),
                             ConstraintL0Pruning(kappa=8),
                             paths=[f"l{i}"])
             for i in range(n)]
    xs = {t.name: params[t.paths[0]] for t in tasks}
    return tasks, xs


def test_describe_groups_reports_spec_and_padding():
    tasks, xs = _four_prune_tasks(n=3)
    mesh = _FakeMesh({"data": 2, "model": 4})
    (g,) = describe_groups(tasks, xs, mesh=mesh)
    assert g["grouped"] and g["items"] == 3
    assert g["spec"] == P("data")
    assert g["padding"] == 1  # 3 items over a 2-way data axis


def test_describe_groups_no_mesh_fields_default():
    tasks, xs = _four_prune_tasks(n=3)
    (g,) = describe_groups(tasks, xs)
    assert g["spec"] is None and g["padding"] == 0


def test_describe_groups_singleton_has_no_spec():
    """Singleton groups run the per-task path, so no sharding spec even
    with a mesh bound."""
    tasks, xs = _four_prune_tasks(n=1)
    mesh = _FakeMesh({"data": 2, "model": 4})
    (g,) = describe_groups(tasks, xs, mesh=mesh)
    assert not g["grouped"]
    assert g["spec"] is None and g["padding"] == 0


def test_describe_groups_replication_fallback_spec_is_none():
    """A mesh without a usable "items" axis falls back to replication —
    the report must say 'not sharded' (None), not PartitionSpec(None)."""
    tasks, xs = _four_prune_tasks(n=4)
    mesh = _FakeMesh({"model": 4})
    (g,) = describe_groups(tasks, xs, mesh=mesh)
    assert g["grouped"]
    assert g["spec"] is None and g["padding"] == 0


def test_group_summary_ignores_mesh_on_pertask_path():
    """group_tasks=False executes the unsharded per-task C step, so the
    summary must not report a layout that is never applied."""
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(KEY, i), (64,))
              for i in range(4)}
    tasks = [CompressionTask(f"pr{i}", f"^l{i}$", AsVector(),
                             ConstraintL0Pruning(kappa=8))
             for i in range(4)]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lc = LCAlgorithm(tasks, [1e-2], group_tasks=False, mesh=mesh)
    (g,) = lc.group_summary(params)
    assert g["spec"] is None and g["padding"] == 0


def test_group_summary_threads_mesh():
    params = {f"l{i}": jax.random.normal(jax.random.fold_in(KEY, i), (64,))
              for i in range(4)}
    tasks = [CompressionTask(f"pr{i}", f"^l{i}$", AsVector(),
                             ConstraintL0Pruning(kappa=8))
             for i in range(4)]
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lc = LCAlgorithm(tasks, [1e-2], mesh=mesh)
    (g,) = lc.group_summary(params)
    assert g["spec"] == P("data") and g["padding"] == 0


# ----------------------------------------------------------------------
# single-device mesh: the sharded code path must already be exact
# ----------------------------------------------------------------------
def _state_equal(sa, sb):
    fa = jax.tree_util.tree_leaves_with_path(sa)
    fb = jax.tree_util.tree_leaves_with_path(sb)
    assert len(fa) == len(fb)
    for (ka, va), (kb, vb) in zip(fa, fb):
        assert ka == kb
        np.testing.assert_array_equal(np.asarray(va), np.asarray(vb),
                                      err_msg=jax.tree_util.keystr(ka))


def _quant_prune_setup():
    params = {
        f"l{i}": {"w": jax.random.normal(jax.random.fold_in(KEY, i),
                                         (128,)),
                  "p": jax.random.normal(jax.random.fold_in(KEY, 50 + i),
                                         (96,))}
        for i in range(3)}

    def tasks():
        return (
            [CompressionTask(f"q{i}", rf"l{i}/w$", AsVector(),
                             AdaptiveQuantization(k=4, iters=5))
             for i in range(3)]
            + [CompressionTask(f"pr{i}", rf"l{i}/p$", AsVector(),
                               ConstraintL0Pruning(kappa=16))
               for i in range(3)])
    return params, tasks


def test_one_device_mesh_matches_mesh_none():
    params, tasks = _quant_prune_setup()
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    lcm = LCAlgorithm(tasks(), [1e-2, 1.5e-2], mesh=mesh)
    lc0 = LCAlgorithm(tasks(), [1e-2, 1.5e-2])
    sm, s0 = lcm.init(params), lc0.init(params)
    for _ in range(2):
        sm = lcm.multiplier_step(params, lcm.c_step(params, sm))
        s0 = lc0.multiplier_step(params, lc0.c_step(params, s0))
    _state_equal(sm, s0)


def test_set_mesh_rebuilds_jitted_c_step():
    """A mesh bound after the first compile must still take effect —
    set_mesh rebuilds the jitted steps (the mesh is trace-time state)."""
    params, tasks = _quant_prune_setup()
    lc = LCAlgorithm(tasks(), [1e-2])
    st = lc.init(params)
    st1 = lc.c_step(params, st)                     # compiled without mesh
    before = lc._c_step
    lc.set_mesh(jax.make_mesh((1, 1), ("data", "model")))
    assert lc._c_step is not before                 # stale cache dropped
    st2 = lc.c_step(params, st)
    _state_equal(st1, st2)
    (g, *_) = lc.group_summary(params)
    assert g["spec"] == P("data")


def test_trainer_threads_mesh_into_algorithm():
    from repro.configs import get_config, reduced_config
    from repro.data import TokenStream
    from repro.launch.mesh import make_debug_mesh
    from repro.runtime import LCTrainer

    cfg = reduced_config(get_config("phi3-mini-3.8b")).with_(pattern_reps=1)
    lc = LCAlgorithm(
        [CompressionTask("q", r"stages/.*/w_gate$", AsVector(),
                         AdaptiveQuantization(k=2, iters=5))], [1e-4])
    mesh = make_debug_mesh()
    trainer = LCTrainer(cfg, lc, TokenStream(cfg.vocab_size, 2, 16),
                        mesh=mesh)
    assert trainer.lc.mesh is mesh


# ----------------------------------------------------------------------
# real multi-device meshes (subprocess, 8 forced host devices)
# ----------------------------------------------------------------------
def _run(script: str, devices: int = 8, timeout: int = 500) -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = (
        env.get("XLA_FLAGS", "")
        + f" --xla_force_host_platform_device_count={devices}")
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run(
        [sys.executable, "-c", script], capture_output=True, text=True,
        env=env, timeout=timeout)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def test_sharded_cstep_bit_identical_on_2x4_mesh():
    """Acceptance criterion: a (2, 4) data×model mesh produces Θ/Δ(Θ)/λ
    bit-identical to mesh=None on a mixed config that covers every edge:
    a padded group (5 items over data=2), divisible groups, a LAPACK
    custom-call scheme (LowRank/SVD), a stacked view, a singleton group,
    and a non-groupable (group_key=None) scheme."""
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import (AsIs, AsStacked, AsVector, CompressionTask,
                        LCAlgorithm)
from repro.core.schemes import (AdaptiveQuantization, ConstraintL0Pruning,
                                LowRank)
from jax.sharding import PartitionSpec as P

class OptOutPrune(ConstraintL0Pruning):
    def group_key(self):
        return None

KEY = jax.random.PRNGKey(0)
params = {
    f"l{i}": {"w": jax.random.normal(jax.random.fold_in(KEY, i), (32, 16)),
              "p": jax.random.normal(jax.random.fold_in(KEY, 100 + i),
                                     (512,))}
    for i in range(4)}
params["stack"] = {"w": jax.random.normal(jax.random.fold_in(KEY, 999),
                                          (3, 512))}
params["solo"] = {"w": jax.random.normal(jax.random.fold_in(KEY, 55),
                                         (77,))}
params["exotic"] = {"p": jax.random.normal(jax.random.fold_in(KEY, 66),
                                           (512,))}

def tasks():
    return (
        # 2 single items + 3 stacked items = 5 over data=2 -> padding 1
        [CompressionTask(f"q{i}", rf"l{i}/w$", AsVector(),
                         AdaptiveQuantization(k=4, iters=5))
         for i in range(2)]
        + [CompressionTask("st", r"stack/w$", AsStacked("vector"),
                           AdaptiveQuantization(k=4, iters=5))]
        # 4 items over data=2 -> divisible
        + [CompressionTask(f"pr{i}", rf"l{i}/p$", AsVector(),
                           ConstraintL0Pruning(kappa=64))
           for i in range(4)]
        # LAPACK svd custom call inside the sharded region
        + [CompressionTask("lr", r"l[23]/w$", AsIs(),
                           LowRank(2, randomized=False))]
        # singleton group: unique shape -> per-task path
        + [CompressionTask("solo", r"solo/w$", AsVector(),
                           ConstraintL0Pruning(kappa=8))]
        # non-groupable: group_key None -> per-task path
        + [CompressionTask("ex", r"exotic/p$", AsVector(),
                           OptOutPrune(kappa=64))])

mesh = jax.make_mesh((2, 4), ("data", "model"))
lcm = LCAlgorithm(tasks(), [1e-2] * 3, mesh=mesh)
lc0 = LCAlgorithm(tasks(), [1e-2] * 3)

summary = {tuple(g["tasks"]): g for g in lcm.group_summary(params)}
g_quant = summary[("q0", "q1", "st")]
assert g_quant["spec"] == P("data") and g_quant["padding"] == 1, g_quant
g_prune = summary[("pr0", "pr1", "pr2", "pr3")]
assert g_prune["spec"] == P("data") and g_prune["padding"] == 0, g_prune
g_solo = summary[("solo",)]
assert g_solo["spec"] is None and g_solo["padding"] == 0, g_solo
g_ex = summary[("ex",)]
assert g_ex["spec"] is None and not g_ex["grouped"], g_ex

sm, s0 = lcm.init(params), lc0.init(params)
params2 = jax.tree_util.tree_map(lambda x: x + 0.01 * jnp.sin(7 * x),
                                 params)
for _ in range(2):
    sm = lcm.multiplier_step(params2, lcm.c_step(params2, sm))
    s0 = lc0.multiplier_step(params2, lc0.c_step(params2, s0))
fm = jax.tree_util.tree_leaves_with_path(sm)
f0 = jax.tree_util.tree_leaves_with_path(s0)
assert len(fm) == len(f0)
for (km, vm), (k0, v0) in zip(fm, f0):
    assert km == k0
    np.testing.assert_array_equal(np.asarray(vm), np.asarray(v0),
                                  err_msg=jax.tree_util.keystr(km))
print("bit-identical ok")
"""
    out = _run(script)
    assert "bit-identical ok" in out


def test_sharded_cstep_multipod_rule():
    """Custom rules: ("pod", "data") joint sharding on a (2, 2, 2) mesh,
    6 items -> pad 2, still bit-identical to mesh=None."""
    script = """
import jax, jax.numpy as jnp, numpy as np
from repro.core import AsVector, CompressionTask, LCAlgorithm
from repro.core.schemes import ConstraintL0Pruning
from jax.sharding import PartitionSpec as P

KEY = jax.random.PRNGKey(0)
params = {f"l{i}": jax.random.normal(jax.random.fold_in(KEY, i), (256,))
          for i in range(6)}
def tasks():
    return [CompressionTask(f"pr{i}", f"^l{i}$", AsVector(),
                            ConstraintL0Pruning(kappa=32))
            for i in range(6)]
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
rules = {"items": [("pod", "data"), ("data",), ()]}
lcm = LCAlgorithm(tasks(), [1e-2], mesh=mesh, sharding_rules=rules)
lc0 = LCAlgorithm(tasks(), [1e-2])
(g,) = lcm.group_summary(params)
assert g["spec"] == P(("pod", "data")) and g["padding"] == 2, g
sm = lcm.c_step(params, lcm.init(params))
s0 = lc0.c_step(params, lc0.init(params))
for (km, vm), (k0, v0) in zip(jax.tree_util.tree_leaves_with_path(sm),
                              jax.tree_util.tree_leaves_with_path(s0)):
    assert km == k0
    np.testing.assert_array_equal(np.asarray(vm), np.asarray(v0),
                                  err_msg=jax.tree_util.keystr(km))
print("multipod ok")
"""
    out = _run(script)
    assert "multipod ok" in out
