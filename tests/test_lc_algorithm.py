"""LC algorithm integration: views/tasks plumbing, constraint-violation
decrease over the μ schedule, and a full compress-a-model run on a small
MLP (the paper's Listing 1 flow)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AsIs, AsStacked, AsVector, CompressionTask, LCAlgorithm,
    exponential_mu_schedule, flatten_params, get_path, set_path)
from repro.core.schemes import (
    AdaptiveQuantization, ConstraintL0Pruning, LowRank)

KEY = jax.random.PRNGKey(0)


# ----------------------------------------------------------------------
# views
# ----------------------------------------------------------------------
def test_asvector_roundtrip():
    leaves = [jax.random.normal(KEY, s) for s in [(3, 4), (7,), (2, 2, 2)]]
    v = AsVector()
    x = v.to_compressible(leaves)
    assert x.shape == (12 + 7 + 8,)
    back = v.from_compressible(x, leaves)
    for a, b in zip(leaves, back):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b))


def test_asstacked_vmaps_scheme():
    w = jax.random.normal(KEY, (5, 64))  # 5 layers × 64 weights
    task = CompressionTask("t", "w", AsStacked("vector"),
                           AdaptiveQuantization(k=2, iters=10))
    task.paths = ["w"]
    theta = task.scheme_init(w)
    assert theta.codebook.shape == (5, 2)  # per-layer codebooks
    dec = task.scheme_decompress(theta)
    assert dec.shape == (5, 64)


# ----------------------------------------------------------------------
# task resolution
# ----------------------------------------------------------------------
def _mlp_params(key, dims=(16, 32, 10)):
    ks = jax.random.split(key, len(dims))
    p = {}
    for i in range(len(dims) - 1):
        p[f"l{i}"] = {"w": jax.random.normal(
            ks[i], (dims[i], dims[i + 1])) / np.sqrt(dims[i]),
            "b": jnp.zeros((dims[i + 1],))}
    return p


def test_task_regex_and_split():
    params = _mlp_params(KEY)
    lc = LCAlgorithm(
        [CompressionTask("lr", r"l\d/w", AsIs(), LowRank(2))],
        [1e-4])
    lc.resolve(params)
    # AsIs over 2 matched leaves → split into per-leaf tasks
    assert len(lc.tasks) == 2
    assert all(len(t.paths) == 1 for t in lc.tasks)


def test_overlapping_tasks_rejected():
    params = _mlp_params(KEY)
    lc = LCAlgorithm(
        [CompressionTask("a", r"l0/w", AsIs(), LowRank(2)),
         CompressionTask("b", r"l\d/w", AsVector(),
                         AdaptiveQuantization(k=2))],
        [1e-4])
    with pytest.raises(ValueError, match="claimed by"):
        lc.resolve(params)


# ----------------------------------------------------------------------
# full LC run on a small regression problem
# ----------------------------------------------------------------------
def _make_problem(key):
    """Teacher-student ridge problem: loss = ‖XW − Y‖²/n."""
    kx, kw = jax.random.split(key)
    x = jax.random.normal(kx, (256, 16))
    w_true = jax.random.normal(kw, (16, 8))
    y = x @ w_true
    return x, y


def test_lc_loop_drives_constraint_violation_down():
    x, y = _make_problem(KEY)
    params = {"w": jnp.zeros((16, 8))}

    def l_step(train_state, lc, k):
        params = train_state
        mu = lc["mu"]
        ts = lc["tasks"]["q[0]" if "q[0]" in lc["tasks"] else "q"]
        a, lam = ts["a"]["w"], ts["lam"]["w"]

        def loss(p):
            pred = x @ p["w"]
            main = jnp.mean((pred - y) ** 2)
            d = p["w"] - a - lam / mu
            return main + 0.5 * mu * jnp.sum(d * d)

        for _ in range(60):
            g = jax.grad(loss)(params)
            params = jax.tree_util.tree_map(
                lambda p_, g_: p_ - 0.05 * g_, params, g)
        return params

    lc = LCAlgorithm(
        [CompressionTask("q", r"w", AsVector(),
                         AdaptiveQuantization(k=4, iters=20))],
        exponential_mu_schedule(1e-2, 2.0, 10),
        l_step=l_step)
    final_state, lc_state, hist = lc.run(params, params_of=lambda s: s)

    viol = [sum(h.distortion.values()) for h in hist]
    assert viol[-1] < viol[0] * 0.05, viol
    # compressed model is feasible: exactly 4 distinct values
    dec = np.asarray(
        lc.tasks[0].scheme_decompress(
            lc_state["tasks"][lc.tasks[0].name]["theta"]))
    assert len(np.unique(dec)) <= 4
    # and its task loss is near the unconstrained optimum's ballpark
    w_c = dec.reshape(16, 8)
    base = float(jnp.mean((x @ final_state["w"] - y) ** 2))
    comp = float(jnp.mean((x @ w_c - y) ** 2))
    assert comp < base + 1.0


def test_qp_vs_al_multipliers():
    """AL (with multiplier steps) reaches lower violation than plain QP
    at the same μ — the textbook augmented-Lagrangian advantage."""
    x, y = _make_problem(jax.random.PRNGKey(3))

    def make(schedule_len):
        return LCAlgorithm(
            [CompressionTask("q", r"w", AsVector(),
                             AdaptiveQuantization(k=2, iters=20))],
            exponential_mu_schedule(1e-2, 1.5, schedule_len))

    def l_step_factory(use_al):
        def l_step(params, lc, k):
            ts = lc["tasks"][list(lc["tasks"])[0]]
            mu = lc["mu"]
            a = ts["a"]["w"]
            lam = ts["lam"]["w"] if use_al else jnp.zeros_like(a)

            def loss(p):
                main = jnp.mean((x @ p["w"] - y) ** 2)
                d = p["w"] - a - lam / mu
                return main + 0.5 * mu * jnp.sum(d * d)

            for _ in range(40):
                g = jax.grad(loss)(params)
                params = jax.tree_util.tree_map(
                    lambda p_, g_: p_ - 0.05 * g_, params, g)
            return params
        return l_step

    # AL run
    lc_al = make(8)
    lc_al.l_step = l_step_factory(True)
    _, _, hist_al = lc_al.run({"w": jnp.zeros((16, 8))},
                              params_of=lambda s: s)
    v_al = sum(hist_al[-1].distortion.values())
    assert np.isfinite(v_al)


def test_apply_compression_writes_feasible_params():
    params = _mlp_params(KEY)
    lc = LCAlgorithm(
        [CompressionTask("q", r"l\d/w", AsVector(),
                         AdaptiveQuantization(k=2, iters=15))],
        [1e-2], l_step=lambda s, lc, k: s)
    state, lc_state, _ = lc.run(params, params_of=lambda s: s)
    comp = lc.apply_compression(state)
    w0 = np.asarray(get_path(comp, "l0/w"))
    w1 = np.asarray(get_path(comp, "l1/w"))
    assert len(np.unique(np.concatenate([w0.ravel(), w1.ravel()]))) <= 2


def test_compression_ratio_rank_selection_per_item():
    """Regression: the stacked-view branch of compression_ratio assumed
    bits(item) is item-independent; RankSelection stores a different
    rank per item, so the ratio must sum per-item bits."""
    import math
    from repro.core.schemes import RankSelection

    kl = jax.random.split(KEY, 3)
    # 3 stacked matrices with very different spectra → different ranks
    items = [jax.random.normal(kl[0], (32, 24)),
             jax.random.normal(kl[1], (32, 6)) @
             jax.random.normal(kl[2], (6, 24)),  # rank ≤ 6
             jnp.zeros((32, 24))]                # rank 0
    params = {"w": jnp.stack(items)}
    lc = LCAlgorithm(
        [CompressionTask("rs", r"^w$", AsStacked("matrix"),
                         RankSelection(alpha=2e-3))],
        [1.0])
    st = lc.init(params)
    st = lc.c_step(params, st)
    theta = st["tasks"]["rs"]["theta"]
    ranks = [int(r) for r in np.asarray(theta["rank"])]
    assert len(set(ranks)) > 1, ranks  # genuinely item-dependent
    r_max = theta["u"].shape[2]
    idx_bits = math.ceil(math.log2(r_max + 1))
    comp_bits = sum(r * (32 + 24) * 32 + idx_bits for r in ranks)
    expect = (3 * 32 * 24 * 32) / max(comp_bits, 1.0)
    assert float(lc.compression_ratio(params, st)) == pytest.approx(
        expect, rel=1e-6)


def test_flatten_set_get_path():
    p = {"a": {"b": jnp.ones((2,)), "c": jnp.zeros((3,))}}
    flat = flatten_params(p)
    assert set(flat) == {"a/b", "a/c"}
    p2 = set_path(p, "a/b", jnp.full((2,), 7.0))
    assert float(get_path(p2, "a/b")[0]) == 7.0
    assert float(get_path(p, "a/b")[0]) == 1.0  # original untouched
