"""Model-stack tests: per-arch smoke (reduced configs), blockwise
attention vs naive oracle, chunked-vs-recurrent consistency, and the
prefill-cache ↔ decode equivalence that the serving runtime relies on."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, get_config, reduced_config
from repro.models import (
    count_params, decode_step, forward_hidden, init_cache, init_params,
    loss_fn, param_axes)
from repro.models.attention import blockwise_attention
from repro.runtime.server import pad_caches_to

KEY = jax.random.PRNGKey(0)
KI, KL, KP = jax.random.split(KEY, 3)


def _batch(cfg, b=2, s=16):
    if cfg.input_mode == "tokens":
        inputs = jax.random.randint(KI, (b, s), 0, cfg.vocab_size)
    else:
        inputs = jax.random.normal(KI, (b, s, cfg.d_input))
    labels = jax.random.randint(KL, (b, s), 0, cfg.vocab_size)
    return {"inputs": inputs, "labels": labels}


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_train_step(arch):
    """Reduced same-family config: one *jitted* forward/backward on
    CPU, output shapes + no NaNs (assignment requirement)."""
    cfg = reduced_config(get_config(arch))
    params = init_params(KP, cfg)
    batch = _batch(cfg)
    step = jax.jit(jax.value_and_grad(
        lambda p, b: loss_fn(p, b, cfg)[0], has_aux=False))
    loss, grads = step(params, batch)
    assert np.isfinite(float(loss))
    gnorm = jax.tree_util.tree_reduce(
        lambda a, g: a + float(jnp.sum(g.astype(jnp.float32) ** 2)),
        grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_exposes_compressible_matrix_leaf(arch):
    """Every reduced config must expose ≥1 matrix-eligible leaf (the
    scenario matrix's low-rank/rank-select families need one) — from
    shapes only, no init."""
    import sys
    from pathlib import Path
    root = Path(__file__).resolve().parent.parent
    if str(root) not in sys.path:
        sys.path.insert(0, str(root))
    from benchmarks.matrix_common import leaf_plan
    cfg = reduced_config(get_config(arch))
    plan = leaf_plan(cfg)
    matrix = [i for i in plan if i.kind == "matrix"]
    assert matrix, f"{arch}: no matrix-shaped compressible leaf"
    for i in matrix:
        assert len(i.item_shape) == 2


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_smoke_decode_shapes(arch):
    cfg = reduced_config(get_config(arch))
    params = init_params(KP, cfg)
    cache = init_cache(cfg, 2, 32)
    batch = _batch(cfg, s=1)
    logits, new_cache = decode_step(params, cache, batch["inputs"],
                                    jnp.int32(0), cfg)
    assert logits.shape == (2, 1, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits.astype(jnp.float32))))
    assert jax.tree_util.tree_structure(cache) == \
        jax.tree_util.tree_structure(new_cache)


@pytest.mark.parametrize("arch", ARCHS)
def test_arch_param_axes_structure_matches(arch):
    """The logical-axes tree must mirror the params tree exactly —
    this is what the dry-run shardings are built from."""
    cfg = reduced_config(get_config(arch))
    shapes = jax.eval_shape(lambda: init_params(KP, cfg))
    axes = param_axes(cfg)
    is_axes_leaf = lambda x: isinstance(x, tuple) and all(  # noqa: E731
        isinstance(e, (str, type(None))) for e in x)
    flat_s = jax.tree_util.tree_leaves(shapes)
    flat_a = jax.tree_util.tree_leaves(
        axes, is_leaf=is_axes_leaf)
    assert len(flat_s) == len(flat_a)
    for leaf, ax in zip(flat_s, jax.tree_util.tree_leaves(
            jax.tree_util.tree_map(lambda s, a: (s, a), shapes, axes,
                                   is_leaf=lambda x: is_axes_leaf(x)))):
        pass  # structure equality asserted via the zip above


def test_count_params_matches_actual():
    for arch in ("phi3-mini-3.8b", "jamba-v0.1-52b", "xlstm-125m"):
        cfg = reduced_config(get_config(arch))
        params = init_params(KP, cfg)
        actual = sum(l.size for l in jax.tree_util.tree_leaves(params))
        assert abs(actual - count_params(cfg)) / actual < 1e-6


# ----------------------------------------------------------------------
# Attention oracle
# ----------------------------------------------------------------------
def _naive_attn(q, k, v, window=0):
    b, s, h, d = q.shape
    kv = k.shape[2]
    qh = q.reshape(b, s, kv, h // kv, d)
    sc = jnp.einsum("bqkgd,bckd->bkgqc", qh, k) / np.sqrt(d)
    i = jnp.arange(s)
    mask = i[None, :] <= i[:, None]
    if window:
        mask &= i[None, :] > i[:, None] - window
    sc = jnp.where(mask[None, None, None], sc, -1e30)
    p = jax.nn.softmax(sc, -1)
    o = jnp.einsum("bkgqc,bckd->bkgqd", p, v)
    return jnp.moveaxis(o, 3, 1).reshape(b, s, h, d)


@pytest.mark.parametrize("b,s,h,kvh,d,w,qc,kc", [
    (2, 64, 4, 2, 16, 0, 16, 16),
    (1, 128, 8, 8, 32, 24, 32, 16),
    (3, 96, 6, 3, 16, 7, 32, 32),
    (2, 32, 2, 1, 8, 0, 32, 8),
])
def test_blockwise_attention_matches_naive(b, s, h, kvh, d, w, qc, kc):
    kq, kk, kv_ = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (b, s, h, d))
    k = jax.random.normal(kk, (b, s, kvh, d))
    v = jax.random.normal(kv_, (b, s, kvh, d))
    pos = jnp.arange(s)
    out = blockwise_attention(q, k, v, pos, pos, window=w,
                              q_chunk=qc, kv_chunk=kc)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(_naive_attn(q, k, v, w)),
                               atol=2e-5)


def test_blockwise_attention_mla_vdim():
    """v head dim ≠ qk head dim (MLA)."""
    kq, kk, kv_ = jax.random.split(KEY, 3)
    q = jax.random.normal(kq, (2, 32, 4, 24))
    k = jax.random.normal(kk, (2, 32, 4, 24))
    v = jax.random.normal(kv_, (2, 32, 4, 8))
    pos = jnp.arange(32)
    out = blockwise_attention(q, k, v, pos, pos, q_chunk=8, kv_chunk=8)
    assert out.shape == (2, 32, 4, 8)


# ----------------------------------------------------------------------
# Prefill-cache ↔ decode equivalence (per mixer family)
# ----------------------------------------------------------------------
@pytest.mark.parametrize("arch", [
    "phi3-mini-3.8b",    # full attention
    "gemma3-27b",        # sliding-window ring buffer + global
    "minicpm3-4b",       # MLA latent cache
    "jamba-v0.1-52b",    # mamba + attention + MoE
    "xlstm-125m",        # mLSTM + sLSTM recurrent states
])
def test_prefill_then_decode_matches_all_decode(arch):
    import dataclasses
    cfg = reduced_config(get_config(arch)).with_(dtype="float32")
    if cfg.moe:
        # no-drop capacity: decode routes 1 token at a time, so per-step
        # capacity drops differ from prefill's batch routing otherwise
        cfg = cfg.with_(moe=dataclasses.replace(
            cfg.moe, capacity_factor=8.0))
    params = init_params(KP, cfg)
    b, s, extra = 2, 24, 4
    max_len = s + extra
    toks = jax.random.randint(KI, (b, s), 0, cfg.vocab_size)

    # path A: prefill with cache capture, then decode `extra` tokens
    hidden, _, caches = forward_hidden(params, toks, cfg,
                                       return_caches=True)
    caches_a = pad_caches_to(caches, cfg, s, max_len)

    # path B: token-by-token decode from scratch
    caches_b = init_cache(cfg, b, max_len, jnp.float32)
    logits_b = None
    for i in range(s):
        logits_b, caches_b = decode_step(
            params, caches_b, toks[:, i:i + 1], jnp.int32(i), cfg)

    from repro.models.layers import unembed
    logits_a = unembed(params["embed"], hidden[:, -1:], cfg)
    np.testing.assert_allclose(np.asarray(logits_a),
                               np.asarray(logits_b),
                               rtol=2e-3, atol=2e-3)

    # continue decoding — caches must agree functionally
    tok = jnp.argmax(logits_a, axis=-1).astype(jnp.int32)
    for i in range(extra):
        la, caches_a = decode_step(params, caches_a, tok,
                                   jnp.int32(s + i), cfg)
        lb, caches_b = decode_step(params, caches_b, tok,
                                   jnp.int32(s + i), cfg)
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb),
                                   rtol=2e-3, atol=2e-3)
        tok = jnp.argmax(la, axis=-1).astype(jnp.int32)
