"""CI smoke: the overlapped (async L/C) trainer on CPU.

    PYTHONPATH=src python examples/overlap_smoke.py

Runs ``LCTrainer(overlap="on")`` for 2 LC steps on a reduced model and
asserts the §7 monitors stay clean: no C step may increase its own
shifted distortion ‖(w − λ/μ) − Δ(Θ)‖², overlap or not. A violation
here means the double-buffered pipeline handed the C step inconsistent
(w, λ, μ) — the exact failure mode the overlap must not introduce.
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config, reduced_config
from repro.core import (AsVector, CompressionTask, LCAlgorithm,
                        exponential_mu_schedule)
from repro.core.schemes import AdaptiveQuantization
from repro.data import TokenStream
from repro.runtime import LCTrainer, TrainerConfig


def main():
    cfg = reduced_config(get_config("phi3-mini-3.8b")).with_(
        pattern_reps=1)
    data = TokenStream(cfg.vocab_size, 2, 16)
    lc = LCAlgorithm(
        [CompressionTask("qg", r"stages/.*/w_gate$", AsVector(),
                         AdaptiveQuantization(k=2, iters=5)),
         CompressionTask("qu", r"stages/.*/w_up$", AsVector(),
                         AdaptiveQuantization(k=2, iters=5))],
        exponential_mu_schedule(1e-3, 2.0, 2))
    trainer = LCTrainer(cfg, lc, data,
                        tcfg=TrainerConfig(steps_per_l=3, overlap="on"))
    state, lc_state = trainer.run(jax.random.PRNGKey(0))

    assert len(trainer.history) == 2, trainer.history
    for h in trainer.history:
        assert h["c_step_violations"] == [], \
            f"§7 monitor violation under overlap: {h}"
        print(f"LC step {h['lc_step']}: mu={h['mu']:.4g} "
              f"loss={h['loss']:.4f} c_step={h['c_step_ms']:.1f}ms "
              f"swap_after={h['swap_after_microbatches']} "
              f"violations={h['c_step_violations']}")
    assert int(state["step"]) == 6
    print("overlap smoke OK")


if __name__ == "__main__":
    main()
