"""Mix-and-match compression (paper Table 2, last row + Fig. 6):
prune the first layer, low-rank the second, quantize the third — plus a
single shared codebook with additive pruning, exactly the paper's
"flexibility showcase".

    PYTHONPATH=src python examples/mixed_compression.py
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from repro.core import AsIs, AsVector, CompressionTask
from repro.core.schemes import (
    AdaptiveQuantization, AdditiveCombination, ConstraintL0Pruning,
    LowRank)

from benchmarks.common import reference_problem, run_lc


def main():
    prob = reference_problem()
    print(f"reference test error: {prob.ref_test_err:.4f}")

    # paper Table 2 last row: prune l1, low-rank l2, quantize l3
    mixed = [
        CompressionTask("p1", r"l0/w$", AsVector(),
                        ConstraintL0Pruning(kappa=5000)),
        CompressionTask("lr2", r"l1/w$", AsIs(), LowRank(target_rank=10)),
        CompressionTask("q3", r"l2/w$", AsVector(),
                        AdaptiveQuantization(k=2)),
    ]
    out = run_lc(prob, mixed)
    print(f"[prune | low-rank | quantize] test error: "
          f"{out['test_err']:.4f}, ratio {out['ratio']:.1f}x")

    # paper Table 2 row 5: single codebook + additive pruning, all layers
    additive = [CompressionTask(
        "pq", r"l\d/w$", AsVector(),
        AdditiveCombination([
            ConstraintL0Pruning(kappa=2662),       # 1% of weights
            AdaptiveQuantization(k=2),
        ], iters=2))]
    out2 = run_lc(prob, additive)
    print(f"[1%-prune + quantize, additive] test error: "
          f"{out2['test_err']:.4f}, ratio {out2['ratio']:.1f}x")


if __name__ == "__main__":
    main()
