"""Quickstart: compress a model with the LC algorithm (paper Listing 1).

    PYTHONPATH=src python examples/quickstart.py

Trains a LeNet300-style MLP on synthetic classification, then compresses
it to 2-bit per-layer codebooks with the LC algorithm — the exact flow of
the paper's Listing 1/2, in JAX.
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

import jax

from repro.core import AsVector, CompressionTask, LCAlgorithm
from repro.core.schemes import AdaptiveQuantization

from benchmarks.common import (
    direct_compress, error_rate, reference_problem, run_lc)


def main():
    # 1. the reference (uncompressed) model — "w ← argmin L(w)"
    prob = reference_problem()
    print(f"reference test error: {prob.ref_test_err:.4f}")

    # 2. compression tasks: quantize every layer, own codebook (K=4)
    tasks = [
        CompressionTask(f"q{i}", rf"l{i}/w$", AsVector(),
                        AdaptiveQuantization(k=4, iters=20))
        for i in range(3)
    ]

    # 3. direct compression baseline (Θ^DC = Π(w̄), no retraining)
    dc = direct_compress(prob, tasks)
    print(f"direct-compression test error: {dc['test_err']:.4f} "
          f"(ratio {dc['ratio']:.1f}x)")

    # 4. the LC algorithm: alternate L steps (SGD + penalty) and C steps
    out = run_lc(prob, tasks, n_steps=20, iters_per_l=40)
    print(f"LC-compressed test error: {out['test_err']:.4f} "
          f"(ratio {out['ratio']:.1f}x, {out['wall_s']:.0f}s)")
    assert out["test_err"] <= dc["test_err"] + 1e-6, \
        "LC must not lose to direct compression"


if __name__ == "__main__":
    main()
