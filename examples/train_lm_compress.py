"""End-to-end driver: pretrain a ~100M-class LM for a few hundred steps
while LC-compressing it (per-layer adaptive codebooks on every scanned
weight stack), with checkpointing and fault-tolerant stepping.

    PYTHONPATH=src python examples/train_lm_compress.py \
        [--steps-per-l 20] [--lc-steps 10] [--full-100m]

Default is a CPU-sized reduced xlstm config so the example finishes in
minutes; ``--full-100m`` uses the real xlstm-125m config (TPU-scale).
"""
import argparse
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax

from repro.configs import get_config, reduced_config
from repro.core import (AsStacked, CompressionTask, LCAlgorithm,
                        exponential_mu_schedule)
from repro.core.schemes import AdaptiveQuantization
from repro.data import TokenStream
from repro.launch.mesh import make_debug_mesh
from repro.runtime import LCTrainer, TrainerConfig


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--lc-steps", type=int, default=6)
    ap.add_argument("--steps-per-l", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--full-100m", action="store_true")
    ap.add_argument("--ckpt-dir", default="/tmp/lm_compress_ckpt")
    args = ap.parse_args()

    cfg = get_config("xlstm-125m")
    if not args.full_100m:
        cfg = reduced_config(cfg)
    print(f"model: {cfg.name}, {cfg.n_layers} layers")

    data = TokenStream(cfg.vocab_size, args.batch, args.seq)
    tasks = [CompressionTask(
        "quantize-stacks",
        r"stages/.*/(wq|wk|wv|up_proj|down_proj|w)$",
        AsStacked("vector"), AdaptiveQuantization(k=16, iters=10))]
    lc = LCAlgorithm(tasks, exponential_mu_schedule(
        9e-5, 1.3, args.lc_steps))

    trainer = LCTrainer(
        cfg, lc, data, mesh=make_debug_mesh(),
        tcfg=TrainerConfig(steps_per_l=args.steps_per_l, lr=1e-3,
                           ckpt_dir=args.ckpt_dir, ckpt_every=20))
    state, lc_state = trainer.run(jax.random.PRNGKey(0))

    print("\nLC trajectory (loss should fall, distortion shrink):")
    for rec in trainer.history:
        total_dist = sum(rec["distortion"].values())
        print(f"  lc_step={rec['lc_step']:2d} mu={rec['mu']:.2e} "
              f"loss={rec['loss']:.4f} ce={rec['ce']:.4f} "
              f"distortion={total_dist:.3f} "
              f"ratio={rec['compression_ratio']:.1f}x")
    print(f"\ncheckpoints in {args.ckpt_dir}: "
          f"{trainer.ckpt.steps() if trainer.ckpt else []}")


if __name__ == "__main__":
    main()
