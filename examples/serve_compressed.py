"""Continuous-batching serving of an LC-compressed model — the paper's
deployment story end to end: define compression tasks (one per scheme
family), run the LC direct-compression init, bridge Θ into compressed
serving forms, then serve a Poisson request trace with the slot-based
engine and check parity against the densified counterpart.

    PYTHONPATH=src python examples/serve_compressed.py
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

import jax
import numpy as np

from repro.configs import get_config, reduced_config
from repro.core import AsIs, AsVector, CompressionTask, LCAlgorithm
from repro.core.schemes import (
    AdaptiveQuantization, ConstraintL0Pruning, LowRank)
from repro.models.transformer import init_params
from repro.runtime import compressed as cforms
from repro.runtime.server import (
    Request, ServingEngine, densified_for_serving,
    load_compressed_for_serving)


def main():
    # float32 + unrolled layers: exact compressed-vs-densified token
    # parity, and per-layer (non-stacked) leaves for the bridge
    cfg = dataclasses.replace(
        reduced_config(get_config("phi3-mini-3.8b")),
        pattern_reps=1, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)

    # one task per LC scheme family, all live in the same served model
    tasks = [
        CompressionTask("quant", r"ffn/w_gate", AsVector(),
                        AdaptiveQuantization(k=16)),
        CompressionTask("lowrank", r"ffn/w_up", AsIs(), LowRank(8)),
        CompressionTask("prune", r"ffn/w_down", AsVector(),
                        ConstraintL0Pruning(kappa=1000)),
    ]
    algo = LCAlgorithm(tasks, [1e-4])
    state = algo.init(params)      # Θ ← Π(w̄): direct compression

    serving, report = load_compressed_for_serving(params, state,
                                                  algo.tasks)
    print("bridged forms:")
    for task_name, forms in report.items():
        for path, form in forms.items():
            print(f"  {task_name:10s} {path:40s} -> {form}")
    dense_b = cforms.tree_weight_bytes(params)
    comp_b = cforms.tree_weight_bytes(serving)
    print(f"modeled decode HBM: {dense_b} B -> {comp_b} B "
          f"({dense_b / comp_b:.2f}x less per step)\n")

    # synthetic heavy traffic: Poisson arrivals, mixed lengths
    rng = np.random.default_rng(0)
    t, reqs = 0.0, []
    for i in range(12):
        t += float(rng.exponential(0.02))
        reqs.append(Request(
            id=i,
            prompt=rng.integers(1, cfg.vocab_size,
                                size=int(rng.integers(8, 40)))
            .astype(np.int32),
            max_new=int(rng.integers(4, 16)), arrival=t))

    engine = ServingEngine(cfg, serving, slots=4, max_len=64,
                           prefill_chunk=8)
    out = engine.run(list(reqs))
    s = out["stats"]
    print(f"served {s['requests']} requests, {s['tokens']} tokens: "
          f"{s['tokens_per_sec']:.1f} tok/s, "
          f"p50={s['p50_latency_s'] * 1e3:.0f}ms "
          f"p99={s['p99_latency_s'] * 1e3:.0f}ms")
    assert all(n == 1 for n in engine.trace_counts.values()), \
        engine.trace_counts
    print("zero decode-step recompiles across the mixed-length trace")

    # parity: the compressed engine must reproduce the densified model
    reference = densified_for_serving(params, state, algo.tasks)
    ref_out = ServingEngine(cfg, reference, slots=4, max_len=64,
                            prefill_chunk=8).run(list(reqs))
    ref = {f.id: f.tokens for f in ref_out["finished"]}
    for f in out["finished"]:
        assert np.array_equal(f.tokens, ref[f.id]), f.id
    print("parity OK: all compressed forms greedy-decode identical "
          "tokens to the densified model")


if __name__ == "__main__":
    main()
