"""Batched serving of an LC-quantized model (the paper's deployment
story): quantize all big matrices to 16-entry codebooks, then run
batched prefill + decode on the compressed weights.

    PYTHONPATH=src python examples/serve_compressed.py
"""
import sys
import os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, reduced_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import lc_param_paths
from repro.models.transformer import init_params
from repro.runtime.server import (
    Server, quantize_params_for_serving, serving_bits)


def main():
    cfg = reduced_config(get_config("phi3-mini-3.8b"))
    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)

    paths = lc_param_paths(params)
    packed, qparams = quantize_params_for_serving(params, paths, k=16)
    comp_bits, dense_bits = serving_bits(packed)
    print(f"quantized {len(paths)} matrices: "
          f"{dense_bits / 8e6:.2f} MB → {comp_bits / 8e6:.2f} MB "
          f"({dense_bits / comp_bits:.1f}× smaller)")

    prompts = jax.random.randint(key, (4, 32), 0, cfg.vocab_size,
                                 jnp.int32)
    for name, p in [("dense", params), ("lc-quantized", qparams)]:
        server = Server(cfg, p, mesh=make_debug_mesh(), max_len=64)
        t0 = time.time()
        res = server.generate(prompts, 16)
        dt = time.time() - t0
        print(f"{name:13s}: {res.tokens.shape} tokens in {dt:.2f}s, "
              f"sample={res.tokens[0][:8]}")

    # compressed-weight kernels: the TPU path streams uint8 indices
    # through kernels/quant_matmul (validated in tests); HBM per matmul:
    any_path = paths[0]
    idx, cb = packed[any_path]
    print(f"\nper-matmul HBM: bf16 {idx.size * 2} B → "
          f"uint8+codebook {idx.size + cb.size * 4} B "
          f"(~2×; 4-bit packing → 4×)")


if __name__ == "__main__":
    main()
