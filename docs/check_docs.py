"""Docs CI: execute every runnable code block and check relative links.

    PYTHONPATH=src python docs/check_docs.py

Rules:
* every fenced ```python block in README.md and docs/*.md is executed,
  top to bottom, in one namespace per file (so imports and definitions
  carry across blocks of the same document);
* annotate a block ```python no-run to exclude it (illustrative
  fragments that reference names which don't exist);
* every relative markdown link target must exist on disk (http(s) and
  mailto links are not checked — no network in the doc check).

This is what keeps the README/docs from rotting: a renamed module or a
signature change breaks this script, not a reader.
"""
from __future__ import annotations

import os
import re
import sys

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

FENCE_RX = re.compile(r"^```(\S*)([^\n]*)\n(.*?)^```\s*$",
                      re.MULTILINE | re.DOTALL)
LINK_RX = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")


def doc_files() -> list[str]:
    files = [os.path.join(REPO, "README.md")]
    docs = os.path.join(REPO, "docs")
    files += sorted(
        os.path.join(docs, f) for f in os.listdir(docs)
        if f.endswith(".md"))
    return [f for f in files if os.path.exists(f)]


def check_links(path: str, text: str) -> list[str]:
    errors = []
    base = os.path.dirname(path)
    for target in LINK_RX.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not os.path.exists(os.path.normpath(os.path.join(base, rel))):
            errors.append(f"{os.path.relpath(path, REPO)}: broken link "
                          f"-> {target}")
    return errors


def run_blocks(path: str, text: str) -> list[str]:
    errors = []
    ns: dict = {"__name__": f"docs_block_{os.path.basename(path)}"}
    n_run = 0
    for m in FENCE_RX.finditer(text):
        lang, info, body = m.group(1), m.group(2), m.group(3)
        if lang != "python" or "no-run" in info:
            continue
        n_run += 1
        line = text[:m.start()].count("\n") + 2  # first line of the body
        try:
            code = compile(body, f"{path}:block@L{line}", "exec")
            exec(code, ns)
        except Exception as e:  # noqa: BLE001 — report, don't crash
            errors.append(
                f"{os.path.relpath(path, REPO)} block at line {line}: "
                f"{type(e).__name__}: {e}")
    print(f"  {os.path.relpath(path, REPO)}: ran {n_run} python block(s)")
    return errors


def main() -> int:
    # docs examples import both `repro` (src/) and `benchmarks` (root)
    sys.path.insert(0, os.path.join(REPO, "src"))
    sys.path.insert(0, REPO)
    errors: list[str] = []
    for path in doc_files():
        with open(path, encoding="utf-8") as f:
            text = f.read()
        errors += check_links(path, text)
        errors += run_blocks(path, text)
    if errors:
        print("\n".join(["DOC CHECK FAILURES:"] + errors))
        return 1
    print("docs OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
