from repro.data.pipeline import (
    Prefetcher, TokenStream, embedding_stream, gaussian_blobs,
    teacher_classification)

__all__ = ["Prefetcher", "TokenStream", "embedding_stream",
           "gaussian_blobs", "teacher_classification"]
