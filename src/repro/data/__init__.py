from repro.data.pipeline import (
    TokenStream, embedding_stream, gaussian_blobs, teacher_classification)

__all__ = ["TokenStream", "embedding_stream", "gaussian_blobs",
           "teacher_classification"]
