"""Deterministic, seekable synthetic data pipelines.

Offline container ⇒ no external datasets. Two generators:

* ``TokenStream`` — LM pretraining stream with learnable bigram structure
  (a fixed random Markov kernel over the vocab + Zipfian unigram floor).
  ``batch_at(step)`` is a pure function of (seed, step): restarts and
  elastic re-sharding resume exactly, with zero state to checkpoint
  beyond the step counter (this is the fault-tolerance contract).
* ``teacher_classification`` — the LeNet300-analog showcase task: inputs
  x ~ N(0, I_d), labels from a fixed random 2-layer teacher MLP. An MLP
  can fit it to ~0 error, so compression-vs-error tradeoffs (paper
  Table 2 / Fig. 3) are measurable without MNIST.
"""
from __future__ import annotations

import threading
from concurrent.futures import Future
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


class Prefetcher:
    """Lookahead wrapper for seekable batch sources.

    The LC trainer's overlapped pipeline dispatches the C step at an LC
    boundary and immediately starts the next L step — whose *first
    microbatch* still pays the full host-side batch construction
    latency. ``prefetch(step)`` starts that construction on a
    background thread while the boundary work is in flight;
    ``batch_at(step)`` consumes the result (or computes directly on a
    miss — prefetching is purely an overlap optimization).

    Correctness leans on the repo's data contract: ``batch_at`` is a
    pure function of ``step``, so a prefetched batch equals the
    directly-computed one bit-for-bit, retries/restores can re-request
    any step, and entries prefetched for steps a restore rewound past
    are simply dropped when they age out. Only the trainer thread calls
    ``prefetch``/``batch_at``; the worker thread only runs the wrapped
    source. Workers are deliberately *non-daemon*: a daemon thread
    mid-jax-dispatch at interpreter teardown aborts the process inside
    XLA ("terminate called without an active exception"), while a
    non-daemon worker finishes its single batch (milliseconds) and
    exits cleanly.
    """

    #: prefetched steps kept around before the oldest is dropped (a
    #: rewind can strand entries; the slots are tiny host batches)
    MAX_SLOTS = 4

    def __init__(self, source):
        self._source = source
        self._fetch = (source.batch_at if hasattr(source, "batch_at")
                       else source)
        self._pending: dict[int, Future] = {}
        self._lock = threading.Lock()

    def prefetch(self, step: int) -> None:
        """Start computing ``batch_at(step)`` in the background
        (idempotent per step)."""
        step = int(step)
        with self._lock:
            if step in self._pending:
                return
            fut: Future = Future()
            self._pending[step] = fut
            while len(self._pending) > self.MAX_SLOTS:
                self._pending.pop(next(iter(self._pending)))

        def work():
            try:
                fut.set_result(self._fetch(step))
            except BaseException as e:  # surfaced on consumption
                fut.set_exception(e)

        threading.Thread(target=work, daemon=False).start()

    def batch_at(self, step: int):
        with self._lock:
            fut = self._pending.pop(int(step), None)
        if fut is not None:
            return fut.result()
        return self._fetch(int(step))


@dataclass
class TokenStream:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    n_states: int = 256   # Markov structure lives on vocab % n_states
    temperature: float = 1.0

    def __post_init__(self):
        key = jax.random.PRNGKey(self.seed)
        k1, k2 = jax.random.split(key)
        n = min(self.n_states, self.vocab_size)
        self._n = n
        # sparse-ish Markov kernel over n states
        self._trans = jax.random.normal(k1, (n, n)) * 2.0
        # Zipfian unigram over the full vocab
        ranks = jnp.arange(1, self.vocab_size + 1, dtype=jnp.float32)
        self._unigram = -jnp.log(ranks)
        self._proj = k2

    def _sample_seq(self, key, length):
        n = self._n

        def step(tok, k):
            logits = self._trans[tok % n]
            nxt_state = jax.random.categorical(k, logits / self.temperature)
            # lift state to vocab id with Zipf-weighted residue
            kk = jax.random.fold_in(k, 1)
            block = jax.random.categorical(
                kk, self._unigram[:self.vocab_size // n * n:n])
            nxt = (block * n + nxt_state) % self.vocab_size
            return nxt, nxt

        keys = jax.random.split(key, length)
        t0 = jax.random.randint(key, (), 0, self.vocab_size)
        _, toks = jax.lax.scan(step, t0, keys)
        return toks

    def batch_at(self, step: int) -> dict:
        """Pure function of step — seekable/restartable."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed),
                                 int(step) + 1)
        keys = jax.random.split(key, self.batch)
        toks = jax.vmap(lambda k: self._sample_seq(k, self.seq_len + 1))(
            keys)
        return {"inputs": toks[:, :-1].astype(jnp.int32),
                "labels": toks[:, 1:].astype(jnp.int32)}


def teacher_classification(n: int, d: int = 784, classes: int = 10,
                           hidden: int = 64, seed: int = 7):
    """(x (n,d), y (n,)) from a fixed random teacher MLP."""
    key = jax.random.PRNGKey(seed)
    kx, k1, k2 = jax.random.split(key, 3)
    x = jax.random.normal(kx, (n, d))
    w1 = jax.random.normal(k1, (d, hidden)) / np.sqrt(d)
    w2 = jax.random.normal(k2, (hidden, classes)) / np.sqrt(hidden)
    y = jnp.argmax(jnp.tanh(x @ w1) @ w2, axis=-1)
    return x, y.astype(jnp.int32)


def gaussian_blobs(n: int, d: int = 784, classes: int = 10,
                   sigma: float = 1.0, seed: int = 7):
    """Class-conditional Gaussians — learnable to ~0 error (the MNIST
    stand-in for the LeNet300 showcase; paper-like ref errors)."""
    key = jax.random.PRNGKey(seed)
    km, kx, ky = jax.random.split(key, 3)
    means = jax.random.normal(km, (classes, d))
    y = jax.random.randint(ky, (n,), 0, classes)
    x = means[y] + sigma * jax.random.normal(kx, (n, d))
    return x, y.astype(jnp.int32)


def embedding_stream(batch: int, seq_len: int, d_input: int,
                     vocab_size: int, seed: int = 0):
    """Stub modality frontend stream (VLM patches / audio frames):
    precomputed embeddings + token labels."""
    def batch_at(step: int) -> dict:
        key = jax.random.fold_in(jax.random.PRNGKey(seed), int(step) + 1)
        ke, kl = jax.random.split(key)
        return {
            "inputs": jax.random.normal(
                ke, (batch, seq_len, d_input), jnp.bfloat16),
            "labels": jax.random.randint(
                kl, (batch, seq_len), 0, vocab_size, jnp.int32),
        }
    return batch_at
