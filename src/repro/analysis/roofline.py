"""Roofline terms from a compiled dry-run artifact.

    compute    = global_FLOPs / (chips · peak_FLOPs)   [s]
    memory     = global_bytes / (chips · HBM_bw)       [s]
    collective = per-chip collective bytes / link_bw   [s]
                 (== global collective bytes / (chips · link_bw), since
                 post-SPMD HLO shapes are already per-device)

``compiled.cost_analysis()`` on an SPMD executable reports the per-device
module, so flops/bytes are per-chip; we report both conventions and
time-per-step directly (time = per-chip work / per-chip peak).

Collective bytes are NOT in cost_analysis — we parse the post-partitioning
HLO text and sum the output-shape bytes of every all-gather / all-reduce /
reduce-scatter / all-to-all / collective-permute op, split by primitive.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

from repro.analysis.cost import TPU_V5E

# TPU v5e-class hardware constants (per chip) — the dry-run target this
# module always modeled. Sourced from ``analysis/cost.HardwareSpec``
# now that the planner owns hardware detection; values are unchanged.
PEAK_FLOPS = TPU_V5E.peak_flops   # bf16
HBM_BW = TPU_V5E.hbm_bw           # bytes/s
LINK_BW = TPU_V5E.link_bw         # bytes/s per ICI link

_DTYPE_BYTES = {
    "pred": 1, "s4": 0.5, "u4": 0.5, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "s32": 4, "u32": 4, "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# one result shape: `bf16[8,128,2048]{2,1,0}` (layout suffix optional)
_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?[\w.\-]+\s*=\s*(\([^)]*\)|\w+\[[\d,]*\][^\s]*)\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|"
    r"collective-permute)\(")


def _shape_bytes(shape_str: str) -> float:
    total = 0.0
    for dt, dims in _SHAPE_RE.findall(shape_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict[str, float]:
    """Per-device bytes by collective primitive from partitioned HLO."""
    out: dict[str, float] = {c: 0.0 for c in _COLLECTIVES}
    out["count"] = 0
    for line in hlo_text.splitlines():
        m = _OP_RE.match(line)
        if not m:
            continue
        shape_str, op = m.groups()
        op = op.replace("-start", "")
        out[op] += _shape_bytes(shape_str)
        out["count"] += 1
    return out


@dataclass
class RooflineTerms:
    arch: str
    shape: str
    mesh: str
    chips: int
    flops_per_chip: float
    bytes_per_chip: float
    coll_bytes_per_chip: float
    coll_breakdown: dict = field(default_factory=dict)
    model_flops: float = 0.0          # 6·N·D (N = active params)
    peak_bytes_per_chip: float = 0.0  # memory_analysis, if available

    @property
    def t_compute(self) -> float:
        return self.flops_per_chip / PEAK_FLOPS

    @property
    def t_memory(self) -> float:
        return self.bytes_per_chip / HBM_BW

    @property
    def t_collective(self) -> float:
        return self.coll_bytes_per_chip / LINK_BW

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.t_compute, "memory": self.t_memory,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def useful_flops_ratio(self) -> float:
        """MODEL_FLOPS / global HLO FLOPs — remat/waste indicator."""
        total = self.flops_per_chip * self.chips
        return self.model_flops / total if total else 0.0

    @property
    def roofline_fraction(self) -> float:
        """useful time at peak / modelled step time (max of terms)."""
        t_star = self.model_flops / (self.chips * PEAK_FLOPS)
        t = max(self.t_compute, self.t_memory, self.t_collective)
        return t_star / t if t else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch, "shape": self.shape, "mesh": self.mesh,
            "chips": self.chips,
            "flops_per_chip": self.flops_per_chip,
            "bytes_per_chip": self.bytes_per_chip,
            "coll_bytes_per_chip": self.coll_bytes_per_chip,
            "t_compute_s": self.t_compute,
            "t_memory_s": self.t_memory,
            "t_collective_s": self.t_collective,
            "bottleneck": self.bottleneck,
            "model_flops": self.model_flops,
            "useful_flops_ratio": self.useful_flops_ratio,
            "roofline_fraction": self.roofline_fraction,
            "peak_bytes_per_chip": self.peak_bytes_per_chip,
            "coll_breakdown": self.coll_breakdown,
        }


def model_flops_train(cfg, tokens: int) -> float:
    """6·N_active·D for a train step (fwd+bwd)."""
    n = cfg.active_param_count() if cfg.moe else cfg.param_count()
    return 6.0 * n * tokens


def model_flops_decode(cfg, batch: int, context: int) -> float:
    """2·N_active per token + attention KV reads ≈ 2·N + 2·kv_flops."""
    n = cfg.active_param_count() if cfg.moe else cfg.param_count()
    # per-token attention score+value FLOPs against the cache
    kv_flops = 0.0
    for spec in cfg.all_layer_specs():
        if spec.mixer == "attn":
            ctx = min(spec.window, context) if spec.window else context
            kv_flops += 2 * 2 * cfg.n_heads * cfg.head_dim * ctx
        elif spec.mixer == "mla":
            m = cfg.mla
            kv_flops += 2 * cfg.n_heads * context * (
                m.kv_lora_rank * 2 + m.qk_rope_dim)
    return batch * (2.0 * n + kv_flops)


def model_flops_prefill(cfg, tokens: int) -> float:
    n = cfg.active_param_count() if cfg.moe else cfg.param_count()
    return 2.0 * n * tokens


def fused_attention_bytes(cfg, shape_cfg, chips: int) -> float:
    """Analytic per-chip boundary I/O of the flash-attention kernel.

    train/prefill: q, k, v, o tiles in bf16, ×4 passes for training
    (fwd + remat recompute + bwd reads/writes), ×1 for prefill.
    decode (flash-decoding): the KV-cache read dominates — per step the
    kernel streams the whole (window-clamped) cache once."""
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    total = 0.0
    if shape_cfg.kind == "decode":
        for spec in cfg.all_layer_specs():
            if spec.mixer == "attn":
                ctx = min(spec.window, s) if spec.window else s
                total += 2 * b * ctx * cfg.kv_dim * 2      # K + V bf16
            elif spec.mixer == "mla":
                m = cfg.mla
                total += b * s * (m.kv_lora_rank + m.qk_rope_dim) * 2
        return total / chips
    passes = 4.0 if shape_cfg.kind == "train" else 1.0
    for spec in cfg.all_layer_specs():
        if spec.mixer == "attn":
            q = b * s * cfg.q_dim * 2
            kv = 2 * b * s * cfg.kv_dim * 2
            o = b * s * cfg.q_dim * 2
        elif spec.mixer == "mla":
            m = cfg.mla
            q = b * s * cfg.n_heads * (m.qk_nope_dim + m.qk_rope_dim) * 2
            kv = b * s * cfg.n_heads * (m.qk_nope_dim + m.qk_rope_dim
                                        + m.v_head_dim) * 2
            o = b * s * cfg.n_heads * m.v_head_dim * 2
        else:
            continue
        total += (q + kv + o) * passes
    return total / chips


def analyze(compiled, *, arch: str, shape: str, mesh_name: str,
            chips: int, model_flops: float, skip_scopes: tuple = (),
            extra_bytes_per_chip: float = 0.0) -> RooflineTerms:
    # trip-count-aware analysis (XLA's HloCostAnalysis counts while bodies
    # once — useless for scanned layer stacks; see hlo_stats.py)
    from repro.analysis.hlo_stats import analyze_hlo
    hlo = compiled.as_text()
    st = analyze_hlo(hlo, skip_scopes=skip_scopes)
    st.bytes += extra_bytes_per_chip
    st.bytes_major += extra_bytes_per_chip
    flops = st.flops
    byts = st.bytes
    coll = dict(st.coll)
    coll["count"] = st.coll_count
    peak_bytes = 0.0
    try:
        ma = compiled.memory_analysis()
        if ma is not None:
            peak_bytes = float(
                getattr(ma, "temp_size_in_bytes", 0)
                + getattr(ma, "argument_size_in_bytes", 0)
                + getattr(ma, "output_size_in_bytes", 0)
                - getattr(ma, "alias_size_in_bytes", 0))
    except Exception:
        pass
    total_coll = sum(v for k, v in coll.items() if k != "count")
    return RooflineTerms(
        arch=arch, shape=shape, mesh=mesh_name, chips=chips,
        flops_per_chip=flops, bytes_per_chip=byts,
        coll_bytes_per_chip=total_coll, coll_breakdown=coll,
        model_flops=model_flops, peak_bytes_per_chip=peak_bytes)
