from repro.analysis import roofline

__all__ = ["roofline"]
