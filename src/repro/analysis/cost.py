"""Roofline cost model + group planner core for the C-step engine.

This module holds the machinery behind the cost-model-driven group
planner (`core/grouping.py` wires it in at ``build_groups``/
``grouped_compress`` time):

* :class:`HardwareSpec` — peak FLOPs / HBM / interconnect / VMEM
  constants per device kind, detected from ``jax.devices()`` instead of
  the v5e literals that used to live in ``analysis/roofline.py``.
* :class:`GroupPlan` — the per-group decision record: dispatch backend,
  Pallas items-grid tile rows, chunk count, shard mode, and the modeled
  roofline terms that justified them.
* ``plan_group(...)`` — the planner: an analytic first pass (per-solver
  FLOP/byte factors over the packed abstract shapes) optionally refined
  by lowering the chosen program once and running
  ``analysis/hlo_stats.analyze_hlo`` over the HLO text.
* The **plan cache** and **executable cache** — keyed by the group
  signature ``(scheme batch_key, item shape/dtype, n_items, operand
  treedef, mesh fingerprint, backend, hardware)`` so repeated LC
  boundaries pay zero re-lower/re-trace.  ``cache_stats()`` exposes
  hit/miss counters; ``lint/trace_count.check_planner_cache`` and
  ``benchmarks/bench_roofline.py`` assert the miss count stays flat
  across boundaries.

The module deliberately does NOT import ``core.grouping`` (grouping
imports us); lowering callables are passed in by the caller.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import numpy as np


# ---------------------------------------------------------------------------
# Hardware specs
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class HardwareSpec:
    """Peak-rate constants for one device kind.

    ``match`` is a lowercase substring matched against
    ``device.device_kind`` by :func:`detect_hardware`.
    """

    name: str
    match: str
    peak_flops: float      # f32-equivalent FLOP/s per chip
    hbm_bw: float          # bytes/s per chip
    link_bw: float         # interconnect bytes/s per chip (one direction)
    vmem_bytes: int        # fast on-chip memory per core
    hbm_bytes: int         # device memory per chip

    @property
    def ridge_intensity(self) -> float:
        """FLOP/byte above which a kernel is compute-bound."""
        return self.peak_flops / self.hbm_bw


# The v5e numbers are the literals `analysis/roofline.py` shipped with;
# roofline.py now re-exports them from here so dry-run behaviour is
# unchanged by the refactor.
TPU_V4 = HardwareSpec("tpu-v4", "tpu v4", 275e12, 1228e9, 75e9,
                      16 * 2**20, 32 * 2**30)
TPU_V5E = HardwareSpec("tpu-v5e", "tpu v5e", 197e12, 819e9, 50e9,
                       16 * 2**20, 16 * 2**30)
TPU_V5P = HardwareSpec("tpu-v5p", "tpu v5", 459e12, 2765e9, 100e9,
                       16 * 2**20, 95 * 2**30)
TPU_V6E = HardwareSpec("tpu-v6e", "tpu v6", 918e12, 1640e9, 100e9,
                       32 * 2**20, 32 * 2**30)
# CPU numbers are deliberately coarse (one modern server socket); they
# only need to rank alternatives sensibly, not predict wall clock.
CPU = HardwareSpec("cpu", "cpu", 1e12, 100e9, 25e9,
                   32 * 2**20, 64 * 2**30)

_KNOWN = (TPU_V4, TPU_V6E, TPU_V5P, TPU_V5E)  # order: most-specific match


def detect_hardware(devices=None) -> HardwareSpec:
    """Map ``jax.devices()`` onto a :class:`HardwareSpec`.

    Unknown TPU kinds default to :data:`TPU_V5E` (the repo's historic
    dry-run target); anything else falls back to :data:`CPU`.
    """
    if devices is None:
        devices = jax.devices()
    if not devices:
        return CPU
    kind = getattr(devices[0], "device_kind", "cpu").lower()
    platform = getattr(devices[0], "platform", "cpu").lower()
    for spec in _KNOWN:
        if spec.match in kind:
            return spec
    if platform == "tpu" or "tpu" in kind:
        return TPU_V5E
    return CPU


# ---------------------------------------------------------------------------
# The plan record
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class GroupPlan:
    """One group's planner decisions plus the cost terms behind them.

    ``source`` is ``"analytic"`` when only the closed-form estimate ran
    and ``"hlo"`` when the lowered program was analyzed; ``fallbacks``
    records every decision the planner wanted but could not apply (the
    Layer-3 lint flags plans whose fallbacks went unreported).
    """

    backend: str                    # actual dispatch backend ("jnp"/...)
    solver: str | None              # registry solver name (None = vmap)
    block_rows: int | None          # Pallas items-grid tile rows
    n_chunks: int                   # launches the packed group splits into
    shard_mode: str                 # "gspmd" | "shard_map" | "none"
    flops: float                    # modeled FLOPs for the whole group
    bytes: float                    # modeled HBM traffic (bytes)
    coll_bytes: float               # modeled collective traffic (bytes)
    t_compute: float                # seconds at peak_flops
    t_memory: float                 # seconds at hbm_bw
    t_collective: float             # seconds at link_bw
    working_set_bytes: int          # packed operands + outputs resident
    source: str                     # "analytic" | "hlo"
    fallbacks: tuple[str, ...]      # decisions not applied, with reasons
    hardware: str                   # HardwareSpec.name used

    @property
    def modeled_ms(self) -> float:
        return 1e3 * max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def bottleneck(self) -> str:
        t = {"compute": self.t_compute, "memory": self.t_memory,
             "collective": self.t_collective}
        return max(t, key=t.get)

    def as_dict(self) -> dict:
        d = dataclasses.asdict(self)
        d["modeled_ms"] = self.modeled_ms
        d["bottleneck"] = self.bottleneck
        return d


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

_PLAN_CACHE: dict[tuple, GroupPlan] = {}
_EXEC_CACHE: dict[tuple, Any] = {}
_STATS = {"plan_hits": 0, "plan_misses": 0,
          "exec_hits": 0, "exec_misses": 0}


def cache_stats() -> dict:
    """Copy of the hit/miss counters (lint + bench assert on these)."""
    return dict(_STATS, plan_entries=len(_PLAN_CACHE),
                exec_entries=len(_EXEC_CACHE))


def clear_caches() -> None:
    _PLAN_CACHE.clear()
    _EXEC_CACHE.clear()
    for k in _STATS:
        _STATS[k] = 0


def _leaf_sig(x) -> tuple:
    return (tuple(getattr(x, "shape", ())),
            str(getattr(x, "dtype", type(x).__name__)))


def _mesh_fingerprint(mesh) -> tuple:
    if mesh is None:
        return ()
    return (tuple(mesh.axis_names), tuple(mesh.devices.shape),
            tuple(int(d.id) for d in mesh.devices.flat))


def plan_key(signature, n_items, arrays, mesh, backend,
             hw: HardwareSpec | None = None) -> tuple:
    """Cache key for a group's plan/executable.

    ``signature`` is the group's ``group_signature`` tuple (scheme
    batch_key + item shape/dtype + view kind); ``arrays`` the packed
    operand pytree (abstract or concrete — only shapes/dtypes and the
    treedef are hashed).
    """
    hw = hw or detect_hardware()
    if signature is None:
        signature = ("ungrouped",)
    leaves, treedef = jax.tree_util.tree_flatten(arrays)
    return (tuple(signature), int(n_items),
            tuple(_leaf_sig(x) for x in leaves), str(treedef),
            _mesh_fingerprint(mesh), str(backend), hw.name)


# ---------------------------------------------------------------------------
# Analytic cost model
# ---------------------------------------------------------------------------

# Coarse FLOPs-per-input-element factors per registry solver. They only
# need to be the right order of magnitude: the planner compares a
# handful of discrete alternatives, and the HLO refinement pass
# replaces them with counted FLOPs where lowering is available.
def _solver_flop_factor(solver: str | None, signature) -> float:
    if solver == "kmeans_lloyd":
        # iters × (K distances + onehot moments) per element
        k = _sig_field(signature, "k", 4)
        iters = _sig_field(signature, "iters", 25)
        return 3.0 * float(k) * float(iters)
    if solver == "topk_mask":
        return 2.0 * 30.0            # bisection feasibility sweeps
    if solver in ("lowrank_rsvd", "rank_select"):
        # sketch + power iters + finisher ≈ (2·POWER+2)·k matmul passes
        k = _sig_field(signature, "max_rank", 16) + 16
        return 2.0 * 8.0 * float(k) / 8.0
    if solver in ("project_l1_ball", "soft_threshold"):
        return 10.0                  # sort-dominated / elementwise
    return 20.0                      # unknown solver / vmap fallback


def _sig_field(signature, name: str, default):
    """Best-effort scalar pull from a group signature tuple (they carry
    scheme batch_key entries like ``("quant-kmeans", 4, 25)``)."""
    flat = []

    def walk(x):
        if isinstance(x, tuple):
            for y in x:
                walk(y)
        else:
            flat.append(x)

    walk(tuple(signature))
    ints = [x for x in flat if isinstance(x, int) and not
            isinstance(x, bool)]
    if name == "k" and ints:
        return ints[0]
    if name == "iters" and len(ints) > 1:
        return ints[1]
    if name == "max_rank" and ints:
        return ints[-1]
    return default


def _tree_bytes(tree) -> int:
    total = 0
    for leaf in jax.tree_util.tree_leaves(tree):
        shape = getattr(leaf, "shape", None)
        dtype = getattr(leaf, "dtype", None)
        if shape is None or dtype is None:
            continue
        total += int(np.prod(shape, dtype=np.int64)) * \
            np.dtype(dtype).itemsize
    return total


def estimate_terms(signature, solver: str | None, arrays, out_shapes,
                   hw: HardwareSpec, mesh=None,
                   shard_items: bool = False) -> dict:
    """Closed-form roofline terms for one packed group.

    ``arrays`` / ``out_shapes`` are pytrees of (abstract) arrays; the
    model is per-chip when ``shard_items`` (item axis sharded over the
    mesh) else whole-group.
    """
    in_bytes = _tree_bytes(arrays)
    out_bytes = _tree_bytes(out_shapes)
    n_elems = max(1, in_bytes // 4)
    flops = _solver_flop_factor(solver, signature) * float(n_elems)
    total_bytes = float(in_bytes + out_bytes)
    chips = 1
    coll_bytes = 0.0
    if mesh is not None and mesh.devices.size > 1:
        chips = int(mesh.devices.size)
        if shard_items:
            flops /= chips
            total_bytes /= chips
        else:
            # replicated solve: every chip reads the full group and the
            # result is all-gathered conceptually — model the output
            # traffic as the collective term
            coll_bytes = float(out_bytes)
    return {
        "flops": flops,
        "bytes": total_bytes,
        "coll_bytes": coll_bytes,
        "t_compute": flops / hw.peak_flops,
        "t_memory": total_bytes / hw.hbm_bw,
        "t_collective": coll_bytes / hw.link_bw if coll_bytes else 0.0,
        "working_set_bytes": int(in_bytes + out_bytes),
        "chips": chips,
    }


def refine_with_hlo(hlo_text: str, terms: dict,
                    hw: HardwareSpec) -> dict:
    """Replace the analytic FLOP/byte counts with counted ones from the
    lowered HLO (``analysis/hlo_stats``). Collective bytes come from
    the same pass. Falls back to ``terms`` untouched on parse failure.
    """
    from repro.analysis import hlo_stats
    stats = hlo_stats.analyze_hlo(hlo_text)
    refined = dict(terms)
    if stats.flops > 0:
        refined["flops"] = float(stats.flops)
        refined["t_compute"] = stats.flops / hw.peak_flops
    if stats.bytes > 0:
        refined["bytes"] = float(stats.bytes)
        refined["t_memory"] = stats.bytes / hw.hbm_bw
    coll = float(stats.coll_bytes)
    refined["coll_bytes"] = coll
    refined["t_collective"] = coll / hw.link_bw if coll else 0.0
    return refined


# ---------------------------------------------------------------------------
# Decision helpers
# ---------------------------------------------------------------------------

#: planner-tunable tile-row candidates for the items-grid kernels
BLOCK_ROWS_CANDIDATES = (8, 16, 32)

#: below this working set the Pallas launch overhead dominates — stay
#: on the fused jnp path even on TPU
_MIN_PALLAS_BYTES = 1 << 20

#: test hook — force the chunk budget down so small groups split.
#: ``None`` means "derive from the hardware spec".
CHUNK_BUDGET_BYTES: int | None = None


def chunk_budget(hw: HardwareSpec) -> int:
    if CHUNK_BUDGET_BYTES is not None:
        return int(CHUNK_BUDGET_BYTES)
    # a packed group should leave headroom next to the train state:
    # cap its working set at 1/4 of device memory
    return hw.hbm_bytes // 4


def choose_backend(requested: str, solver: str | None,
                   registered: tuple[str, ...], terms: dict,
                   hw: HardwareSpec) -> tuple[str, list[str]]:
    """Pick the dispatch backend for a group.

    Explicit requests ("jnp"/"interpret"/"pallas") are honored — the
    planner only decides for ``"auto"``. Returns (backend, fallbacks).
    """
    fallbacks: list[str] = []
    if requested != "auto":
        return requested, fallbacks
    on_tpu = hw.name.startswith("tpu")
    if not on_tpu:
        return "jnp", fallbacks
    if "pallas" not in registered:
        if solver is not None:
            fallbacks.append(
                f"backend:pallas-unregistered-for-{solver}->jnp")
        return "jnp", fallbacks
    # memory-bound groups with a real working set win from the fused
    # items-grid kernels; tiny or compute-bound ones stay on XLA where
    # fusion already covers them
    intensity = terms["flops"] / max(terms["bytes"], 1.0)
    if terms["working_set_bytes"] >= _MIN_PALLAS_BYTES and \
            intensity < hw.ridge_intensity:
        return "pallas", fallbacks
    fallbacks.append("backend:pallas-skipped-small-or-compute-bound")
    return "jnp", fallbacks


def choose_block_rows(solver: str | None, backend: str, n_items: int,
                      item_elems: int, extra_vmem_per_row: int,
                      hw: HardwareSpec) -> tuple[int | None, list[str]]:
    """Tile rows for the items-grid Pallas kernels.

    Larger tiles amortize grid overhead; the pick is the largest
    candidate whose per-tile VMEM footprint fits in a quarter of VMEM
    and whose padding waste stays under 1/8 of the item. Off-TPU the
    kernels only ever run emulated (interpret mode), so the default
    tile is kept — tile changes reorder float accumulation, and the
    planner-on/planner-off bit-parity contract must hold on CPU.
    """
    from repro.kernels import dispatch as _dispatch
    if backend not in ("pallas", "interpret") or \
            solver not in _dispatch.TILED_SOLVERS:
        return None, []
    if not hw.name.startswith("tpu"):
        return None, []
    lanes = 128
    best = 8
    for rows in BLOCK_ROWS_CANDIDATES:
        tile_elems = rows * lanes
        vmem = tile_elems * 4 * 3 + rows * extra_vmem_per_row
        pad = (-item_elems) % tile_elems
        if vmem > hw.vmem_bytes // 4:
            continue
        if pad > max(item_elems, 1) / 8:
            continue
        best = rows
    return best, []


def choose_chunks(working_set_bytes: int, n_items: int,
                  hw: HardwareSpec) -> int:
    """Launch count for a packed group: split when the working set
    exceeds the chunk budget, never beyond one item per launch."""
    budget = max(1, chunk_budget(hw))
    n = -(-working_set_bytes // budget)      # ceil div
    return max(1, min(int(n), max(1, int(n_items))))


# ---------------------------------------------------------------------------
# The planner entry point
# ---------------------------------------------------------------------------

def plan_group(signature, n_items, arrays, out_shapes, *,
               requested_backend: str, solver: str | None,
               registered: tuple[str, ...] = (),
               gspmd_safe: bool = False, mesh=None,
               item_elems: int = 0, extra_vmem_per_row: int = 0,
               lower_fn: Callable[[str], str] | None = None,
               base_fallbacks: tuple = (),
               hw: HardwareSpec | None = None) -> GroupPlan:
    """Plan one packed group. Cached on :func:`plan_key`.

    ``lower_fn`` (optional) takes the *chosen* backend and returns the
    HLO text of the program that would run on it; when provided and
    parseable the analytic terms are replaced by counted ones
    (``source="hlo"``). ``registered`` lists the dispatch backends
    actually carrying ``solver``. ``base_fallbacks`` pre-records
    caller-side decisions (e.g. refinement deliberately skipped) so an
    analytic plan is never silent about why.
    """
    hw = hw or detect_hardware()
    key = plan_key(signature, n_items, arrays, mesh,
                   requested_backend, hw)
    cached = _PLAN_CACHE.get(key)
    if cached is not None:
        _STATS["plan_hits"] += 1
        return cached
    _STATS["plan_misses"] += 1

    shard_mode = "none"
    if mesh is not None and mesh.devices.size > 1:
        shard_mode = "gspmd" if (solver is not None and gspmd_safe) \
            else "shard_map"
    terms = estimate_terms(signature, solver, arrays, out_shapes, hw,
                           mesh=mesh, shard_items=shard_mode != "none")
    backend, fallbacks = choose_backend(requested_backend, solver,
                                        registered, terms, hw)
    fallbacks = list(base_fallbacks) + fallbacks
    block_rows, tile_fb = choose_block_rows(
        solver, backend, n_items, item_elems, extra_vmem_per_row, hw)
    fallbacks += tile_fb
    n_chunks = choose_chunks(terms["working_set_bytes"], n_items, hw)
    if n_chunks > 1 and shard_mode != "none":
        fallbacks.append("chunking-disabled-under-mesh")
        n_chunks = 1

    source = "analytic"
    if lower_fn is not None:
        try:
            hlo_text = lower_fn(backend)
            if hlo_text:
                terms = refine_with_hlo(hlo_text, terms, hw)
                source = "hlo"
        except Exception as e:  # lowering is best-effort refinement
            fallbacks.append(f"hlo-refine-failed:{type(e).__name__}")

    plan = GroupPlan(
        backend=backend, solver=solver, block_rows=block_rows,
        n_chunks=n_chunks, shard_mode=shard_mode,
        flops=terms["flops"], bytes=terms["bytes"],
        coll_bytes=terms["coll_bytes"], t_compute=terms["t_compute"],
        t_memory=terms["t_memory"], t_collective=terms["t_collective"],
        working_set_bytes=terms["working_set_bytes"], source=source,
        fallbacks=tuple(fallbacks), hardware=hw.name)
    _PLAN_CACHE[key] = plan
    return plan


# ---------------------------------------------------------------------------
# AOT executable cache
# ---------------------------------------------------------------------------

def get_executable(key: tuple, build: Callable[[], Any]):
    """Fetch (or compile-and-insert) an AOT executable for ``key``.

    ``build`` runs ``jax.jit(...).lower(...).compile()`` — exactly once
    per key; repeated LC boundaries (and even ``_build_steps()``
    rebuilds) hit the cache and pay zero re-lower/re-trace.
    """
    exe = _EXEC_CACHE.get(key)
    if exe is not None:
        _STATS["exec_hits"] += 1
        return exe
    _STATS["exec_misses"] += 1
    exe = build()
    _EXEC_CACHE[key] = exe
    return exe


# ---------------------------------------------------------------------------
# Serving-side tile chooser (quant_matmul)
# ---------------------------------------------------------------------------

def gemm_tiles(m: int, n: int, k: int, *, packed: bool = False,
               hw: HardwareSpec | None = None) -> dict:
    """Tile hints for the compressed-serving matmul kernels.

    Returns ``{"block_m", "block_n", "block_k"}`` sized so the three
    operand tiles fit a quarter of VMEM; callers clamp to their grid.
    """
    hw = hw or detect_hardware()
    budget = hw.vmem_bytes // 4
    bm, bn, bk = 128, 128, 128
    itemsize = 0.5 if packed else 4.0

    def fits(bm, bn, bk):
        return (bm * bk * 4 + bk * bn * itemsize + bm * bn * 4) <= budget

    for cand in (256, 512):
        if cand <= n and fits(bm, cand, bk):
            bn = cand
    for cand in (256, 512):
        if cand <= k and fits(bm, bn, cand):
            bk = cand
    return {"block_m": min(bm, max(8, m)),
            "block_n": min(bn, max(128, n)),
            "block_k": min(bk, max(128, k))}
