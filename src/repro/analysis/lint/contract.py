"""Layer 2: the scheme/registry contract, machine-checked.

PR 4/5 grew an informal contract between schemes and the kernel
dispatch registry (``solver`` / ``batch_operands`` / ``wants_key`` /
``gspmd_safe`` / the honest-fallback backend rules). Nothing enforced
it. This layer imports the registry and every ``CompressionScheme``
subclass and verifies the *declarations* — no solve is executed.

Rules:

``unregistered-solver``
    a scheme declares ``solver = "name"`` but the registry has no
    ``jnp`` implementation for it — the group would silently fall back
    to the vmap path forever (the backend-gap rule needs a jnp anchor).

``operand-mismatch``
    ``solver_operands`` (+ the implicit trailing ``"keys"`` when
    ``wants_key``) disagrees with the registered solver's positional
    signature, or its length disagrees with what ``batch_operands``
    actually produces — the packed operand arrays would bind to the
    wrong solver parameters.

``pallas-no-interpret``
    a solver registers a ``pallas`` backend without an ``interpret``
    one: an explicit ``"pallas"`` request off-TPU then has no honest
    fallback and hits the backend-gap jnp rule, silently switching
    algorithms (the exact thing ``resolve_backend`` promises not to do).

``solver-without-group-key``
    a scheme declares a solver while ``group_key()`` is ``None`` — the
    documented escape hatch opts out of kernel dispatch entirely, so
    the declaration is dead and misleading.

``solver-no-compress-batched``
    a scheme declares a solver but never implements
    ``compress_batched`` — ``kernel_dispatch_ready`` keeps it on the
    vmap path, so again the declaration is dead.

``init-key-missing``
    a scheme's ``init`` reads hyperparameter attributes that neither
    ``compress`` nor ``group_key`` read, without overriding
    ``init_key()``: ``grouped_init`` would merge tasks whose Θ^DC
    differ and solve the group with ``group[0]``'s init settings.

``no-contract-example``
    a scheme class provides no :meth:`contract_examples` instance, so
    layers 2/3 cannot check it — implement the classmethod (informational
    but reported: uncovered schemes are how contracts rot).
"""
from __future__ import annotations

import ast
import importlib
import inspect
import pkgutil
import textwrap

from repro.analysis.lint.findings import Finding

#: packages walked to discover CompressionScheme subclasses
SCHEME_PACKAGES = ("repro.core.schemes",)


def _rel_file(cls) -> str:
    """Repo-relative source path of a class (stable baseline identity)."""
    import os

    import repro
    try:
        f = inspect.getsourcefile(cls)
    except TypeError:
        f = None
    if not f:
        return cls.__module__
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    root = os.path.dirname(src)
    try:
        rel = os.path.relpath(os.path.abspath(f), root)
    except ValueError:
        return f
    return f if rel.startswith("..") else rel


def discover_scheme_classes(packages=SCHEME_PACKAGES) -> list[type]:
    """Import every module under ``packages`` and return the
    CompressionScheme subclasses *defined there* (transitively walked,
    then filtered by module — live ``__subclasses__`` also sees test
    fixtures and REPL experiments), deterministic order."""
    from repro.core.schemes.base import CompressionScheme

    for pkg_name in packages:
        pkg = importlib.import_module(pkg_name)
        for info in pkgutil.iter_modules(pkg.__path__):
            importlib.import_module(f"{pkg_name}.{info.name}")

    prefixes = tuple(p + "." for p in packages) + tuple(packages)
    out, stack = [], [CompressionScheme]
    while stack:
        cls = stack.pop()
        for sub in cls.__subclasses__():
            if sub.__module__.startswith(prefixes):
                out.append(sub)
            stack.append(sub)
    return sorted(set(out), key=lambda c: (c.__module__, c.__name__))


def _self_attr_reads(fn, cls) -> set[str]:
    """Names of non-method ``self.X`` attribute loads in ``fn``'s body."""
    try:
        src = textwrap.dedent(inspect.getsource(fn))
        tree = ast.parse(src)
    except (OSError, TypeError, SyntaxError):
        return set()
    reads = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Attribute) \
                and isinstance(node.value, ast.Name) \
                and node.value.id == "self" \
                and not callable(getattr(cls, node.attr, None)):
            reads.add(node.attr)
    return reads


def _provider(cls, name):
    for c in cls.__mro__:
        if name in c.__dict__:
            return c
    return None


def check_schemes(classes=None, registry=None) -> list[Finding]:
    """Run every contract rule. ``classes``/``registry`` default to the
    discovered scheme classes and the live dispatch registry (tests pass
    explicit ones)."""
    from repro.core.schemes.base import CompressionScheme
    from repro.kernels import dispatch

    if classes is None:
        classes = discover_scheme_classes()
    if registry is None:
        registry = dispatch.registry_entries()

    findings: list[Finding] = []

    # --- registry-wide: honest-fallback rule -------------------------
    for solver, impls in sorted(registry.items()):
        if "pallas" in impls and "interpret" not in impls:
            findings.append(Finding(
                "pallas-no-interpret", "registry", solver,
                "solver registers a pallas backend without an interpret "
                "one: an explicit pallas request off-TPU then silently "
                "switches to the jnp algorithm via the backend-gap rule "
                "instead of emulating the kernel; register the same "
                "kernel with interpret=True", layer="contract"))

    # --- per-class rules ---------------------------------------------
    for cls in classes:
        if cls is CompressionScheme:
            continue
        rel = _rel_file(cls)
        examples = cls.contract_examples()
        if not examples:
            findings.append(Finding(
                "no-contract-example", rel, cls.__name__,
                "contract_examples() returns no instance, so the "
                "contract and HLO layers cannot cover this scheme; "
                "override the classmethod with one cheap instance",
                layer="contract"))

        # inherited declarations are checked on the declaring class
        solver = cls.__dict__.get("solver", None)
        if solver is not None:
            impls = registry.get(solver, {})
            if "jnp" not in impls:
                findings.append(Finding(
                    "unregistered-solver", rel, cls.__name__,
                    f"declared solver {solver!r} has no registered jnp "
                    "backend — kernel dispatch will silently fall back "
                    "to the vmap path for every group of this scheme; "
                    "register a jnp implementation or drop the "
                    "declaration", layer="contract"))
            if _provider(cls, "compress_batched") is CompressionScheme:
                findings.append(Finding(
                    "solver-no-compress-batched", rel, cls.__name__,
                    f"declares solver {solver!r} but never implements "
                    "compress_batched(); kernel_dispatch_ready() keeps "
                    "it on the vmap path, so the declaration is dead",
                    layer="contract"))

            sig = dispatch.solver_signature(solver) \
                if "jnp" in registry.get(solver, {}) else None
            declared = tuple(cls.solver_operands)
            if cls.wants_key:
                declared = declared + ("keys",)
            if sig is not None:
                missing = [n for n in declared if n not in sig]
                if missing:
                    findings.append(Finding(
                        "operand-mismatch", rel, cls.__name__,
                        f"solver_operands names {missing} are not "
                        f"positional parameters of the registered "
                        f"{solver!r} jnp solver (signature: "
                        f"{list(sig)}); the packed operand arrays "
                        "would bind to the wrong parameters",
                        layer="contract"))
            for ex in examples:
                try:
                    n_ops = len(ex.batch_operands(2))
                except Exception:
                    continue
                n_decl = n_ops if not cls.wants_key else n_ops + 1
                if ex.batch_key() is not None \
                        and len(declared) != n_decl:
                    findings.append(Finding(
                        "operand-mismatch", rel, cls.__name__,
                        f"solver_operands declares {len(declared)} "
                        f"name(s) {list(declared)} but batch_operands() "
                        f"produces {n_ops} array(s)"
                        + (" plus the engine-appended keys operand"
                           if cls.wants_key else "")
                        + "; declare one name per operand, in solver-"
                        "signature order", layer="contract"))
                    break

            for ex in examples:
                if ex.group_key() is None:
                    findings.append(Finding(
                        "solver-without-group-key", rel, cls.__name__,
                        f"declares solver {solver!r} but group_key() is "
                        "None (the documented fully-custom escape "
                        "hatch), which opts out of kernel dispatch — "
                        "the declaration is dead; drop it or implement "
                        "group_key", layer="contract"))
                    break

        # --- init-only hyperparameters must extend init_key ----------
        init_fn = cls.__dict__.get("init")
        if init_fn is not None and _provider(cls, "init_key") is \
                CompressionScheme:
            init_reads = _self_attr_reads(init_fn, cls)
            other = set()
            for name in ("compress", "group_key", "batch_key",
                         "batch_operands"):
                fn = _provider(cls, name)
                if fn is not None and fn is not CompressionScheme:
                    other |= _self_attr_reads(fn.__dict__[name], cls)
            init_only = init_reads - other
            if init_only:
                findings.append(Finding(
                    "init-key-missing", rel, cls.__name__,
                    f"init() reads hyperparameters {sorted(init_only)} "
                    "that compress()/group_key() never read, but "
                    "init_key() is not overridden: grouped_init would "
                    "merge tasks whose direct compression differs and "
                    "solve them with group[0]'s settings; extend "
                    "init_key() with these hyperparameters",
                    layer="contract"))
    return findings
