"""Three-layer static analysis for the LC engine.

The scheme/dispatch contract that makes compressions pluggable is easy
to violate silently (a ``float()`` on a traced Θ leaf, a solver name
with no registered backend, a LAPACK custom-call under plain GSPMD).
This package machine-checks it:

* Layer 1 — AST rules over the source tree (``ast_rules``),
* Layer 2 — scheme/registry declaration checks (``contract``),
* Layer 3 — lowered-HLO rules + retrace counting (``hlo_rules``,
  ``trace_count``).

CLI: ``python -m repro.analysis.lint`` (see ``cli``); rule table and
suppression story: docs/extending.md, "The lint contract".
"""
from repro.analysis.lint.findings import Baseline, Finding, Report
from repro.analysis.lint.cli import run_lint

__all__ = ["Baseline", "Finding", "Report", "run_lint"]
