"""``python -m repro.analysis.lint`` — run the three-layer linter.

Exit status is the contract CI relies on: 0 when every finding is
covered by the committed baseline (``lint_baseline.json`` at the repo
root), 1 when any *new* finding exists, 2 when the linter itself broke.

Layers (``--layers``):

========  ============================================================
ast       plain-AST rules over the source tree (no imports)
contract  scheme/registry declaration checks (imports, no compute)
hlo       lower every registered solver + scheme family C step, run
          the HLO rules (tracing only, no solves execute)
trace     run 2 tiny LC boundaries and count retraces (executes a few
          KB-sized solves; the only layer that computes anything)
========  ============================================================

Typical invocations::

    python -m repro.analysis.lint                       # full run
    python -m repro.analysis.lint --layers ast,contract # fast subset
    python -m repro.analysis.lint --json report.json    # CI artifact
    python -m repro.analysis.lint --write-baseline      # accept current
"""
from __future__ import annotations

import argparse
import json
import os
import sys

from repro.analysis.lint.findings import Baseline, Report

ALL_LAYERS = ("ast", "contract", "hlo", "trace")


def repo_root() -> str:
    """Repo root = parent of the ``src`` directory holding ``repro``."""
    import repro
    # namespace package: no __file__, use __path__
    src = os.path.dirname(os.path.abspath(list(repro.__path__)[0]))
    return os.path.dirname(src)


def _trace_findings():
    """The CLI's default retrace probe: two boundaries of a toy 2-task
    LC setup (tiny arrays — this is the only layer that executes)."""
    import jax.numpy as jnp

    from repro.analysis.lint.trace_count import check_retraces
    from repro.core.algorithm import LCAlgorithm
    from repro.core.schemes.prune import ConstraintL0Pruning
    from repro.core.schemes.quantize import AdaptiveQuantization
    from repro.core.tasks import CompressionTask
    from repro.core.views import AsStacked

    params = {
        "qa": jnp.linspace(-1.0, 1.0, 32).reshape(2, 16),
        "pb": jnp.linspace(1.0, -1.0, 32).reshape(2, 16),
    }
    tasks = [
        CompressionTask("lint/quant", "qa", AsStacked("vector"),
                        AdaptiveQuantization(k=2, iters=2)),
        CompressionTask("lint/prune", "pb", AsStacked("vector"),
                        ConstraintL0Pruning(kappa=8)),
    ]
    algo = LCAlgorithm(tasks, mu_schedule=[1e-3, 1e-2])
    lc = algo.init(params)
    return check_retraces(algo, params, lc, boundaries=2)


def _planner_findings():
    """Planner-cache probe: a 4-task LC with two real multi-task groups
    (2× quant, 2× prune — mixed κ packs via the per-item operand), run
    planner-on across 3 boundaries plus a forced jit rebuild; every
    re-trace must hit the plan cache (zero re-plans)."""
    import jax.numpy as jnp

    from repro.analysis.lint.trace_count import check_planner_cache
    from repro.core.algorithm import LCAlgorithm
    from repro.core.schemes.prune import ConstraintL0Pruning
    from repro.core.schemes.quantize import AdaptiveQuantization
    from repro.core.tasks import CompressionTask
    from repro.core.views import AsStacked

    params = {
        "qa": jnp.linspace(-1.0, 1.0, 32).reshape(2, 16),
        "qb": jnp.linspace(-3.0, 3.0, 32).reshape(2, 16),
        "pa": jnp.linspace(1.0, -1.0, 32).reshape(2, 16),
        "pb": jnp.linspace(2.0, -2.0, 32).reshape(2, 16),
    }
    tasks = [
        CompressionTask("lint/quant/a", "qa", AsStacked("vector"),
                        AdaptiveQuantization(k=2, iters=2)),
        CompressionTask("lint/quant/b", "qb", AsStacked("vector"),
                        AdaptiveQuantization(k=2, iters=2)),
        CompressionTask("lint/prune/a", "pa", AsStacked("vector"),
                        ConstraintL0Pruning(kappa=8)),
        CompressionTask("lint/prune/b", "pb", AsStacked("vector"),
                        ConstraintL0Pruning(kappa=4)),
    ]
    algo = LCAlgorithm(tasks, mu_schedule=[1e-3, 1e-2], planner="on")
    lc = algo.init(params)
    return check_planner_cache(algo, params, lc, boundaries=3)


def _engine_trace_findings():
    """Retrace probe for the serving engine: a tiny one-attn-layer
    model served over a mixed-length trace; every compiled program must
    trace exactly once."""
    import numpy as np
    import jax

    from repro.analysis.lint.trace_count import check_engine_retraces
    from repro.configs.base import LayerSpec, ModelConfig
    from repro.models.transformer import init_params
    from repro.runtime.server import Request, ServingEngine

    cfg = ModelConfig(
        name="lint-serve", d_model=16, n_heads=2, n_kv_heads=2,
        head_dim=8, d_ff=32, vocab_size=64,
        pattern=(LayerSpec("attn", "dense"),), pattern_reps=1,
        attn_chunk_q=8, attn_chunk_kv=8, dtype="float32")
    params = init_params(jax.random.PRNGKey(0), cfg)
    engine = ServingEngine(cfg, params, slots=2, max_len=16,
                           prefill_chunk=4)
    rng = np.random.default_rng(0)
    reqs = [Request(id=i, prompt=rng.integers(1, 64, size=s)
                    .astype(np.int32), max_new=m, arrival=0.0)
            for i, (s, m) in enumerate([(3, 2), (5, 3), (9, 2)])]
    return check_engine_retraces(engine, reqs)


def run_lint(paths=None, layers=ALL_LAYERS, root=None) -> Report:
    """Run the requested layers and return the raw (pre-baseline)
    report. ``paths`` feeds the AST layer only (default:
    ``src/repro``)."""
    root = root or repo_root()
    report = Report()
    if "ast" in layers:
        from repro.analysis.lint.ast_rules import lint_paths
        targets = paths or [os.path.join(root, "src", "repro")]
        report.extend(lint_paths(targets, root), "ast")
    if "contract" in layers:
        from repro.analysis.lint.contract import check_schemes
        report.extend(check_schemes(), "contract")
    if "hlo" in layers:
        from repro.analysis.lint.hlo_rules import (
            check_planner_lowerings, check_scheme_lowerings,
            check_serving_lowerings, check_solvers)
        report.extend(check_solvers(), "hlo")
        report.extend(check_scheme_lowerings(), "hlo")
        report.extend(check_planner_lowerings(), "hlo")
        report.extend(check_serving_lowerings(), "hlo")
    if "trace" in layers:
        report.extend(_trace_findings(), "trace")
        report.extend(_planner_findings(), "trace")
        report.extend(_engine_trace_findings(), "trace")
    return report


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="python -m repro.analysis.lint",
        description="Three-layer static analysis for the LC engine "
                    "(AST / scheme-registry contract / lowered HLO).")
    ap.add_argument("paths", nargs="*",
                    help="files or directories for the AST layer "
                         "(default: src/repro)")
    ap.add_argument("--layers", default=",".join(ALL_LAYERS),
                    help="comma-separated subset of: "
                         + ", ".join(ALL_LAYERS))
    ap.add_argument("--json", nargs="?", const="-", default=None,
                    metavar="FILE",
                    help="emit the JSON report to FILE (or stdout)")
    ap.add_argument("--baseline", default=None, metavar="FILE",
                    help="suppression baseline "
                         "(default: <repo>/lint_baseline.json)")
    ap.add_argument("--write-baseline", action="store_true",
                    help="accept all current findings into the "
                         "baseline and exit 0")
    args = ap.parse_args(argv)

    layers = tuple(l.strip() for l in args.layers.split(",") if l.strip())
    bad = [l for l in layers if l not in ALL_LAYERS]
    if bad:
        ap.error(f"unknown layer(s) {bad}; choose from {ALL_LAYERS}")

    root = repo_root()
    baseline_path = args.baseline or os.path.join(root,
                                                  "lint_baseline.json")
    report = run_lint(args.paths or None, layers, root)

    if args.write_baseline:
        Baseline.write(baseline_path, report.findings)
        print(f"wrote {len(report.findings)} suppression(s) to "
              f"{baseline_path}")
        return 0

    report.apply_baseline(Baseline.load(baseline_path))

    if args.json is not None:
        payload = json.dumps(report.to_json(), indent=2)
        if args.json == "-":
            print(payload)
        else:
            with open(args.json, "w", encoding="utf-8") as f:
                f.write(payload + "\n")

    for f in report.findings:
        print(f.format())
    n_new, n_sup = len(report.findings), len(report.suppressed)
    tail = f" ({n_sup} baseline-suppressed)" if n_sup else ""
    if n_new:
        print(f"lint: {n_new} new finding(s){tail} "
              f"[layers: {', '.join(layers)}]")
        return 1
    print(f"lint: clean{tail} [layers: {', '.join(layers)}]")
    return 0


if __name__ == "__main__":
    sys.exit(main())
