"""Layer 1: AST rules over ``src/repro`` (plain ``ast``, no imports).

These encode the Python-side hazards this engine has actually hit (see
ISSUE/CHANGES history), scoped tightly enough to run with **zero false
positives** on the tree:

``traced-cast``
    ``float()/int()/bool()`` applied to a value flowing from scheme
    state or jit arguments. Casting a tracer forces a host transfer and
    raises ``ConcretizationTypeError`` under jit — the PR-5
    ``float(theta["rank"])`` bug class. Shape/static accesses
    (``x.shape``, ``x.ndim``, ``x.size``, ``x.dtype``) are exempt:
    shapes are static under jit.

``np-in-jit``
    a ``np.*``/``numpy.*`` call whose arguments reference a traced
    value inside a jitted function body or scheme method. numpy eagerly
    pulls tracers to host; ``np.prod(x.shape)``-style static uses are
    exempt.

``shape-derived-key``
    ``jax.random.PRNGKey(seed)`` where the seed is derived from array
    shapes. Equal-shaped arrays then share a PRNG stream (the old
    LowRank ``PRNGKey(m·7919+n)`` bug: every same-shape matrix got the
    same sketch). Keys must come from the engine (``item_keys``) or an
    explicit constant seed.

``mutable-default``
    a mutable literal (``[]``/``{}``/``set()``) as a class-level default
    on a scheme class or dataclass — shared across instances, so one
    task's state mutation leaks into every other task using the scheme.

``guard-bypass``
    a scheme subclass that overrides ``compress`` and
    ``kernel_dispatch_ready`` without providing ``compress_batched``:
    it disables the MRO guard that keeps compress-overriding subclasses
    off the batched path, so the *parent's* batched math silently runs
    for the subclass's tasks.

Scoping: "traced scope" = bodies of ``jax.jit``-decorated functions
(minus ``static_argnames``) and the traced methods of
``CompressionScheme`` subclasses (``init``/``compress``/
``compress_batched``/``decompress``/``bits``/``flops``/``distortion``).
Scheme subclasses are recognized textually per file (direct or
transitive bases named after a known scheme class); cross-file subclass
chains outside ``repro.core.schemes`` are invisible to this layer — the
contract layer covers those at import time.
"""
from __future__ import annotations

import ast
import os

from repro.analysis.lint.findings import Finding

#: scheme methods whose array parameters are traced under the C step
TRACED_METHODS = ("init", "compress", "compress_batched", "decompress",
                  "bits", "flops", "distortion")
#: parameters of those methods that are static/host-side by contract
STATIC_PARAMS = {"self", "solve", "float_bits", "orig_shape", "n_items"}
#: attribute accesses that yield static (non-traced) values under jit
STATIC_ATTRS = {"shape", "ndim", "size", "dtype"}
#: class names whose subclasses are treated as schemes (textual match
#: on the last dotted component of a base expression)
SCHEME_BASES = {"CompressionScheme"}

SUPPRESS_TOKEN = "lint: disable"


def lint_paths(paths: list[str], repo_root: str) -> list[Finding]:
    """Run every AST rule over ``paths`` (files or directories)."""
    findings: list[Finding] = []
    for path in paths:
        if os.path.isdir(path):
            for dirpath, _, names in sorted(os.walk(path)):
                for name in sorted(names):
                    if name.endswith(".py"):
                        findings += lint_file(
                            os.path.join(dirpath, name), repo_root)
        elif path.endswith(".py"):
            findings += lint_file(path, repo_root)
    return findings


def lint_file(path: str, repo_root: str) -> list[Finding]:
    with open(path, encoding="utf-8") as f:
        source = f.read()
    rel = os.path.relpath(os.path.abspath(path), repo_root)
    try:
        tree = ast.parse(source, filename=path)
    except SyntaxError as e:
        return [Finding("syntax-error", rel, "<module>",
                        f"file does not parse: {e}", e.lineno or 0)]
    return _FileLinter(tree, source, rel).run()


# ----------------------------------------------------------------------
def _base_names(cls: ast.ClassDef) -> set[str]:
    out = set()
    for b in cls.bases:
        if isinstance(b, ast.Name):
            out.add(b.id)
        elif isinstance(b, ast.Attribute):
            out.add(b.attr)
    return out


def _is_jit_decorator(dec: ast.expr) -> tuple[bool, set[str]]:
    """(is a jit decorator, static_argnames it declares).

    Matches ``@jax.jit``, ``@jit``, ``@partial(jax.jit, ...)`` and
    ``@jax.jit(...)`` forms.
    """
    def names_of(call: ast.Call) -> set[str]:
        static: set[str] = set()
        for kw in call.keywords:
            if kw.arg == "static_argnames":
                for node in ast.walk(kw.value):
                    if isinstance(node, ast.Constant) \
                            and isinstance(node.value, str):
                        static.add(node.value)
        return static

    def is_jit_ref(node: ast.expr) -> bool:
        return (isinstance(node, ast.Name) and node.id == "jit") or \
            (isinstance(node, ast.Attribute) and node.attr == "jit")

    if is_jit_ref(dec):
        return True, set()
    if isinstance(dec, ast.Call):
        if is_jit_ref(dec.func):          # @jax.jit(...)
            return True, names_of(dec)
        if (isinstance(dec.func, (ast.Name, ast.Attribute))
                and (getattr(dec.func, "id", None) == "partial"
                     or getattr(dec.func, "attr", None) == "partial")
                and dec.args and is_jit_ref(dec.args[0])):
            return True, names_of(dec)    # @partial(jax.jit, ...)
    return False, set()


class _FileLinter:
    def __init__(self, tree: ast.Module, source: str, rel: str):
        self.tree = tree
        self.lines = source.splitlines()
        self.rel = rel
        self.findings: list[Finding] = []
        self.parents: dict[ast.AST, ast.AST] = {}
        for node in ast.walk(tree):
            for child in ast.iter_child_nodes(node):
                self.parents[child] = node
        # numpy import aliases in this module ("np", "numpy", ...)
        self.np_aliases = {
            a.asname or a.name
            for node in ast.walk(tree) if isinstance(node, ast.Import)
            for a in node.names if a.name == "numpy"}
        # scheme classes: transitive closure of known bases, per file
        self.scheme_classes: set[str] = set()
        known = set(SCHEME_BASES)
        classes = [n for n in ast.walk(tree)
                   if isinstance(n, ast.ClassDef)]
        changed = True
        while changed:
            changed = False
            for cls in classes:
                if cls.name not in known and _base_names(cls) & known:
                    known.add(cls.name)
                    self.scheme_classes.add(cls.name)
                    changed = True

    # ------------------------------------------------------------------
    def run(self) -> list[Finding]:
        for node in ast.walk(self.tree):
            if isinstance(node, ast.ClassDef):
                if node.name in self.scheme_classes:
                    self._check_scheme_class(node)
                if node.name in self.scheme_classes or \
                        self._is_dataclass(node):
                    self._check_mutable_defaults(node)
            elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                self._check_function(node)
        return self.findings

    def _emit(self, rule: str, node: ast.AST, context: str, message: str):
        line = getattr(node, "lineno", 0)
        if 0 < line <= len(self.lines):
            text = self.lines[line - 1]
            if SUPPRESS_TOKEN in text:
                tail = text.split(SUPPRESS_TOKEN, 1)[1]
                if "=" not in tail or rule in tail:
                    return
        self.findings.append(
            Finding(rule, self.rel, context, message, line))

    # ------------------------------------------------------------------
    @staticmethod
    def _is_dataclass(cls: ast.ClassDef) -> bool:
        for dec in cls.decorator_list:
            name = getattr(dec, "id", None) or getattr(dec, "attr", None)
            if name is None and isinstance(dec, ast.Call):
                name = getattr(dec.func, "id", None) \
                    or getattr(dec.func, "attr", None)
            if name == "dataclass":
                return True
        return False

    def _check_mutable_defaults(self, cls: ast.ClassDef):
        for stmt in cls.body:
            value = None
            if isinstance(stmt, ast.Assign):
                value = stmt.value
            elif isinstance(stmt, ast.AnnAssign) and stmt.value is not None:
                value = stmt.value
            if value is None:
                continue
            bad = isinstance(value, (ast.List, ast.Dict, ast.Set)) or (
                isinstance(value, ast.Call)
                and isinstance(value.func, ast.Name)
                and value.func.id in ("list", "dict", "set")
                and not value.args and not value.keywords)
            if bad:
                self._emit(
                    "mutable-default", stmt, cls.name,
                    "mutable class-level default is shared across every "
                    "instance (one task's mutation leaks into all tasks "
                    "using this scheme); use dataclasses.field("
                    "default_factory=...) or set it in __init__")

    # ------------------------------------------------------------------
    def _check_scheme_class(self, cls: ast.ClassDef):
        methods = {n.name: n for n in cls.body
                   if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
        if ("compress" in methods and "kernel_dispatch_ready" in methods
                and "compress_batched" not in methods):
            self._emit(
                "guard-bypass", methods["kernel_dispatch_ready"], cls.name,
                "overrides compress() and kernel_dispatch_ready() without "
                "compress_batched(): this disables the MRO guard and lets "
                "the parent's batched solver silently run the parent's "
                "math for this subclass's tasks; either implement "
                "compress_batched or drop the kernel_dispatch_ready "
                "override")
        for name, fn in methods.items():
            if name in TRACED_METHODS:
                traced = {a.arg for a in (fn.args.args
                                          + fn.args.kwonlyargs)
                          if a.arg not in STATIC_PARAMS}
                self._check_traced_scope(fn, traced,
                                         f"{cls.name}.{name}")

    def _check_function(self, fn):
        is_jit, static = False, set()
        for dec in fn.decorator_list:
            j, s = _is_jit_decorator(dec)
            if j:
                is_jit, static = True, s
                break
        context = fn.name
        parent = self.parents.get(fn)
        if isinstance(parent, ast.ClassDef):
            context = f"{parent.name}.{fn.name}"
            if parent.name in self.scheme_classes and not is_jit:
                if fn.name in TRACED_METHODS:
                    return  # fully handled by _check_scheme_class
                self._check_prng_keys(fn, context)
                return
        if is_jit:
            traced = {a.arg for a in (fn.args.args + fn.args.kwonlyargs)
                      if a.arg != "self" and a.arg not in static}
            self._check_traced_scope(fn, traced, context)
        else:
            self._check_prng_keys(fn, context)

    # ------------------------------------------------------------------
    def _local_flow(self, fn, traced: set[str]) -> tuple[set[str],
                                                         set[str]]:
        """One forward pass over assignments: propagate tracedness and
        collect shape-derived locals.

        ``x = theta["u"]`` makes ``x`` traced; ``m, n = w.shape`` (or
        ``m = w.shape[0]``) makes ``m``/``n`` *shape-derived* — static
        under jit but a PRNG-seed hazard.
        """
        traced = set(traced)
        shape_derived: set[str] = set()
        for node in ast.walk(fn):
            if not isinstance(node, ast.Assign):
                continue
            value_traced = self._references_traced(node.value, traced)
            value_shapey = self._references_shape(node.value,
                                                  shape_derived)
            for tgt in node.targets:
                names = [n.id for n in ast.walk(tgt)
                         if isinstance(n, ast.Name)]
                for n in names:
                    if value_traced:
                        traced.add(n)
                    elif value_shapey:
                        shape_derived.add(n)
        return traced, shape_derived

    def _references_traced(self, node: ast.expr, traced: set[str]) -> bool:
        """Does ``node`` read a traced name *as data* (not through a
        static ``.shape``-style access)?"""
        for n in ast.walk(node):
            if isinstance(n, ast.Name) and n.id in traced:
                if not self._under_static_attr(n, stop=node):
                    return True
        return False

    @staticmethod
    def _references_shape(node: ast.expr, shape_derived: set[str]) -> bool:
        for n in ast.walk(node):
            if isinstance(n, ast.Attribute) and n.attr in ("shape",):
                return True
            if isinstance(n, ast.Name) and n.id in shape_derived:
                return True
        return False

    def _under_static_attr(self, name: ast.Name, stop: ast.expr) -> bool:
        """True when the path from ``name`` up to ``stop`` passes
        through ``<...>.shape``/``ndim``/``size``/``dtype`` — the value
        consumed is static metadata, not the traced array."""
        node: ast.AST = name
        while node is not stop:
            parent = self.parents.get(node)
            if parent is None:
                break
            if isinstance(parent, ast.Attribute) \
                    and parent.value is node \
                    and parent.attr in STATIC_ATTRS:
                return True
            node = parent
        return False

    # ------------------------------------------------------------------
    def _check_traced_scope(self, fn, params: set[str], context: str):
        traced, shape_derived = self._local_flow(fn, params)
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            func = node.func
            # float()/int()/bool() on a traced value
            if isinstance(func, ast.Name) \
                    and func.id in ("float", "int", "bool") \
                    and node.args \
                    and self._references_traced(node.args[0], traced):
                self._emit(
                    "traced-cast", node, context,
                    f"{func.id}() applied to a traced value "
                    f"({ast.unparse(node.args[0])}): under jit this "
                    "raises ConcretizationTypeError (and outside jit it "
                    "forces a device sync); keep it as a jnp scalar — "
                    "plain arithmetic works for both traced and host "
                    "values (the PR-5 float(theta[\"rank\"]) bug class)")
            # np.* call consuming a traced value
            if isinstance(func, ast.Attribute) \
                    and isinstance(func.value, ast.Name) \
                    and func.value.id in (self.np_aliases or {"np"}):
                args = list(node.args) + [k.value for k in node.keywords]
                if any(self._references_traced(a, traced) for a in args):
                    self._emit(
                        "np-in-jit", node, context,
                        f"numpy call np.{func.attr}(...) consumes a "
                        "traced value inside a jitted scope: numpy "
                        "pulls tracers to host (ConcretizationTypeError "
                        "under jit); use the jnp equivalent")
        self._check_prng_keys(fn, context, shape_derived)

    def _check_prng_keys(self, fn, context: str,
                         shape_derived: set[str] | None = None):
        if shape_derived is None:
            _, shape_derived = self._local_flow(fn, set())
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call) or not node.args:
                continue
            func = node.func
            name = getattr(func, "attr", None) or getattr(func, "id", None)
            if name != "PRNGKey":
                continue
            if self._references_shape(node.args[0], shape_derived):
                self._emit(
                    "shape-derived-key", node, context,
                    "PRNG key seeded from an array shape: every "
                    "equal-shaped array shares the stream (the old "
                    "LowRank PRNGKey(m*7919+n) sketch-collision bug); "
                    "derive keys from the engine's per-item "
                    "CompressionTask.item_keys, or an explicit constant "
                    "seed")
