"""Finding/report/baseline plumbing for ``repro.analysis.lint``.

A *finding* is one rule violation at one location. Its identity for
baseline purposes is ``(rule, file, context)`` — deliberately
line-insensitive, so reformatting a file does not resurrect a
suppressed finding, while moving the offending code to a different
function does (the context is the enclosing ``Class.method`` /
function / checked entity).

The *baseline* is a committed JSON file (``lint_baseline.json`` at the
repo root) listing finding identities that are accepted on main. The
CLI exits nonzero only on findings **not** in the baseline, so CI fails
on new violations without forcing an immediate fix of grandfathered
ones. A clean tree keeps an empty baseline.

Inline suppression: a ``# lint: disable=<rule>`` comment on the
offending line silences that rule there (AST-layer rules only — the
contract/HLO layers have no source line to carry a comment, use the
baseline for those).
"""
from __future__ import annotations

import json
import os
from dataclasses import asdict, dataclass, field


@dataclass(frozen=True)
class Finding:
    """One rule violation.

    ``rule``     stable rule id (e.g. ``"traced-cast"``)
    ``file``     repo-relative path (or a dotted entity for non-file
                 findings, e.g. ``"registry"``)
    ``context``  enclosing function/class or checked entity name
    ``line``     1-based source line (0 when the rule has no line)
    ``message``  actionable description: what is wrong and what to do
    ``layer``    ``"ast" | "contract" | "hlo"``
    """

    rule: str
    file: str
    context: str
    message: str
    line: int = 0
    layer: str = "ast"

    @property
    def key(self) -> tuple[str, str, str]:
        return (self.rule, self.file, self.context)

    def format(self) -> str:
        loc = f"{self.file}:{self.line}" if self.line else self.file
        return f"{loc}: [{self.rule}] {self.context}: {self.message}"


@dataclass
class Report:
    findings: list[Finding] = field(default_factory=list)
    #: layers that actually ran (a layer skipped by --layers is absent)
    layers: list[str] = field(default_factory=list)
    #: findings matched by the baseline (reported, never failing)
    suppressed: list[Finding] = field(default_factory=list)

    def extend(self, findings, layer: str):
        self.layers.append(layer)
        self.findings.extend(findings)

    def apply_baseline(self, baseline: "Baseline") -> None:
        live, dead = [], []
        for f in self.findings:
            (dead if baseline.covers(f) else live).append(f)
        self.findings = live
        self.suppressed.extend(dead)

    def to_json(self) -> dict:
        return {
            "version": 1,
            "layers": self.layers,
            "counts": {
                "new": len(self.findings),
                "suppressed": len(self.suppressed),
            },
            "findings": [asdict(f) for f in self.findings],
            "suppressed": [asdict(f) for f in self.suppressed],
        }


class Baseline:
    """Committed suppression list (see module docstring)."""

    def __init__(self, entries: list[dict] | None = None,
                 path: str | None = None):
        self.path = path
        self.entries = entries or []
        self._keys = {(e["rule"], e["file"], e["context"])
                      for e in self.entries}

    @classmethod
    def load(cls, path: str) -> "Baseline":
        if not os.path.exists(path):
            return cls([], path=path)
        with open(path, encoding="utf-8") as f:
            data = json.load(f)
        return cls(data.get("suppressions", []), path=path)

    def covers(self, finding: Finding) -> bool:
        return finding.key in self._keys

    @staticmethod
    def write(path: str, findings: list[Finding]) -> None:
        data = {
            "version": 1,
            "comment": "Accepted lint findings; new findings fail CI. "
                       "Regenerate with: python -m repro.analysis.lint "
                       "--write-baseline",
            "suppressions": [
                {"rule": f.rule, "file": f.file, "context": f.context,
                 "message": f.message}
                for f in sorted(findings, key=lambda f: f.key)],
        }
        with open(path, "w", encoding="utf-8") as f:
            json.dump(data, f, indent=2, sort_keys=False)
            f.write("\n")
