"""Retrace detection for LC boundaries (Layer 3's dynamic half).

Every LC boundary runs the same jitted ``c_step``/``multiplier_step``
on identically-shaped state, so each should compile **exactly once** —
a retrace between identical boundaries means something non-hashable or
shape-unstable leaked into the trace (a Python float μ that changes
identity, a re-created mesh, a Θ whose shapes drift), and every
boundary silently pays seconds of compile time instead of
microseconds of dispatch.

The counter instruments an ``LCAlgorithm`` *instance*: the unjitted
step impls are shadowed with counting wrappers (instance attributes win
over bound methods) and ``_build_steps()`` re-wraps them in jit — after
which each jit cache miss calls the wrapped Python impl exactly once,
so the counter equals the number of traces.
"""
from __future__ import annotations

from repro.analysis.lint.findings import Finding


def instrument(algo) -> dict:
    """Attach trace counters to an LCAlgorithm; returns the live
    counter dict {"c_step": n, "multiplier_step": n}. Must be called
    before the first step (it rebuilds the jit wrappers, dropping any
    cached executables)."""
    counters = {"c_step": 0, "multiplier_step": 0}
    orig_c = algo._c_step_impl
    orig_m = algo._multiplier_step_impl

    def counting_c(params, lc):
        counters["c_step"] += 1
        return orig_c(params, lc)

    def counting_m(params, lc):
        counters["multiplier_step"] += 1
        return orig_m(params, lc)

    algo._c_step_impl = counting_c
    algo._multiplier_step_impl = counting_m
    algo._build_steps()
    return counters


def run_boundaries(algo, params, lc, boundaries: int = 2,
                   overlap: bool = False) -> dict:
    """Run ``boundaries`` identical LC boundaries (C step + multiplier
    step at the schedule's first μ) through an *instrumented* algo and
    return the final counter values. ``overlap=True`` exercises the
    async (non-donating) entry points the overlapped trainer uses."""
    counters = instrument(algo)
    mu = float(algo.mu_schedule[0])
    for k in range(boundaries):
        lc = algo.set_mu(lc, mu, k)
        if overlap:
            lc = algo.c_step_async(params, lc)
            lc = algo.multiplier_step_async(params, lc)
        else:
            lc = algo.c_step(params, lc)
            lc = algo.multiplier_step(params, lc)
    return dict(counters)


def check_engine_retraces(engine, requests,
                          context: str = "serving-traffic"
                          ) -> list[Finding]:
    """``engine-retrace`` findings for a serving engine's compiled
    programs: run a mixed-length request trace and flag any program
    that traced more than once. The continuous-batching contract is
    fixed signatures — slot count, chunk size, and cache shapes never
    vary with the traffic — so each of decode/prefill/reset must
    compile exactly once no matter how lengths and arrivals mix."""
    engine.run(list(requests))
    findings = []
    for prog, n in sorted(engine.trace_counts.items()):
        if n > 1:
            findings.append(Finding(
                "engine-retrace", "runtime/server", f"{context}:{prog}",
                f"serving {prog} program traced {n}× across one "
                "mixed-length traffic trace (expected 1): a Python "
                "value or data-dependent shape is leaking into the jit "
                "cache key — slot state must stay in fixed-shape "
                "arrays (tok/pos/active), never in traced Python "
                "scalars", layer="trace"))
    return findings


def check_planner_cache(algo, params, lc, boundaries: int = 3,
                        context: str = "planner-cache") -> list[Finding]:
    """``planner-replan`` / ``planner-inactive`` findings for the group
    planner's memoization contract.

    Runs ``boundaries`` identical LC boundaries on a planner-on algo,
    then forces a full jit rebuild + re-trace (the
    ``set_mesh``/``set_backend`` shape) and runs one more boundary. The
    retrace re-enters ``_plan_multi_group`` for every group, and every
    one of those lookups must HIT the plan cache: a miss means the plan
    key is unstable across traces (an unhashable leaking in, an
    id-based component) and each rebuild silently re-lowers/re-plans
    every group."""
    from repro.analysis import cost

    if getattr(algo, "planner", None) != "on":
        return [Finding(
            "planner-inactive", "algorithm", context,
            "planner-cache probe was handed a planner-off algo: the "
            "check is vacuous — construct the probe LCAlgorithm with "
            "planner='on'", layer="trace")]
    run_boundaries(algo, params, lc, boundaries)
    before = cost.cache_stats()
    if before["plan_entries"] == 0:
        return [Finding(
            "planner-inactive", "algorithm", context,
            "planner-on boundaries planned zero groups: the probe "
            "tasks no longer form any multi-task group, so the cache "
            "check is vacuous — give the probe ≥2 tasks per scheme "
            "family", layer="trace")]
    # a bare _build_steps() would NOT retrace — jax's shared pjit cache
    # keys on the impl function object, which is unchanged. Re-wrapping
    # through instrument() swaps in fresh closures, so the next step
    # genuinely re-traces (the set_mesh/set_backend rebuild shape).
    instrument(algo)
    mu = float(algo.mu_schedule[0])
    lc = algo.set_mu(lc, mu, 0)
    lc = algo.c_step(params, lc)
    after = cost.cache_stats()
    findings = []
    replans = after["plan_misses"] - before["plan_misses"]
    if replans > 0:
        findings.append(Finding(
            "planner-replan", "algorithm", context,
            f"{replans} group plan(s) re-planned on a jit rebuild over "
            "identical shapes (expected 0 — every lookup should hit "
            "the plan cache): the plan key is trace-unstable; check "
            "repro.analysis.cost.plan_key covers only hashable, "
            "identity-free components", layer="trace"))
    if after["plan_hits"] <= before["plan_hits"] and not replans:
        findings.append(Finding(
            "planner-replan", "algorithm", context,
            "jit rebuild produced neither plan-cache hits nor misses: "
            "the rebuilt C step no longer consults the planner — "
            "grouped_compress lost its planner wiring", layer="trace"))
    return findings


def check_retraces(algo, params, lc, boundaries: int = 2,
                   context: str = "lc-boundaries",
                   overlap: bool = False) -> list[Finding]:
    """``boundary-retrace`` findings for any step that traced more than
    once across ``boundaries`` identical boundaries."""
    counts = run_boundaries(algo, params, lc, boundaries,
                            overlap=overlap)
    findings = []
    for step, n in sorted(counts.items()):
        if n > 1:
            findings.append(Finding(
                "boundary-retrace", "algorithm", f"{context}:{step}",
                f"{step} traced {n}× across {boundaries} identical LC "
                "boundaries (expected 1): something non-hashable or "
                "shape-unstable is leaking into the jit cache key — "
                "check that μ enters as a traced scalar (set_mu) and "
                "that Θ/λ shapes are boundary-stable", layer="hlo"))
    return findings
