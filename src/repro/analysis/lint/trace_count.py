"""Retrace detection for LC boundaries (Layer 3's dynamic half).

Every LC boundary runs the same jitted ``c_step``/``multiplier_step``
on identically-shaped state, so each should compile **exactly once** —
a retrace between identical boundaries means something non-hashable or
shape-unstable leaked into the trace (a Python float μ that changes
identity, a re-created mesh, a Θ whose shapes drift), and every
boundary silently pays seconds of compile time instead of
microseconds of dispatch.

The counter instruments an ``LCAlgorithm`` *instance*: the unjitted
step impls are shadowed with counting wrappers (instance attributes win
over bound methods) and ``_build_steps()`` re-wraps them in jit — after
which each jit cache miss calls the wrapped Python impl exactly once,
so the counter equals the number of traces.
"""
from __future__ import annotations

from repro.analysis.lint.findings import Finding


def instrument(algo) -> dict:
    """Attach trace counters to an LCAlgorithm; returns the live
    counter dict {"c_step": n, "multiplier_step": n}. Must be called
    before the first step (it rebuilds the jit wrappers, dropping any
    cached executables)."""
    counters = {"c_step": 0, "multiplier_step": 0}
    orig_c = algo._c_step_impl
    orig_m = algo._multiplier_step_impl

    def counting_c(params, lc):
        counters["c_step"] += 1
        return orig_c(params, lc)

    def counting_m(params, lc):
        counters["multiplier_step"] += 1
        return orig_m(params, lc)

    algo._c_step_impl = counting_c
    algo._multiplier_step_impl = counting_m
    algo._build_steps()
    return counters


def run_boundaries(algo, params, lc, boundaries: int = 2,
                   overlap: bool = False) -> dict:
    """Run ``boundaries`` identical LC boundaries (C step + multiplier
    step at the schedule's first μ) through an *instrumented* algo and
    return the final counter values. ``overlap=True`` exercises the
    async (non-donating) entry points the overlapped trainer uses."""
    counters = instrument(algo)
    mu = float(algo.mu_schedule[0])
    for k in range(boundaries):
        lc = algo.set_mu(lc, mu, k)
        if overlap:
            lc = algo.c_step_async(params, lc)
            lc = algo.multiplier_step_async(params, lc)
        else:
            lc = algo.c_step(params, lc)
            lc = algo.multiplier_step(params, lc)
    return dict(counters)


def check_engine_retraces(engine, requests,
                          context: str = "serving-traffic"
                          ) -> list[Finding]:
    """``engine-retrace`` findings for a serving engine's compiled
    programs: run a mixed-length request trace and flag any program
    that traced more than once. The continuous-batching contract is
    fixed signatures — slot count, chunk size, and cache shapes never
    vary with the traffic — so each of decode/prefill/reset must
    compile exactly once no matter how lengths and arrivals mix."""
    engine.run(list(requests))
    findings = []
    for prog, n in sorted(engine.trace_counts.items()):
        if n > 1:
            findings.append(Finding(
                "engine-retrace", "runtime/server", f"{context}:{prog}",
                f"serving {prog} program traced {n}× across one "
                "mixed-length traffic trace (expected 1): a Python "
                "value or data-dependent shape is leaking into the jit "
                "cache key — slot state must stay in fixed-shape "
                "arrays (tok/pos/active), never in traced Python "
                "scalars", layer="trace"))
    return findings


def check_retraces(algo, params, lc, boundaries: int = 2,
                   context: str = "lc-boundaries",
                   overlap: bool = False) -> list[Finding]:
    """``boundary-retrace`` findings for any step that traced more than
    once across ``boundaries`` identical boundaries."""
    counts = run_boundaries(algo, params, lc, boundaries,
                            overlap=overlap)
    findings = []
    for step, n in sorted(counts.items()):
        if n > 1:
            findings.append(Finding(
                "boundary-retrace", "algorithm", f"{context}:{step}",
                f"{step} traced {n}× across {boundaries} identical LC "
                "boundaries (expected 1): something non-hashable or "
                "shape-unstable is leaking into the jit cache key — "
                "check that μ enters as a traced scalar (set_mu) and "
                "that Θ/λ shapes are boundary-stable", layer="hlo"))
    return findings
