"""Layer 3: rules over *lowered HLO* — what the compiler will actually
see, checked without executing anything.

Two sweeps, both feeding the same rule set:

* every registered ``(solver, backend)`` dispatch entry is lowered on
  representative packed shapes (``jax.jit(...).lower`` on
  ``ShapeDtypeStruct``s);
* every scheme family's grouped C step is lowered through
  ``core.grouping.lower_group`` — the same packing/solve/shard code the
  engine jits, so the analyzed program IS the production program.

The HLO text (``lowered.compiler_ir(dialect="hlo").as_hlo_text()``) is
parsed with the existing ``analysis/hlo_stats.parse_module`` and
checked for:

``gspmd-unsafe-custom-call``
    a LAPACK/linalg custom-call reachable from a scheme that claims
    ``gspmd_safe=True`` while kernel-dispatch-ready — the exact PR-2
    miscompile shape: GSPMD has no partitioning rule for these targets
    and silently miscompiles sliced uses under plain sharding.

``donation-unaliased``
    a donated input the compiler could not alias into any output
    (detected via the lowering-time "donated buffers were not usable"
    warning): the engine donates Θ/λ buffers expecting in-place reuse,
    so an unusable donation is a silent 2× liveness regression.

``f64-op``
    f64/c128 ops in the lowered module — a Python float or np.float64
    upcast leaking into the trace (doubles bandwidth, and TPUs emulate
    f64 at ~1/10 throughput).

``host-callback``
    ``pure_callback``/``io_callback``-style custom-call targets — a
    host synchronization point inside the C step that also blocks
    sharding.

``lower-failed``
    the entry/scheme would not lower at all on its representative
    shapes — whatever the exception says is broken before any of the
    above can even be asked.

Lowering never runs a solve; the sweep is pure tracing and takes
seconds. Compiled-``pallas`` entries are skipped off-TPU (Mosaic cannot
lower them there); their ``interpret`` twins cover the kernel body.
"""
from __future__ import annotations

import warnings
from functools import partial

import jax
import jax.numpy as jnp

from repro.analysis import hlo_stats
from repro.analysis.lint.findings import Finding

_DONATION_MARKER = "donated buffers were not usable"


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def solver_fixture(name: str):
    """Representative packed inputs ``(args, static_kwargs)`` for a
    registered solver name, or None for names this sweep cannot cover
    (user-registered solvers should extend the scheme-level sweep via
    ``contract_examples`` instead)."""
    f32, i32, u32 = jnp.float32, jnp.int32, jnp.uint32
    n = 4
    table = {
        "kmeans_lloyd": ((_sds((n, 64), f32), _sds((n, 4), f32),
                          _sds((n,), i32)), {"iters": 2}),
        "topk_mask": ((_sds((n, 64), f32), _sds((n,), i32)), {}),
        "project_l1_ball": ((_sds((n, 64), f32), _sds((n,), f32)), {}),
        "soft_threshold": ((_sds((n, 64), f32), _sds((n,), f32),
                            _sds((), f32)), {}),
        "lowrank_rsvd": ((_sds((n, 12, 8), f32), _sds((n,), i32),
                          _sds((n, 2), u32)), {"r_max": 3}),
        "rank_select": ((_sds((n, 12, 8), f32), _sds((n,), f32),
                         _sds((n, 2), u32), _sds((), f32)),
                        {"r_max": 3}),
    }
    return table.get(name)


def _hlo_text(lowered) -> str:
    return lowered.compiler_ir(dialect="hlo").as_hlo_text()


def _module_findings(hlo_text: str, file: str, context: str,
                     gspmd_claimed: bool = False) -> list[Finding]:
    """The shared per-module rule set (see module docstring)."""
    comps = hlo_stats.parse_module(hlo_text)
    findings = []
    linalg = hlo_stats.linalg_custom_calls(comps)
    if gspmd_claimed and linalg:
        findings.append(Finding(
            "gspmd-unsafe-custom-call", file, context,
            f"gspmd_safe=True but the lowered C step contains linalg "
            f"custom-call(s) {linalg}: GSPMD has no partitioning rule "
            "for these and miscompiles sliced uses under plain "
            "sharding (the PR-2 bug) — either make the batched solver "
            "matmul-only or drop the gspmd_safe claim so the shard_map "
            "workaround applies", layer="hlo"))
    for target in hlo_stats.host_callbacks(comps):
        findings.append(Finding(
            "host-callback", file, context,
            f"lowered module calls host callback {target!r}: a host "
            "round-trip inside the C step serializes the device and "
            "blocks sharding; compute it in-graph or hoist it out of "
            "the jitted step", layer="hlo"))
    f64 = hlo_stats.f64_ops(comps)
    if f64:
        findings.append(Finding(
            "f64-op", file, context,
            f"lowered module contains {len(f64)} f64/c128 op(s) (e.g. "
            f"{f64[:3]}): a Python float or np.float64 is upcasting "
            "the trace — cast to jnp.float32 at the boundary",
            layer="hlo"))
    return findings


def check_solvers(registry=None) -> list[Finding]:
    """Lower every registered (solver, backend) entry and run the
    module rules. The registry is the live dispatch table by default."""
    from repro.kernels import dispatch

    if registry is None:
        registry = dispatch.registry_entries()
    on_tpu = jax.default_backend() == "tpu"
    findings = []
    for solver, impls in sorted(registry.items()):
        fixture = solver_fixture(solver)
        if fixture is None:
            continue
        args, kwargs = fixture
        for backend, fn in sorted(impls.items()):
            if backend == "pallas" and not on_tpu:
                continue  # Mosaic cannot lower off-TPU; interpret covers it
            context = f"{solver}:{backend}"
            try:
                lowered = jax.jit(partial(fn, **kwargs)).lower(*args)
                text = _hlo_text(lowered)
            except Exception as e:  # noqa: BLE001 — reported, not raised
                findings.append(Finding(
                    "lower-failed", "registry", context,
                    f"registered solver failed to lower on "
                    f"representative shapes: {type(e).__name__}: {e}",
                    layer="hlo"))
                continue
            findings += _module_findings(text, "registry", context)
    return findings


# ----------------------------------------------------------------------
def representative_group(scheme, n_tasks: int = 2, n_items: int = 2):
    """Build a toy multi-task group + abstract inputs for one scheme
    instance: ``(group, xs, thetas)`` ready for ``lower_group``. Vector
    schemes get ``(n_items, 64)`` stacks, matrix schemes
    ``(n_items, 12, 8)`` — nothing is materialized (xs are
    ShapeDtypeStructs, thetas come from ``jax.eval_shape``)."""
    from repro.core.tasks import CompressionTask
    from repro.core.views import AsStacked

    item = (12, 8) if scheme.domain == "matrix" else (64,)
    group, xs, thetas = [], {}, {}
    for i in range(n_tasks):
        name = f"lint/{type(scheme).__name__}/{i}"
        t = CompressionTask(name, pattern=".",
                            view=AsStacked(scheme.domain), scheme=scheme)
        x = _sds((n_items,) + item, jnp.float32)
        group.append(t)
        xs[name] = x
        thetas[name] = jax.eval_shape(t.scheme_init, x)
    return group, xs, thetas


def check_serving_lowerings(slots: int = 2, max_len: int = 16,
                            prefill_chunk: int = 4) -> list[Finding]:
    """Lower the serving engine's decode/prefill/reset programs (the
    exact production programs, cache donated like the engine's) on a
    tiny one-attn-layer config and run the module rules + the
    donation-aliasing check. Pure tracing: params and cache are
    ``eval_shape`` abstractions — nothing is allocated."""
    from repro.configs.base import LayerSpec, ModelConfig
    from repro.models.transformer import init_cache, init_params
    from repro.runtime.server import engine_programs

    cfg = ModelConfig(
        name="lint-serve", d_model=16, n_heads=2, n_kv_heads=2,
        head_dim=8, d_ff=32, vocab_size=64,
        pattern=(LayerSpec("attn", "dense"),), pattern_reps=1,
        attn_chunk_q=8, attn_chunk_kv=8, dtype="float32")
    params = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    cache = jax.eval_shape(lambda: init_cache(cfg, slots, max_len))
    i32, b_, key = jnp.int32, jnp.bool_, _sds((2,), jnp.uint32)
    decode_impl, prefill_impl, reset_impl = engine_programs(
        cfg, slots, max_len, 0.0, {"decode": 0, "prefill": 0,
                                   "reset": 0})
    programs = [
        ("serving:decode", jax.jit(decode_impl, donate_argnums=(1,)),
         (params, cache, _sds((slots,), i32), _sds((slots,), i32),
          _sds((slots,), b_), key)),
        ("serving:prefill", jax.jit(prefill_impl, donate_argnums=(1,)),
         (params, cache, _sds((slots, prefill_chunk), i32),
          _sds((slots,), i32), _sds((slots,), i32),
          _sds((slots,), b_), key)),
        ("serving:reset", jax.jit(reset_impl, donate_argnums=(0,)),
         (cache, _sds((slots,), b_))),
    ]
    findings = []
    for context, prog, args in programs:
        try:
            with warnings.catch_warnings(record=True) as caught:
                warnings.simplefilter("always")
                text = _hlo_text(prog.lower(*args))
        except Exception as e:  # noqa: BLE001 — reported, not raised
            findings.append(Finding(
                "lower-failed", "runtime/server", context,
                f"serving program failed to lower on representative "
                f"shapes: {type(e).__name__}: {e}", layer="hlo"))
            continue
        donation = [str(w.message) for w in caught
                    if _DONATION_MARKER in str(w.message)]
        if donation:
            findings.append(Finding(
                "donation-unaliased", "runtime/server", context,
                "donated KV-cache input could not be aliased into the "
                "output cache — every serving tick would hold two full "
                "caches live: keep the updated cache's leaf shapes/"
                "dtypes identical to the input's (compiler said: "
                f"{donation[0][:200]})", layer="hlo"))
        findings += _module_findings(text, "runtime/server", context)
    return findings


def check_planner_lowerings(classes=None,
                            backend: str | None = "auto") -> list[Finding]:
    """Lower each scheme family's grouped C step through the *planner*
    path — plan the representative group with the roofline cost model,
    then stage exactly the program a planner-on C step runs
    (``lower_group(..., plan=plan)``) — and run the module rules on it.

    Adds the ``planner-silent-fallback`` rule: with no mesh the planner
    is expected to refine its analytic estimate against the lowered
    HLO (``plan.source == "hlo"``); a plan that stayed analytic without
    recording an ``hlo-refine-failed:*`` fallback means the refinement
    was skipped silently — decisions would quietly degrade to the
    coarse model with nothing in the plan saying so."""
    from repro.analysis.lint.contract import _rel_file, \
        discover_scheme_classes
    from repro.core.grouping import _plan_multi_group, _task_solver, \
        lower_group

    if classes is None:
        classes = discover_scheme_classes()
    findings = []
    for cls in classes:
        for i, ex in enumerate(cls.contract_examples()):
            context = f"planner:{cls.__name__}[{i}]"
            rel = _rel_file(cls)
            try:
                group, xs, thetas = representative_group(ex)
                counts = [t.view.item_count(xs[t.name]) for t in group]
                solver_fn, _ = _task_solver(ex, backend)
                plan = _plan_multi_group(group, xs, thetas, counts,
                                         solver_fn, None, None, backend)
                with warnings.catch_warnings(record=True):
                    warnings.simplefilter("always")
                    text = _hlo_text(lower_group(group, xs, thetas,
                                                 mu=1.0, backend=backend,
                                                 plan=plan))
            except Exception as e:  # noqa: BLE001 — reported, not raised
                findings.append(Finding(
                    "lower-failed", rel, context,
                    f"planner-planned grouped C step failed to lower on "
                    f"representative shapes: {type(e).__name__}: {e}",
                    layer="hlo"))
                continue
            refine_recorded = any(
                f.startswith("hlo-refine-") for f in plan.fallbacks)
            if plan.source != "hlo" and not refine_recorded:
                findings.append(Finding(
                    "planner-silent-fallback", rel, context,
                    f"plan stayed {plan.source!r} with mesh=None and no "
                    "hlo-refine-failed/-skipped fallback recorded: the HLO "
                    "refinement was skipped without leaving a trace in "
                    "plan.fallbacks — planner decisions silently "
                    "degrade to the coarse analytic model", layer="hlo"))
            gspmd_claimed = bool(ex.gspmd_safe
                                 and ex.kernel_dispatch_ready())
            findings += _module_findings(text, rel, context,
                                         gspmd_claimed=gspmd_claimed)
    return findings


def check_scheme_lowerings(classes=None,
                           backend: str | None = "auto") -> list[Finding]:
    """Lower each scheme family's grouped C step (via
    ``core.grouping.lower_group``, Θ donated like the engine's) and run
    the module rules + the donation-aliasing check."""
    from repro.analysis.lint.contract import _rel_file, \
        discover_scheme_classes
    from repro.core.grouping import lower_group

    if classes is None:
        classes = discover_scheme_classes()
    findings = []
    for cls in classes:
        for i, ex in enumerate(cls.contract_examples()):
            context = f"{cls.__name__}[{i}]"
            rel = _rel_file(cls)
            try:
                group, xs, thetas = representative_group(ex)
                with warnings.catch_warnings(record=True) as caught:
                    warnings.simplefilter("always")
                    lowered = lower_group(group, xs, thetas, mu=1.0,
                                          backend=backend, donate=True)
                    text = _hlo_text(lowered)
            except Exception as e:  # noqa: BLE001 — reported, not raised
                findings.append(Finding(
                    "lower-failed", rel, context,
                    f"grouped C step failed to lower on representative "
                    f"shapes: {type(e).__name__}: {e}", layer="hlo"))
                continue
            donation = [str(w.message) for w in caught
                        if _DONATION_MARKER in str(w.message)]
            if donation:
                findings.append(Finding(
                    "donation-unaliased", rel, context,
                    "donated Θ input could not be aliased into any "
                    "output — the engine's donate path would silently "
                    "hold both buffers live (2× Θ memory): keep the new "
                    "Θ's leaf shapes/dtypes equal to the old Θ's "
                    f"(compiler said: {donation[0][:200]})", layer="hlo"))
            gspmd_claimed = bool(ex.gspmd_safe
                                 and ex.kernel_dispatch_ready())
            findings += _module_findings(text, rel, context,
                                         gspmd_claimed=gspmd_claimed)
    return findings
