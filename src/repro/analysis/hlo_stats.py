"""Trip-count-aware HLO cost analyzer.

``compiled.cost_analysis()`` (XLA HloCostAnalysis) counts a while-loop
body **once**, so any scan-over-layers model is underreported by ~n_layers×
in FLOPs, bytes, and (critically) collectives. This analyzer parses the
post-partitioning HLO text and:

* recursively multiplies `while` bodies by their trip count (recovered
  from the loop-condition's compare constant — the `lax.scan`/`fori_loop`
  lowering pattern);
* counts dot FLOPs exactly (2 · |output| · Π contracting dims) including
  inside fusion computations;
* models HBM traffic at **post-fusion granularity**: one fusion op = its
  operands + outputs (what a fused TPU kernel actually streams), skipping
  pure data-movement ops (tuple/GTE/bitcast/parameter/constant);
* attributes collective bytes (all-gather / all-reduce / reduce-scatter /
  all-to-all / collective-permute) by primitive, loop-multiplied.

All numbers are per-device (the SPMD module is per-device).
"""
from __future__ import annotations

import math
import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s2": 0.25, "u2": 0.25, "s4": 0.5, "u4": 0.5,
    "s8": 1, "u8": 1, "s16": 2, "u16": 2, "s32": 4, "u32": 4,
    "s64": 8, "u64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "bf16": 2, "f16": 2, "f32": 4, "f64": 8, "c64": 8, "c128": 16,
    "token": 0, "opaque": 0,
}

_SHAPE_RE = re.compile(r"([a-z]\w*)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*"
    r"(\([^()]*\)|[a-z]\w*\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$")
_COMP_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\(")
# unoptimized HLO (jit(...).lower().compiler_ir("hlo")) emits bare
# computation headers with no signature: "name.N {" / "ENTRY main.M {"
_COMP_BARE_RE = re.compile(r"^\s*(?:ENTRY\s+)?%?([\w.\-]+)\s*\{$")
_CALL_ATTR_RE = re.compile(
    r"(?:calls|to_apply|body|condition|branch_computations)="
    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")
_CONST_RE = re.compile(r"constant\((\d+)\)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([\d,]*)\}")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")
_SKIP_BYTES = {"parameter", "constant", "tuple", "get-tuple-element",
               "bitcast", "after-all", "partition-id", "replica-id",
               "while", "conditional", "call", "custom-call", "iota",
               "rng-bit-generator", "copy-start", "copy-done",
               # loop-carry copies: elided by buffer aliasing on TPU
               "copy"}


def _shape_elems_bytes(type_str: str) -> tuple[float, float]:
    elems = 0.0
    byts = 0.0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        byts += n * _DTYPE_BYTES[dt]
    return elems, byts


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    rest: str
    operands: list[str] = field(default_factory=list)
    called: list[str] = field(default_factory=list)
    raw: str = ""


@dataclass
class Stats:
    flops: float = 0.0
    bytes: float = 0.0        # upper bound: all op I/O at HLO granularity
    bytes_major: float = 0.0  # TPU-fused estimate: dot/reduce/gather I/O +
    #                           2×output for pure-elementwise chains
    coll: dict[str, float] = field(default_factory=dict)
    coll_count: float = 0.0

    def add(self, other: "Stats", mult: float = 1.0):
        self.flops += other.flops * mult
        self.bytes += other.bytes * mult
        self.bytes_major += other.bytes_major * mult
        for k, v in other.coll.items():
            self.coll[k] = self.coll.get(k, 0.0) + v * mult
        self.coll_count += other.coll_count * mult

    @property
    def coll_bytes(self) -> float:
        return sum(self.coll.values())


def parse_module(hlo_text: str) -> dict[str, list[Op]]:
    comps: dict[str, list[Op]] = {}
    cur: list[Op] | None = None
    for line in hlo_text.splitlines():
        if line.rstrip().endswith("{") \
                and not line.lstrip().startswith("HloModule"):
            m = (_COMP_RE.match(line.strip()) if "->" in line
                 else _COMP_BARE_RE.match(line.rstrip()))
            if m:
                cur = []
                comps[m.group(1)] = cur
                if line.strip().startswith("ENTRY"):
                    comps["__entry__"] = cur
                continue
        if cur is None:
            continue
        if line.strip() == "}":
            cur = None
            continue
        m = _OP_RE.match(line)
        if not m:
            continue
        name, type_str, opcode, rest = m.groups()
        called = []
        for cm in _CALL_ATTR_RE.finditer(rest):
            called.extend(x.strip().lstrip("%")
                          for x in cm.group(1).split(","))
        # operands: portion of `rest` before the closing paren of the
        # argument list (attrs follow) — take %refs that are not attr calls
        arg_str = rest.split("),")[0]
        called_set = set(called)
        operands = [o for o in _OPERAND_RE.findall(arg_str)
                    if o not in called_set]
        cur.append(Op(name, type_str, opcode, rest, operands, called,
                      raw=line))
    return comps


def _trip_count(cond_ops: list[Op]) -> float:
    """Largest integer constant in the loop condition ≈ trip count (the
    jax scan/fori lowering compares the induction var against the bound)."""
    best = 1
    for op in cond_ops:
        mm = _CONST_RE.search(op.raw)
        if mm:
            best = max(best, int(mm.group(1)))
    return float(best)


def _dot_flops(op: Op, shapes: dict[str, str]) -> float:
    out_elems, _ = _shape_elems_bytes(op.type_str)
    lhs = op.operands[0] if op.operands else None
    contract = _CONTRACT_RE.search(op.rest)
    k = 1.0
    if lhs and lhs in shapes and contract:
        dims = [int(d) for d in contract.group(1).split(",") if d]
        m = _SHAPE_RE.search(shapes[lhs])
        if m:
            sizes = [int(d) for d in m.group(2).split(",") if d]
            for d in dims:
                if d < len(sizes):
                    k *= sizes[d]
    return 2.0 * out_elems * k


class Analyzer:
    """``skip_scopes``: jax.named_scope tags whose ops are treated as one
    fused Pallas kernel — FLOPs and collectives still count, but HBM
    bytes are excluded (the kernel keeps intermediates in VMEM); the
    caller adds the kernel's analytic boundary I/O instead. Used for
    kernels/flash_attention and kernels/quant_matmul, whose Pallas
    implementations are validated in tests/ but cannot be Mosaic-compiled
    in the CPU dry-run container."""

    def __init__(self, hlo_text: str, skip_scopes: tuple = ()):
        self.skip_scopes = tuple(skip_scopes)
        self.skipped_ops = 0
        self.comps = parse_module(hlo_text)
        self.shapes: dict[str, str] = {}
        self.ops: dict[str, Op] = {}
        for ops in self.comps.values():
            for op in ops:
                self.shapes[op.name] = op.type_str
                self.ops[op.name] = op
        self._memo: dict[str, Stats] = {}

    def _operand_bytes(self, name: str) -> float:
        """Bytes read for an operand; sees through XLA:CPU's convert
        fusions (bf16 weights upcast to f32 for CPU dots — native on
        TPU) by charging the pre-convert source size."""
        elems, full = _shape_elems_bytes(self.shapes.get(name, ""))
        prod = self.ops.get(name)
        if prod is not None and prod.opcode == "fusion" and prod.called:
            body = self.comps.get(prod.called[0], [])
            _PURE = {"parameter", "constant", "convert", "bitcast",
                     "reshape", "transpose", "copy", "broadcast",
                     "dynamic-slice"}
            if body and all(o.opcode in _PURE for o in body) \
                    and any(o.opcode == "convert" for o in body):
                # charge the consumer read at the SOURCE dtype: the
                # convert only exists because XLA:CPU lacks bf16 dots
                src_bytes_per_elem = min(
                    (_DTYPE_BYTES.get(
                        _SHAPE_RE.search(self.shapes.get(o, "x[]")or"")
                        .group(1), 4)
                     for o in prod.operands
                     if _SHAPE_RE.search(self.shapes.get(o, "") or "")),
                    default=4)
                return min(full, elems * src_bytes_per_elem)
        return full

    def comp_stats(self, comp_name: str, count_bytes: bool = True) -> Stats:
        key = f"{comp_name}|{count_bytes}"
        if key in self._memo:
            return self._memo[key]
        self._memo[key] = Stats()  # break cycles
        ops = self.comps.get(comp_name, [])
        st = Stats()
        for op in ops:
            st.add(self.op_stats(op, count_bytes))
        self._memo[key] = st
        return st

    def _while_parts(self, op: Op) -> tuple[str | None, str | None]:
        body = cond = None
        mb = re.search(r"body=%?([\w.\-]+)", op.rest)
        mc = re.search(r"condition=%?([\w.\-]+)", op.rest)
        if mb:
            body = mb.group(1)
        if mc:
            cond = mc.group(1)
        if body is None or cond is None:  # fallback heuristic
            for c in op.called:
                ops_c = self.comps.get(c, [])
                if any(o.opcode == "compare" for o in ops_c) \
                        and len(ops_c) <= 8 and cond is None:
                    cond = c
                elif body is None:
                    body = c
        return body, cond

    def op_stats(self, op: Op, count_bytes: bool = True) -> Stats:
        st = Stats()
        oc = op.opcode
        if oc == "while":
            body, cond = self._while_parts(op)
            trips = _trip_count(self.comps.get(cond, [])) if cond else 1.0
            if body:
                # loop body ops live at real memory granularity
                st.add(self.comp_stats(body, count_bytes), mult=trips)
            return st
        if oc in ("fusion", "call", "conditional"):
            for c in op.called:
                if c in self.comps:
                    # inside a fusion everything is registers/VMEM: count
                    # flops + collectives only, never bytes
                    st.add(self.comp_stats(
                        c, count_bytes and oc != "fusion"))
        if oc == "dot":
            st.flops += _dot_flops(op, self.shapes)
        base = oc.replace("-start", "")
        if base in COLLECTIVES:
            _, byts = _shape_elems_bytes(op.type_str)
            st.coll[base] = st.coll.get(base, 0.0) + byts
            st.coll_count += 1
        if self.skip_scopes and any(s in op.raw
                                    for s in self.skip_scopes):
            self.skipped_ops += 1
            return st
        if count_bytes and oc not in _SKIP_BYTES \
                and not oc.endswith("-done"):
            out_b = _shape_elems_bytes(op.type_str)[1]
            if oc == "fusion":
                io = self._fusion_io_bytes(op)
                st.bytes += io
                if self._fusion_has_major(op):
                    st.bytes_major += io
                else:
                    # pure elementwise chain: on TPU it fuses into its
                    # producers/consumers; charge one write + one read
                    st.bytes_major += 2.0 * min(out_b, io)
            elif oc == "dynamic-slice":
                # reads only the slice, not the sliced-from buffer
                st.bytes += 2.0 * out_b
            elif oc == "dynamic-update-slice":
                # in-place on TPU (aliased buffer): r/w the update only
                upd = self._operand_bytes(op.operands[1]) \
                    if len(op.operands) > 1 else out_b
                st.bytes += 2.0 * min(out_b, upd)
            else:
                in_b = 0.0
                for o in op.operands:
                    if o in self.shapes:
                        in_b += self._operand_bytes(o)
                st.bytes += out_b + in_b
                if oc in ("dot", "convolution", "reduce", "sort", "gather",
                          "scatter", "dynamic-slice",
                          "dynamic-update-slice") \
                        or oc.replace("-start", "") in COLLECTIVES:
                    st.bytes_major += out_b + in_b
                else:
                    st.bytes_major += 2.0 * out_b
        return st

    _MAJOR_IN_FUSION = ("dot", "convolution", "reduce", "sort", "gather",
                        "scatter")

    def _fusion_has_major(self, op: Op) -> bool:
        for c in op.called:
            for o in self.comps.get(c, []):
                if o.opcode in self._MAJOR_IN_FUSION:
                    return True
        return False

    def _fusion_io_bytes(self, op: Op) -> float:
        """Effective HBM traffic of a fusion:

        * a param consumed only by dynamic-slice/gather reads the slice,
          not the whole operand (scan-over-layers weight stacks);
        * a param consumed only by dynamic-update-slice is the *aliased
          destination buffer* — in-place on TPU, charge the update size;
        * a dynamic-update-slice anywhere writing the output charges the
          update, not the whole buffer;
        * a pure-convert body (bf16↔f32 casts XLA:CPU inserts around
          dots — TPU has native bf16 MXU) charges the *source-dtype*
          read only; the cast fuses into the consumer on TPU.
        """
        body_name = op.called[0] if op.called else None
        body = self.comps.get(body_name, []) if body_name else []
        body_shapes = {o.name: o.type_str for o in body}
        consumers: dict[str, list[Op]] = {}
        params: dict[int, Op] = {}
        dus_ops = [o for o in body if o.opcode == "dynamic-update-slice"]
        for o in body:
            if o.opcode == "parameter":
                m = re.match(r"(\d+)", o.rest)
                if m:
                    params[int(m.group(1))] = o
            for src in o.operands:
                consumers.setdefault(src, []).append(o)

        _PURE = {"parameter", "constant", "convert", "bitcast", "reshape",
                 "transpose", "copy", "broadcast", "dynamic-slice"}
        pure_convert = (body and all(o.opcode in _PURE for o in body)
                        and any(o.opcode == "convert" for o in body))

        _UNARY = {"convert", "bitcast", "reshape", "copy", "transpose"}

        def final_consumers(name, depth=0) -> list[Op]:
            """Consumers, walking through pure unary ops (XLA:CPU's
            bf16↔f32 convert chains sit between params and slices)."""
            out = []
            for c in consumers.get(name, []):
                if c.opcode in _UNARY and depth < 4:
                    out.extend(final_consumers(c.name, depth + 1))
                else:
                    out.append(c)
            return out

        total = 0.0
        for i, operand in enumerate(op.operands):
            full = _shape_elems_bytes(self.shapes.get(operand, ""))[1]
            pop = params.get(i)
            if pop is not None:
                cons = final_consumers(pop.name)
                if cons and all(c.opcode in ("dynamic-slice", "gather")
                                for c in cons):
                    sliced = sum(_shape_elems_bytes(c.type_str)[1]
                                 for c in cons)
                    total += min(full, sliced)
                    continue
                if cons and all(c.opcode == "dynamic-update-slice"
                                for c in cons):
                    # aliased in-place destination: charged via output
                    continue
            total += full

        out_b = _shape_elems_bytes(op.type_str)[1]
        if dus_ops:
            upd = sum(_shape_elems_bytes(
                body_shapes.get(o.operands[1], self.shapes.get(
                    o.operands[1], "")))[1]
                for o in dus_ops if len(o.operands) >= 2)
            if upd:
                out_b = min(out_b, upd)
        if pure_convert:
            # source read only; cast output fuses into the consumer on TPU
            return total
        return total + out_b

    def entry_stats(self) -> Stats:
        return self.comp_stats("__entry__")


def analyze_hlo(hlo_text: str, skip_scopes: tuple = ()) -> Stats:
    return Analyzer(hlo_text, skip_scopes).entry_stats()


# ----------------------------------------------------------------------
# Static-analysis helpers over a parsed module (consumed by
# ``repro.analysis.lint``'s HLO layer — see that package). These work on
# ``parse_module`` output, so they see every computation, including
# while bodies and fusion subcomputations.
# ----------------------------------------------------------------------
_TARGET_RE = re.compile(r'custom_call_target="([^"]+)"')
_ALIAS_RE = re.compile(
    r"input_output_alias=\{([^}]*(?:\{[^}]*\}[^}]*)*)\}")
_ALIAS_ENTRY_RE = re.compile(r"\{[\d,\s]*\}:\s*\((\d+)")

#: custom-call target substrings that mark a LAPACK/cuSOLVER-style
#: linalg routine — the op family with NO SPMD partitioning rule, whose
#: presence under plain GSPMD sharding is the PR-2 miscompile shape
LINALG_TARGET_MARKERS = ("lapack", "cusolver", "cusolver_", "magma",
                         "hipsolver", "Qr", "Eigh", "Svd", "getrf",
                         "geqrf", "orgqr", "gesdd", "gesvd", "syevd",
                         "potrf")
#: custom-call target substrings that mark a host callback (pure_callback
#: / io_callback / debug.print) — a hard synchronization point that also
#: cannot shard
CALLBACK_TARGET_MARKERS = ("callback", "py_func", "host")


def custom_call_targets(comps: dict[str, list[Op]]) -> dict[str, int]:
    """{custom-call target: occurrence count} across all computations."""
    out: dict[str, int] = {}
    for name, ops in comps.items():
        if name == "__entry__":   # alias of the ENTRY computation
            continue
        for op in ops:
            if op.opcode != "custom-call":
                continue
            m = _TARGET_RE.search(op.rest)
            target = m.group(1) if m else "<unknown>"
            out[target] = out.get(target, 0) + 1
    return out


def linalg_custom_calls(comps: dict[str, list[Op]]) -> list[str]:
    """Custom-call targets that look like LAPACK/solver routines."""
    return sorted(t for t in custom_call_targets(comps)
                  if any(mk.lower() in t.lower()
                         for mk in LINALG_TARGET_MARKERS))


def host_callbacks(comps: dict[str, list[Op]]) -> list[str]:
    """Custom-call targets that look like host callbacks."""
    return sorted(t for t in custom_call_targets(comps)
                  if any(mk in t.lower() for mk in CALLBACK_TARGET_MARKERS))


def f64_ops(comps: dict[str, list[Op]]) -> list[str]:
    """Names of ops producing f64/c128 results (accidental float64 —
    usually a Python float that upcast under ``jax_enable_x64``, or a
    ``np.float64`` scalar leaking into the trace)."""
    out = []
    for name, ops in comps.items():
        if name == "__entry__":
            continue
        for op in ops:
            for dt, _ in _SHAPE_RE.findall(op.type_str):
                if dt in ("f64", "c128") and op.opcode not in (
                        "convert",):
                    out.append(op.name)
                    break
    return out


def parse_input_output_alias(hlo_text: str) -> set[int]:
    """Parameter indices aliased into outputs per the module header's
    ``input_output_alias={ {0}: (1, {}, may-alias), ... }`` — the
    compiled record of which donated inputs were actually reused."""
    m = _ALIAS_RE.search(hlo_text)
    if not m:
        return set()
    return {int(e) for e in _ALIAS_ENTRY_RE.findall(m.group(1))}
