"""Pure-jnp oracles for the ℓ0-pruning kernels."""
from __future__ import annotations

import jax.numpy as jnp


def count_above_ref(w: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.abs(w) > t).astype(jnp.float32)


def mask_apply_ref(w: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(jnp.abs(w) > t, w, 0.0)


def topk_threshold_ref(w: jnp.ndarray, kappa: int) -> jnp.ndarray:
    """Exact κ-th largest |w| (the oracle the bisection must bracket)."""
    a = jnp.sort(jnp.abs(w.ravel()))[::-1]
    return a[kappa - 1]


def topk_mask_batched_ref(w: jnp.ndarray, kappa: jnp.ndarray) -> jnp.ndarray:
    """Per-item top-κ mask with κ a *traced* (I,) operand.

    Sort each row's magnitudes descending, gather the κ_i-th largest as
    the per-item threshold, keep ``|w| >= t_i``. The threshold value is
    the exact order statistic — identical to ``lax.top_k(a, κ)[0][-1]``
    — so this is the bit-exact jnp backend for the ``topk_mask`` solver
    (the kernel path bisects to the same statistic and keeps exactly κ
    on distinct magnitudes).
    """
    a = jnp.abs(w.astype(jnp.float32))
    a_desc = jnp.sort(a, axis=-1)[:, ::-1]
    idx = jnp.maximum(kappa.astype(jnp.int32) - 1, 0)[:, None]
    thresh = jnp.take_along_axis(a_desc, idx, axis=-1)     # (I, 1)
    return jnp.where(a >= thresh, w, 0.0)
