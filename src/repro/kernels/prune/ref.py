"""Pure-jnp oracles for the ℓ0-pruning kernels."""
from __future__ import annotations

import jax.numpy as jnp


def count_above_ref(w: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.abs(w) > t).astype(jnp.float32)


def mask_apply_ref(w: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(jnp.abs(w) > t, w, 0.0)


def topk_threshold_ref(w: jnp.ndarray, kappa: int) -> jnp.ndarray:
    """Exact κ-th largest |w| (the oracle the bisection must bracket)."""
    a = jnp.sort(jnp.abs(w.ravel()))[::-1]
    return a[kappa - 1]


def topk_mask_batched_ref(w: jnp.ndarray, kappa: jnp.ndarray) -> jnp.ndarray:
    """Per-item top-κ mask with κ a *traced* (I,) operand.

    Stable argsort by descending magnitude gives each entry its rank
    (ties ranked by ascending index — the ``lax.top_k`` order); keep
    ``rank < κ_i``. Exactly min(κ_i, P) nonzeros per item even under
    magnitude ties, which a threshold mask (``|w| >= kth``) violates by
    keeping the whole tied class: that makes θ infeasible for the ℓ0
    constraint and breaks the §7 C-step monotonicity monitor. Support
    and tie-break match the per-task scheme solver bit-exactly.
    """
    a = jnp.abs(w.astype(jnp.float32))
    order = jnp.argsort(-a, axis=-1)            # stable: ties → low index
    rank = jnp.argsort(order, axis=-1)          # inverse permutation
    keep = rank < kappa.astype(jnp.int32)[:, None]
    return jnp.where(keep, w, 0.0)
