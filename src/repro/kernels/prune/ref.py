"""Pure-jnp oracles for the ℓ0-pruning kernels."""
from __future__ import annotations

import jax.numpy as jnp


def count_above_ref(w: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    return jnp.sum(jnp.abs(w) > t).astype(jnp.float32)


def mask_apply_ref(w: jnp.ndarray, t: jnp.ndarray) -> jnp.ndarray:
    return jnp.where(jnp.abs(w) > t, w, 0.0)


def topk_threshold_ref(w: jnp.ndarray, kappa: int) -> jnp.ndarray:
    """Exact κ-th largest |w| (the oracle the bisection must bracket)."""
    a = jnp.sort(jnp.abs(w.ravel()))[::-1]
    return a[kappa - 1]
