"""Serving op for pruned-sparse weights.

After the LC pruning C step, a weight W (K, N) keeps nnz surviving
entries. Serving stores them COO-style as (values, rows, cols) — the
HBM read per decode step is nnz·(2 + 4 + 4) bytes (bf16 value + two
int32 coordinates) instead of K·N·2, a win once density drops below
~25%. Below that cutoff callers should densify (see
``runtime.compressed``): scatter-add beats a dense matmul only when
the weight is actually sparse.

The gather/scatter formulation (`x[:, rows] * values` scattered into
output columns) keeps everything inside one XLA program — no host
round-trip, no custom call — and batches over the leading x axes for
free.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sparse_matmul(x: jnp.ndarray, values: jnp.ndarray, rows: jnp.ndarray,
                  cols: jnp.ndarray, n_cols: int) -> jnp.ndarray:
    """y = x @ W for W given in COO form.

    x: (..., K); values: (nnz,); rows/cols: (nnz,) int32 with
    W[rows[i], cols[i]] = values[i]; n_cols = N (static) → y: (..., N).
    """
    with jax.named_scope("sparse_matmul"):
        contrib = x[..., rows] * values.astype(x.dtype)      # (..., nnz)
        out = jnp.zeros((*x.shape[:-1], n_cols), x.dtype)
        return out.at[..., cols].add(contrib)


def densify(values: jnp.ndarray, rows: jnp.ndarray, cols: jnp.ndarray,
            shape: tuple[int, int]) -> jnp.ndarray:
    """Dense W from COO triplets — parity checks and the low-sparsity
    fallback path."""
    w = jnp.zeros(shape, values.dtype)
    return w.at[rows, cols].set(values)
