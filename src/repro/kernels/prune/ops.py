"""Top-κ magnitude pruning via threshold bisection over the count kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.prune import ref
from repro.kernels.prune.prune import LANES, ROWS, count_above, mask_apply


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad(w):
    p = w.shape[0]
    tile = ROWS * LANES
    padn = (-p) % tile
    if padn:
        w = jnp.concatenate([w, jnp.zeros((padn,), w.dtype)])
    return w, p


def topk_mask(w: jnp.ndarray, kappa: int, iters: int = 30,
              use_pallas: bool | str = "auto") -> jnp.ndarray:
    """θ = w · 1[|w| ≥ t*], with t* bisected so that nnz(θ) ≈ κ.

    Bisection converges to the exact order statistic up to float-ulp ties;
    any remaining tie-overshoot is the same arbitrary tie-breaking the
    paper's top-κ projection allows.
    """
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    flat = w.ravel().astype(jnp.float32)
    if not use_pallas:
        t = ref.topk_threshold_ref(flat, kappa)
        return jnp.where(jnp.abs(w) >= t, w, 0.0)

    wp, p = _pad(flat)
    interp = not _on_tpu()

    def counts(t):
        return count_above(wp, t, interpret=interp)

    hi = jnp.max(jnp.abs(flat))
    lo = jnp.float32(0.0)

    def body(_, carry):
        lo_, hi_ = carry
        mid = 0.5 * (lo_ + hi_)
        c = counts(mid)
        # too many kept → raise threshold
        lo_ = jnp.where(c > kappa, mid, lo_)
        hi_ = jnp.where(c > kappa, hi_, mid)
        return lo_, hi_

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # invariant: count(>lo) > κ ≥ count(>hi); at convergence both sit at
    # the (κ+1)-th order statistic, so masking with hi keeps exactly κ
    # (fewer under float-identical ties — same arbitrary tie-break as any
    # top-κ projection).
    out = mask_apply(wp, hi, interpret=interp)[:p]
    return out.reshape(w.shape)
