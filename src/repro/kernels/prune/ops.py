"""Top-κ magnitude pruning via threshold bisection over the count kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.prune import ref
from repro.kernels.prune.prune import (
    LANES, ROWS, count_above, count_above_batched)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad(w):
    p = w.shape[0]
    tile = ROWS * LANES
    padn = (-p) % tile
    if padn:
        w = jnp.concatenate([w, jnp.zeros((padn,), w.dtype)])
    return w, p


def topk_mask(w: jnp.ndarray, kappa: int, iters: int = 30,
              use_pallas: bool | str = "auto") -> jnp.ndarray:
    """θ = w · 1[top-κ support], exactly min(κ, nnz-reachable) kept.

    The kernel path bisects a threshold over the streaming count kernel,
    then resolves the boundary class in index order so magnitude ties at
    the κ-th entry never over-keep (an ``|w| ≥ t`` mask keeps the whole
    tied class — infeasible for the ℓ0 constraint and a §7-monitor
    violation once the ties break). Tie-break matches ``lax.top_k``:
    lower index wins.
    """
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    flat = w.ravel().astype(jnp.float32)
    if not use_pallas:
        idx = jax.lax.top_k(jnp.abs(flat), min(int(kappa), flat.size))[1]
        mask = jnp.zeros(flat.shape, bool).at[idx].set(True)
        return jnp.where(mask.reshape(w.shape), w, 0.0)

    wp, p = _pad(flat)
    interp = not _on_tpu()

    def counts(t):
        return count_above(wp, t, interpret=interp)

    hi = jnp.max(jnp.abs(flat))
    lo = jnp.float32(0.0)

    def body(_, carry):
        lo_, hi_ = carry
        mid = 0.5 * (lo_ + hi_)
        c = counts(mid)
        # too many kept → raise threshold
        lo_ = jnp.where(c > kappa, mid, lo_)
        hi_ = jnp.where(c > kappa, hi_, mid)
        return lo_, hi_

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # invariant: count(>lo) > κ ≥ count(>hi) (unless fewer than κ
    # nonzeros — then hi → 0 and every nonzero is kept). Keep the
    # strictly-above-hi class whole, then fill the remaining κ − n_hi
    # slots from the boundary class (lo, hi] in index order — exactly κ
    # kept even on float-identical ties, same lowest-index tie-break as
    # the jnp path.
    a = jnp.abs(wp)
    n_hi = counts(hi).astype(jnp.int32)
    boundary = (a > lo) & (a <= hi)
    fill = jnp.cumsum(boundary.astype(jnp.int32)) <= (kappa - n_hi)
    out = jnp.where((a > hi) | (boundary & fill), wp, 0.0)[:p]
    return out.reshape(w.shape)


# ----------------------------------------------------------------------
# batched solver — the "topk_mask" entry of the kernel dispatch layer
# ----------------------------------------------------------------------
def _pad_batched(w, block_rows: int = ROWS):
    n_items, p = w.shape
    tile = int(block_rows) * LANES
    padn = (-p) % tile
    if padn:
        w = jnp.concatenate(
            [w, jnp.zeros((n_items, padn), w.dtype)], axis=1)
    return w, p


def topk_mask_batched(w: jnp.ndarray, kappa: jnp.ndarray, iters: int = 30,
                      impl: str = "jnp",
                      block_rows: int = ROWS) -> jnp.ndarray:
    """Per-item top-κ mask over a packed item stack.

    ``w``: (I, P) f32; ``kappa``: (I,) — a *traced* per-item operand, so
    tasks differing only in κ share one launch (mixed-κ grouping).

    ``impl``: ``"jnp"`` (sort + gather, bit-exact vs the per-task
    scheme solver), ``"interpret"`` (Pallas kernels in interpret mode —
    the CPU/CI validation path), or ``"pallas"`` (compiled, TPU):
    per-item threshold bisection over :func:`count_above_batched`, then
    one fused boundary-resolution sweep.

    Every backend keeps *exactly* min(κ_i, P) weights per item, ties at
    the κ boundary broken toward the lower index (the ``lax.top_k``
    order, bit-matching the per-task scheme solver). Over-keeping the
    tied class — what a plain ``|w| ≥ t`` threshold mask does — makes θ
    infeasible for the ℓ0 constraint, under-reports distortion, and
    trips the §7 monotonicity monitor once the ties break (mamba
    ``A_log`` leaves tie in 128-wide classes at init). The kernel path
    bisects on the feasibility predicate ``count(|w| ≥ t) ≥ κ``, keeps
    the ``|w| ≥ hi`` class whole (``hi`` infeasible, so < κ weights),
    and fills the remaining slots from the ``[lo, hi)`` boundary class
    in index order. Near-ties inside the final unconverged interval
    (sub-float-ulp after ``iters`` halvings) are filled by index rather
    than magnitude order — still exactly κ, distortion-equal to ulp.
    """
    w = w.astype(jnp.float32)
    kappa = jnp.asarray(kappa, jnp.int32)
    if impl == "jnp":
        return ref.topk_mask_batched_ref(w, kappa)
    interp = impl != "pallas"
    rows = int(block_rows)

    wp, p = _pad_batched(w, rows)
    # invariant: lo feasible (count_ge(lo) ≥ κ — true at 0 since κ ≤ P),
    # hi infeasible (strictly above the max magnitude)
    hi = jnp.max(jnp.abs(w), axis=-1) * 2.0 + 1.0   # (I,)
    lo = jnp.zeros_like(hi)
    kf = kappa.astype(jnp.float32)

    def body(_, carry):
        lo_, hi_ = carry
        mid = 0.5 * (lo_ + hi_)
        c = count_above_batched(wp, mid, interpret=interp,
                                strict=False,
                                block_rows=rows)     # count(|w| ≥ mid)
        feasible = c >= kf
        lo_ = jnp.where(feasible, mid, lo_)
        hi_ = jnp.where(feasible, hi_, mid)
        return lo_, hi_

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # lo feasible (count(|w| ≥ lo) ≥ κ), hi infeasible (< κ): keep the
    # |w| ≥ hi class whole, fill the remaining κ − n_hi slots from the
    # [lo, hi) boundary class in index order (exact κ under ties; the
    # item axis is padded with zeros *after* the live entries, so real
    # boundary weights always outrank the padding in the cumsum).
    a = jnp.abs(wp)
    n_hi = count_above_batched(wp, hi, interpret=interp, strict=False,
                               block_rows=rows).astype(jnp.int32)  # (I,)
    boundary = (a >= lo[:, None]) & (a < hi[:, None])
    fill = (jnp.cumsum(boundary.astype(jnp.int32), axis=-1)
            <= (kappa - n_hi)[:, None])
    keep = (a >= hi[:, None]) | (boundary & fill)
    return jnp.where(keep, wp, 0.0)[:, :p]


# ----------------------------------------------------------------------
# batched ℓ1 solvers — "project_l1_ball" / "soft_threshold" entries of
# the dispatch layer (jnp-only: one sort+cumsum / one elementwise pass
# over the packed item axis; no kernel to emulate)
# ----------------------------------------------------------------------
def project_l1_ball_batched(w: jnp.ndarray,
                            radius: jnp.ndarray) -> jnp.ndarray:
    """Per-item Euclidean projection onto {θ : ‖θ‖₁ ≤ radius_i}
    (Duchi et al.) over a packed (I, P) stack.

    ``radius`` is a *traced* (I,) operand, so tasks differing only in
    the ball radius share one launch. Row-for-row the same arithmetic
    as the per-task ``project_l1_ball`` (whose ``lax.cond`` becomes the
    same both-branches select under vmap): rows already inside their
    ball pass through bit-identically.
    """
    w = w.astype(jnp.float32)
    radius = jnp.asarray(radius, jnp.float32)[:, None]       # (I, 1)
    a = jnp.abs(w)
    total = jnp.sum(a, axis=-1, keepdims=True)
    u = jnp.sort(a, axis=-1)[:, ::-1]
    cs = jnp.cumsum(u, axis=-1)
    r = jnp.arange(1, w.shape[-1] + 1, dtype=jnp.float32)[None, :]
    cond = u * r > (cs - radius)
    rho = jnp.max(jnp.where(cond, r, 0.0), axis=-1, keepdims=True)
    cs_rho = jnp.sum(jnp.where(r <= rho, u, 0.0), axis=-1,
                     keepdims=True)
    tau = (cs_rho - radius) / jnp.maximum(rho, 1.0)
    proj = jnp.sign(w) * jnp.maximum(a - tau, 0.0)
    return jnp.where(total <= radius, w, proj)


def soft_threshold_batched(w: jnp.ndarray, alpha: jnp.ndarray,
                           mu) -> jnp.ndarray:
    """Per-item ℓ1-penalty prox θ = sign(w)·max(|w| − α_i/μ, 0) over a
    packed (I, P) stack; α is a traced (I,) operand (mixed-α grouping).
    Elementwise — bit-identical to the per-task scheme program."""
    w = w.astype(jnp.float32)
    t = (jnp.asarray(alpha, jnp.float32) / mu)[:, None]
    return jnp.sign(w) * jnp.maximum(jnp.abs(w) - t, 0.0)
