"""Top-κ magnitude pruning via threshold bisection over the count kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.prune import ref
from repro.kernels.prune.prune import (
    LANES, ROWS, count_above, count_above_batched, mask_apply,
    mask_apply_batched)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _pad(w):
    p = w.shape[0]
    tile = ROWS * LANES
    padn = (-p) % tile
    if padn:
        w = jnp.concatenate([w, jnp.zeros((padn,), w.dtype)])
    return w, p


def topk_mask(w: jnp.ndarray, kappa: int, iters: int = 30,
              use_pallas: bool | str = "auto") -> jnp.ndarray:
    """θ = w · 1[|w| ≥ t*], with t* bisected so that nnz(θ) ≈ κ.

    Bisection converges to the exact order statistic up to float-ulp ties;
    any remaining tie-overshoot is the same arbitrary tie-breaking the
    paper's top-κ projection allows.
    """
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    flat = w.ravel().astype(jnp.float32)
    if not use_pallas:
        t = ref.topk_threshold_ref(flat, kappa)
        return jnp.where(jnp.abs(w) >= t, w, 0.0)

    wp, p = _pad(flat)
    interp = not _on_tpu()

    def counts(t):
        return count_above(wp, t, interpret=interp)

    hi = jnp.max(jnp.abs(flat))
    lo = jnp.float32(0.0)

    def body(_, carry):
        lo_, hi_ = carry
        mid = 0.5 * (lo_ + hi_)
        c = counts(mid)
        # too many kept → raise threshold
        lo_ = jnp.where(c > kappa, mid, lo_)
        hi_ = jnp.where(c > kappa, hi_, mid)
        return lo_, hi_

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    # invariant: count(>lo) > κ ≥ count(>hi); at convergence both sit at
    # the (κ+1)-th order statistic, so masking with hi keeps exactly κ
    # (fewer under float-identical ties — same arbitrary tie-break as any
    # top-κ projection).
    out = mask_apply(wp, hi, interpret=interp)[:p]
    return out.reshape(w.shape)


# ----------------------------------------------------------------------
# batched solver — the "topk_mask" entry of the kernel dispatch layer
# ----------------------------------------------------------------------
def _pad_batched(w):
    n_items, p = w.shape
    tile = ROWS * LANES
    padn = (-p) % tile
    if padn:
        w = jnp.concatenate(
            [w, jnp.zeros((n_items, padn), w.dtype)], axis=1)
    return w, p


def topk_mask_batched(w: jnp.ndarray, kappa: jnp.ndarray, iters: int = 30,
                      impl: str = "jnp") -> jnp.ndarray:
    """Per-item top-κ mask over a packed item stack.

    ``w``: (I, P) f32; ``kappa``: (I,) — a *traced* per-item operand, so
    tasks differing only in κ share one launch (mixed-κ grouping).

    ``impl``: ``"jnp"`` (sort + gather, bit-exact vs the per-task
    scheme solver), ``"interpret"`` (Pallas kernels in interpret mode —
    the CPU/CI validation path), or ``"pallas"`` (compiled, TPU):
    per-item threshold bisection over :func:`count_above_batched`, then
    one :func:`mask_apply_batched` sweep.

    The kernel path bisects on the *feasibility* predicate
    ``count(|w| ≥ t) ≥ κ`` and masks with ``|w| ≥ lo`` where ``lo`` is
    the best feasible threshold seen — so it never keeps fewer than κ
    weights. This matters on magnitude ties at the κ boundary (±w pairs
    are exact-magnitude ties): a strict ``>`` mask at the converged
    threshold would drop the whole tied class, pruning the largest
    weights. Like the jnp sort path, ties at the threshold over-keep
    (all tied weights survive) — the paper's top-κ projection allows
    any tie-break; near-ties inside the final unconverged interval
    (sub-float-ulp after ``iters`` halvings) share that caveat.
    """
    w = w.astype(jnp.float32)
    kappa = jnp.asarray(kappa, jnp.int32)
    if impl == "jnp":
        return ref.topk_mask_batched_ref(w, kappa)
    interp = impl != "pallas"

    wp, p = _pad_batched(w)
    # invariant: lo feasible (count_ge(lo) ≥ κ — true at 0 since κ ≤ P),
    # hi infeasible (strictly above the max magnitude)
    hi = jnp.max(jnp.abs(w), axis=-1) * 2.0 + 1.0   # (I,)
    lo = jnp.zeros_like(hi)
    kf = kappa.astype(jnp.float32)

    def body(_, carry):
        lo_, hi_ = carry
        mid = 0.5 * (lo_ + hi_)
        c = count_above_batched(wp, mid, interpret=interp,
                                strict=False)        # count(|w| ≥ mid)
        feasible = c >= kf
        lo_ = jnp.where(feasible, mid, lo_)
        hi_ = jnp.where(feasible, hi_, mid)
        return lo_, hi_

    lo, hi = jax.lax.fori_loop(0, iters, body, (lo, hi))
    return mask_apply_batched(wp, lo, interpret=interp,
                              strict=False)[:, :p]


# ----------------------------------------------------------------------
# batched ℓ1 solvers — "project_l1_ball" / "soft_threshold" entries of
# the dispatch layer (jnp-only: one sort+cumsum / one elementwise pass
# over the packed item axis; no kernel to emulate)
# ----------------------------------------------------------------------
def project_l1_ball_batched(w: jnp.ndarray,
                            radius: jnp.ndarray) -> jnp.ndarray:
    """Per-item Euclidean projection onto {θ : ‖θ‖₁ ≤ radius_i}
    (Duchi et al.) over a packed (I, P) stack.

    ``radius`` is a *traced* (I,) operand, so tasks differing only in
    the ball radius share one launch. Row-for-row the same arithmetic
    as the per-task ``project_l1_ball`` (whose ``lax.cond`` becomes the
    same both-branches select under vmap): rows already inside their
    ball pass through bit-identically.
    """
    w = w.astype(jnp.float32)
    radius = jnp.asarray(radius, jnp.float32)[:, None]       # (I, 1)
    a = jnp.abs(w)
    total = jnp.sum(a, axis=-1, keepdims=True)
    u = jnp.sort(a, axis=-1)[:, ::-1]
    cs = jnp.cumsum(u, axis=-1)
    r = jnp.arange(1, w.shape[-1] + 1, dtype=jnp.float32)[None, :]
    cond = u * r > (cs - radius)
    rho = jnp.max(jnp.where(cond, r, 0.0), axis=-1, keepdims=True)
    cs_rho = jnp.sum(jnp.where(r <= rho, u, 0.0), axis=-1,
                     keepdims=True)
    tau = (cs_rho - radius) / jnp.maximum(rho, 1.0)
    proj = jnp.sign(w) * jnp.maximum(a - tau, 0.0)
    return jnp.where(total <= radius, w, proj)


def soft_threshold_batched(w: jnp.ndarray, alpha: jnp.ndarray,
                           mu) -> jnp.ndarray:
    """Per-item ℓ1-penalty prox θ = sign(w)·max(|w| − α_i/μ, 0) over a
    packed (I, P) stack; α is a traced (I,) operand (mixed-α grouping).
    Elementwise — bit-identical to the per-task scheme program."""
    w = w.astype(jnp.float32)
    t = (jnp.asarray(alpha, jnp.float32) / mu)[:, None]
    return jnp.sign(w) * jnp.maximum(jnp.abs(w) - t, 0.0)
