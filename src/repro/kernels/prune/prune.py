"""Pallas TPU kernels for ℓ0-constraint pruning at scale (paper §4.2).

The C step keeps the top-κ weights by magnitude. A global sort of 10⁹
weights is the GPU-ish answer; the TPU-native adaptation is **threshold
bisection**: ~25 iterations of a streaming `count(|w| > t)` kernel (one
compare per element, grid-sequential scalar accumulation — the same
pattern as the k-means moments), then one `mask-apply` pass. 26 cheap
HBM sweeps beat a distributed sort, and every pass is embarrassingly
shardable (the count psums across shards).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8
LANES = 128


def _count_kernel(w_ref, t_ref, out_ref):
    step = pl.program_id(0)
    w = w_ref[...]
    t = t_ref[0, 0]
    c = jnp.sum((jnp.abs(w) > t).astype(jnp.float32))[None, None]

    @pl.when(step == 0)
    def _init():
        out_ref[...] = c

    @pl.when(step != 0)
    def _accum():
        out_ref[...] += c


def _mask_kernel(w_ref, t_ref, out_ref):
    w = w_ref[...]
    t = t_ref[0, 0]
    out_ref[...] = jnp.where(jnp.abs(w) > t, w, 0.0)


@partial(jax.jit, static_argnames=("interpret",))
def count_above(w: jnp.ndarray, t: jnp.ndarray, interpret: bool = True):
    """w: (P,) padded to ROWS·LANES multiples; t: scalar → count f32."""
    p = w.shape[0]
    tile = ROWS * LANES
    assert p % tile == 0
    n_tiles = p // tile
    w2 = w.astype(jnp.float32).reshape(n_tiles * ROWS, LANES)
    out = pl.pallas_call(
        _count_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(w2, t.reshape(1, 1).astype(jnp.float32))
    return out[0, 0]


@partial(jax.jit, static_argnames=("interpret",))
def mask_apply(w: jnp.ndarray, t: jnp.ndarray, interpret: bool = True):
    p = w.shape[0]
    tile = ROWS * LANES
    assert p % tile == 0
    n_tiles = p // tile
    w2 = w.astype(jnp.float32).reshape(n_tiles * ROWS, LANES)
    out = pl.pallas_call(
        _mask_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * ROWS, LANES),
                                       jnp.float32),
        interpret=interpret,
    )(w2, t.reshape(1, 1).astype(jnp.float32))
    return out.reshape(p)
