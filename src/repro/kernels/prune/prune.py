"""Pallas TPU kernels for ℓ0-constraint pruning at scale (paper §4.2).

The C step keeps the top-κ weights by magnitude. A global sort of 10⁹
weights is the GPU-ish answer; the TPU-native adaptation is **threshold
bisection**: ~25 iterations of a streaming `count(|w| > t)` kernel (one
compare per element, grid-sequential scalar accumulation — the same
pattern as the k-means moments), then one `mask-apply` pass. 26 cheap
HBM sweeps beat a distributed sort, and every pass is embarrassingly
shardable (the count psums across shards).

Batched variants (:func:`count_above_batched`,
:func:`mask_apply_batched`) add an **items grid dimension** for the
grouped C step: grid ``(items, n_tiles)``, a per-item threshold block in
VMEM, a per-item count accumulator re-initialized when the (fast) tile
coordinate wraps. The threshold — and therefore κ, which the bisection
driver in ops.py compares the counts against — is a *traced per-item
operand*, which is what lets tasks that differ only in κ share one
kernel launch (mixed-κ grouping).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8
LANES = 128


def _count_kernel(w_ref, t_ref, out_ref):
    step = pl.program_id(0)
    w = w_ref[...]
    t = t_ref[0, 0]
    c = jnp.sum((jnp.abs(w) > t).astype(jnp.float32))[None, None]

    @pl.when(step == 0)
    def _init():
        out_ref[...] = c

    @pl.when(step != 0)
    def _accum():
        out_ref[...] += c


def _mask_kernel(w_ref, t_ref, out_ref):
    w = w_ref[...]
    t = t_ref[0, 0]
    out_ref[...] = jnp.where(jnp.abs(w) > t, w, 0.0)


@partial(jax.jit, static_argnames=("interpret",))
def count_above(w: jnp.ndarray, t: jnp.ndarray, interpret: bool = True):
    """w: (P,) padded to ROWS·LANES multiples; t: scalar → count f32."""
    p = w.shape[0]
    tile = ROWS * LANES
    assert p % tile == 0
    n_tiles = p // tile
    w2 = w.astype(jnp.float32).reshape(n_tiles * ROWS, LANES)
    out = pl.pallas_call(
        _count_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((1, 1), jnp.float32),
        interpret=interpret,
    )(w2, t.reshape(1, 1).astype(jnp.float32))
    return out[0, 0]


@partial(jax.jit, static_argnames=("interpret",))
def mask_apply(w: jnp.ndarray, t: jnp.ndarray, interpret: bool = True):
    p = w.shape[0]
    tile = ROWS * LANES
    assert p % tile == 0
    n_tiles = p // tile
    w2 = w.astype(jnp.float32).reshape(n_tiles * ROWS, LANES)
    out = pl.pallas_call(
        _mask_kernel,
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, 1), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_tiles * ROWS, LANES),
                                       jnp.float32),
        interpret=interpret,
    )(w2, t.reshape(1, 1).astype(jnp.float32))
    return out.reshape(p)


# ----------------------------------------------------------------------
# batched (items-grid) variants — one pallas_call per packed group.
# ``strict`` picks the comparison (|w| > t vs |w| ≥ t): the bisection
# driver in ops.py bisects on the ≥ form (feasibility: count(|w| ≥ t)
# ≥ κ) so its lo threshold never drops a whole tied class; the driver
# then resolves boundary ties down to exactly κ in index order.
# ----------------------------------------------------------------------
def _count_batched_kernel(w_ref, t_ref, out_ref, *, strict: bool):
    tile = pl.program_id(1)                      # fast axis: tiles
    w = w_ref[0]                                 # (ROWS, LANES)
    t = t_ref[0, 0]                              # this item's threshold
    keep = jnp.abs(w) > t if strict else jnp.abs(w) >= t
    c = jnp.sum(keep.astype(jnp.float32))[None, None]

    @pl.when(tile == 0)
    def _init():
        out_ref[...] = c

    @pl.when(tile != 0)
    def _accum():
        out_ref[...] += c


def _mask_batched_kernel(w_ref, t_ref, out_ref, *, strict: bool):
    w = w_ref[0]
    t = t_ref[0, 0]
    keep = jnp.abs(w) > t if strict else jnp.abs(w) >= t
    out_ref[0] = jnp.where(keep, w, 0.0)


def _tiled(w: jnp.ndarray, rows: int = ROWS):
    n_items, p = w.shape
    assert rows >= ROWS and rows % ROWS == 0, rows
    tile = rows * LANES
    assert p % tile == 0, f"pad to a multiple of {tile} in ops.py"
    n_tiles = p // tile
    return (w.astype(jnp.float32).reshape(n_items, n_tiles * rows, LANES),
            n_tiles)


@partial(jax.jit, static_argnames=("interpret", "strict", "block_rows"))
def count_above_batched(w: jnp.ndarray, t: jnp.ndarray,
                        interpret: bool = True, strict: bool = True,
                        block_rows: int = ROWS):
    """w: (I, P) padded; t: (I,) per-item thresholds → counts (I,) f32.
    ``block_rows``: planner-tunable sublane tile height (multiple of 8)."""
    n_items, p = w.shape
    rows = int(block_rows)
    w3, n_tiles = _tiled(w, rows)
    out = pl.pallas_call(
        partial(_count_batched_kernel, strict=strict),
        grid=(n_items, n_tiles),
        in_specs=[
            pl.BlockSpec((1, rows, LANES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n_items, 1), jnp.float32),
        interpret=interpret,
    )(w3, t.reshape(n_items, 1).astype(jnp.float32))
    return out[:, 0]


@partial(jax.jit, static_argnames=("interpret", "strict", "block_rows"))
def mask_apply_batched(w: jnp.ndarray, t: jnp.ndarray,
                       interpret: bool = True, strict: bool = True,
                       block_rows: int = ROWS):
    """w: (I, P) padded; t: (I,) → w·1[|w| > t_i] per item, (I, P)
    (``strict=False``: |w| ≥ t_i)."""
    n_items, p = w.shape
    rows = int(block_rows)
    w3, n_tiles = _tiled(w, rows)
    out = pl.pallas_call(
        partial(_mask_batched_kernel, strict=strict),
        grid=(n_items, n_tiles),
        in_specs=[
            pl.BlockSpec((1, rows, LANES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, 1), lambda i, j: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, rows, LANES), lambda i, j: (i, j, 0)),
        out_shape=jax.ShapeDtypeStruct(
            (n_items, n_tiles * rows, LANES), jnp.float32),
        interpret=interpret,
    )(w3, t.reshape(n_items, 1).astype(jnp.float32))
    return out.reshape(n_items, p)
