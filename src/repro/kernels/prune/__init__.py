from repro.kernels.prune.ops import topk_mask

__all__ = ["topk_mask"]
