"""Pallas TPU kernels for the paper's hot spots: the k-means C step, the
codebook-dequant serving GEMM, and threshold-bisection pruning. Each
subpackage ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with CPU fallback), ref.py (pure-jnp oracle).

``dispatch`` is the kernel dispatch layer: schemes name a batched
solver ("kmeans_lloyd", "topk_mask") and the registry resolves it per
backend (compiled Pallas on TPU, interpret-mode Pallas or batched jnp
on CPU) for the grouped C step.
"""
# NOTE: no function re-exports here — `from ...kmeans.ops import kmeans`
# would shadow the `repro.kernels.kmeans` subpackage attribute on this
# package and break `import repro.kernels.kmeans.ops`-style access.
from repro.kernels import dispatch

__all__ = ["dispatch"]
