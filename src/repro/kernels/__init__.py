"""Pallas TPU kernels for the paper's hot spots: the k-means C step, the
codebook-dequant serving GEMM, and threshold-bisection pruning. Each
subpackage ships <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper with CPU fallback), ref.py (pure-jnp oracle)."""
