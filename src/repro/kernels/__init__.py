"""Batched C-step kernels for the paper's hot spots: the k-means C
step, the codebook-dequant serving GEMM, threshold-bisection pruning
(Pallas TPU kernels), and the matmul-only batched randomized SVD for
the low-rank C steps (``lowrank`` — pure XLA, no custom calls). Each
subpackage ships <name>.py (the core kernel/math), ops.py (jit'd
driver with CPU fallback), ref.py (pure-jnp/LAPACK oracle).

``dispatch`` is the kernel dispatch layer: schemes name a batched
solver ("kmeans_lloyd", "topk_mask", "lowrank_rsvd", "rank_select",
"project_l1_ball", "soft_threshold") and the registry resolves it per
backend (compiled Pallas on TPU, interpret-mode Pallas or batched jnp
on CPU) for the grouped C step.
"""
# NOTE: no function re-exports here — `from ...kmeans.ops import kmeans`
# would shadow the `repro.kernels.kmeans` subpackage attribute on this
# package and break `import repro.kernels.kmeans.ops`-style access.
from repro.kernels import dispatch

__all__ = ["dispatch"]
