"""Matmul-only batched spectral kernels for the low-rank C step.

The low-rank C steps (paper §4.3) were the last solver family bottoming
out in LAPACK custom calls (``jnp.linalg.svd``/``qr``/``eigh``), which
(a) trace one program per task and (b) have no SPMD partitioning rule,
forcing the shard_map workaround documented in docs/architecture.md.
Part I of the series (Carreira-Perpiñán 2017) only needs the top-R
singular directions, so everything here is built from **batched matmuls
and elementwise ops** over a packed ``(items, m, n)`` stack:

* :func:`jacobi_eigh_batched` — symmetric eigendecomposition of small
  ``(items, k, k)`` Gram matrices by cyclic **parallel-order Jacobi**:
  each step applies ⌊k/2⌋ disjoint Givens rotations as ONE orthogonal
  matrix (two batched k×k matmuls), following a round-robin tournament
  schedule; ``sweeps`` full passes give float32 working accuracy for
  the small k used here.
* :func:`orthonormal_columns_batched` — range-finder orthogonalization
  ``Q = Y·E·Λ^{-1/2}`` from the Jacobi eigendecomposition of
  ``G = YᵀY`` (the matmul-only stand-in for the QR step of Halko
  et al.; near-zero directions are zeroed, never divided by).
* :func:`newton_schulz_orthonormalize` — the alternative coupled
  Newton–Schulz inverse-sqrt orthogonalization (``orth=
  "newton_schulz"``), same matmul-only contract.
* :func:`rsvd_spectrum_batched` — the batched top-k spectrum driver:
  Gaussian sketch (per-item fold_in keys), power iteration with
  re-orthogonalization, Rayleigh-Ritz ``B = QᵀW``, Gram finisher
  ``BBᵀ = EΛEᵀ``. When the sketch width reaches ``min(m, n)`` the
  sketch is skipped and the exact Gram path runs (same primitives,
  no randomness).

Every op here has an SPMD partitioning rule, so a packed group shards
over the ``"items"`` mesh axis under plain GSPMD — no shard_map
workaround (``CompressionScheme.gspmd_safe``). All intermediates are
guarded so an all-zero item (mesh padding lanes, pruned-away matrices)
produces exact-zero factors instead of NaNs.
"""
from __future__ import annotations

from functools import partial

import numpy as np

import jax
import jax.numpy as jnp


def _round_robin_schedule(k: int) -> np.ndarray:
    """Tournament pairing: (k-1) rounds of k/2 disjoint (p, q) pairs
    covering every unordered pair exactly once. ``k`` must be even."""
    assert k % 2 == 0, k
    players = list(range(k))
    rounds = []
    for _ in range(k - 1):
        pairs = [(players[i], players[k - 1 - i]) for i in range(k // 2)]
        rounds.append(sorted((min(p, q), max(p, q)) for p, q in pairs))
        players = [players[0], players[-1]] + players[1:-1]
    return np.asarray(rounds, dtype=np.int32)       # (k-1, k/2, 2)


@partial(jax.jit, static_argnames=("sweeps",))
def jacobi_eigh_batched(a: jnp.ndarray, sweeps: int = 10):
    """Symmetric eigendecomposition of a batch of small matrices.

    ``a``: (I, k, k) symmetric (only intended for PSD Gram matrices) →
    ``(eigvals (I, k) descending, eigvecs (I, k, k))`` with eigenvectors
    in columns: ``a ≈ V · diag(λ) · Vᵀ``.

    Parallel-order cyclic Jacobi: one round applies ⌊k/2⌋ disjoint
    Givens rotations as a single orthogonal matrix J (scatter into an
    identity, then ``A ← JᵀAJ``, ``V ← VJ`` — batched matmuls), and a
    sweep of (k-1) rounds touches every off-diagonal pair once. No
    LAPACK custom call anywhere, so the batch axis shards under plain
    GSPMD. Zero matrices pass through untouched (guarded rotations).
    """
    n_items, k = a.shape[0], a.shape[-1]
    a = a.astype(jnp.float32)
    if k == 1:
        return a[..., 0], jnp.ones_like(a)
    kp = k + (k % 2)                     # pad to even for the schedule
    if kp != k:
        # the padded row/col stays exactly zero: its off-diagonals are
        # zero so every rotation touching it is guarded to identity
        a = jnp.pad(a, ((0, 0), (0, 1), (0, 1)))
    sched = jnp.asarray(_round_robin_schedule(kp))   # (kp-1, kp/2, 2)
    n_rounds = kp - 1
    eye = jnp.eye(kp, dtype=jnp.float32)
    v = jnp.broadcast_to(eye, a.shape)

    def round_step(t, carry):
        a_, v_ = carry
        pq = sched[t % n_rounds]
        p, q = pq[:, 0], pq[:, 1]                    # (kp/2,) each
        app = a_[:, p, p]
        aqq = a_[:, q, q]
        apq = a_[:, p, q]
        # symmetric Schur rotation (Golub & Van Loan §8.4), guarded so
        # an already-zero off-diagonal (incl. all-zero items and the
        # even-padding lane) yields the identity rotation
        live = jnp.abs(apq) > 0.0
        tau = (aqq - app) / (2.0 * jnp.where(live, apq, 1.0))
        t_ = jnp.sign(tau) / (jnp.abs(tau) + jnp.sqrt(1.0 + tau * tau))
        t_ = jnp.where(live, t_, 0.0)
        c = 1.0 / jnp.sqrt(1.0 + t_ * t_)
        s = t_ * c
        j = jnp.broadcast_to(eye, a_.shape)
        j = j.at[:, p, p].set(c).at[:, q, q].set(c)
        j = j.at[:, p, q].set(s).at[:, q, p].set(-s)
        a_ = jnp.einsum("ipk,ikl,ilq->ipq", j.transpose(0, 2, 1), a_, j)
        a_ = 0.5 * (a_ + a_.transpose(0, 2, 1))      # kill drift
        v_ = v_ @ j
        return a_, v_

    a, v = jax.lax.fori_loop(0, sweeps * n_rounds, round_step, (a, v))
    lam = jnp.diagonal(a, axis1=-2, axis2=-1)        # (I, kp)
    order = jnp.argsort(-lam, axis=-1)
    lam = jnp.take_along_axis(lam, order, axis=-1)
    v = jnp.take_along_axis(v, order[:, None, :], axis=-1)
    return lam[:, :k], v[:, :k, :k]


def orthonormal_columns_batched(y: jnp.ndarray, sweeps: int = 6):
    """Orthonormal basis of each item's column span, matmul-only.

    ``y``: (I, m, k) → ``q`` (I, m, k) with orthonormal columns spanning
    (numerically) the same space — via ``G = YᵀY = EΛEᵀ`` and
    ``Q = Y·E·Λ^{-1/2}``. Directions with λ ≤ ε·λ_max are zeroed (an
    all-zero item yields an all-zero Q, never NaN).
    """
    g = jnp.einsum("imk,iml->ikl", y, y)
    lam, e = jacobi_eigh_batched(g, sweeps=sweeps)
    lam_max = jnp.maximum(lam[:, :1], 1e-30)
    keep = lam > 1e-12 * lam_max
    inv = jnp.where(keep,
                    jax.lax.rsqrt(jnp.where(keep, lam, 1.0)), 0.0)
    return jnp.einsum("imk,ikl->iml", y, e) * inv[:, None, :]


def newton_schulz_orthonormalize(y: jnp.ndarray, iters: int = 30):
    """Matmul-only orthonormalization via coupled Newton–Schulz.

    Iterates ``T = (3I − Z·Yk)/2; Yk ← Yk·T; Z ← T·Z`` on ``Yk =
    G/tr(G)`` (G = YᵀY), which converges to ``Z → (G/tr(G))^{-1/2}``;
    then ``Q = Y·Z/√tr(G)``. Purely (I, k, k) matmuls — the classic
    no-LAPACK range-finder orthogonalization. Convergence on the small
    eigenvalues is geometric (×1.5 per step), so very ill-conditioned
    sketches orthonormalize less tightly than the Jacobi route at equal
    cost — which is why the rsvd driver defaults to
    :func:`orthonormal_columns_batched` (``orth="jacobi"``); this is
    the ``orth="newton_schulz"`` alternative. All-zero items yield
    all-zero Q (guarded trace), never NaN.
    """
    y = y.astype(jnp.float32)
    g = jnp.einsum("imk,iml->ikl", y, y)
    k = g.shape[-1]
    eye = jnp.eye(k, dtype=jnp.float32)
    c = jnp.trace(g, axis1=-2, axis2=-1)             # ≥ λ_max for PSD
    live = c > 1e-30
    c_ = jnp.where(live, c, 1.0)[:, None, None]
    yk = g / c_
    zk = jnp.broadcast_to(eye, g.shape)

    def step(_, carry):
        yk_, zk_ = carry
        t = 1.5 * eye - 0.5 * (zk_ @ yk_)
        return yk_ @ t, t @ zk_

    _, zk = jax.lax.fori_loop(0, iters, step, (yk, zk))
    inv_sqrt = zk * jax.lax.rsqrt(c_)
    q = jnp.einsum("imk,ikl->iml", y, inv_sqrt)
    return jnp.where(live[:, None, None], q, 0.0)


def _safe_inv(s: jnp.ndarray) -> jnp.ndarray:
    """1/s where s is meaningfully nonzero (vs the item's s_max), 0
    elsewhere — the division guard for back-solving singular vectors."""
    s_max = jnp.maximum(jnp.max(s, axis=-1, keepdims=True), 1e-30)
    keep = s > 1e-12 * s_max
    return jnp.where(keep, 1.0 / jnp.where(keep, s, 1.0), 0.0)


@partial(jax.jit,
         static_argnames=("k_sketch", "power_iters", "orth",
                          "orth_sweeps", "finish_sweeps"))
def rsvd_spectrum_batched(w: jnp.ndarray, keys: jnp.ndarray,
                          k_sketch: int, power_iters: int = 2,
                          orth: str = "jacobi",
                          orth_sweeps: int = 6, finish_sweeps: int = 12,
                          q0: jnp.ndarray | None = None):
    """Batched top-``k_sketch`` spectrum of a packed item stack.

    ``w``: (I, m, n) f32; ``keys``: (I, 2) uint32 per-item PRNG keys
    (one Gaussian sketch per item — packed groups never share one).
    Returns ``(u (I, m, k), s (I, k), v (I, n, k))`` with
    ``w ≈ u · diag(s) · vᵀ`` on the top-k subspace, all from batched
    matmuls + the Jacobi finisher.

    ``q0`` (optional, (I, m, r0)) **warm-starts the range finder**: the
    previous C step's left factor seeds the sketch basis, topped up
    with fresh Gaussian sketch directions so genuinely new directions
    still enter. At late μ, where Θ barely moves between LC
    boundaries, this lets callers cut power iterations. Zero columns
    in ``q0`` (masked ranks, a rank-0 previous Θ, all-zero items) are
    backfilled with the fresh directions they shadow — the warm basis
    never has less width than the cold one. The exact Gram path
    ignores ``q0`` (it is already deterministic and exact).

    ``orth`` selects the range-finder orthogonalization: ``"jacobi"``
    (default — reuses the Jacobi eigh primitive, robust to
    ill-conditioned sketches) or ``"newton_schulz"`` (the coupled NS
    inverse-sqrt iteration — same matmul-only contract, geometric
    small-eigenvalue convergence). Both keep the solver free of LAPACK
    custom calls.

    When ``k_sketch ≥ min(m, n)`` the randomized range finder is
    pointless and the **exact Gram path** runs instead: eigendecompose
    ``WWᵀ`` (or ``WᵀW``, whichever is smaller) and back-solve the other
    factor — deterministic, keys unused.
    """
    n_items, m, n = w.shape
    w = w.astype(jnp.float32)
    k = min(k_sketch, m, n)

    if k >= min(m, n):                       # exact Gram path
        if m <= n:
            g = jnp.einsum("imn,ikn->imk", w, w)          # W·Wᵀ (I,m,m)
            lam, e = jacobi_eigh_batched(g, sweeps=finish_sweeps)
            s = jnp.sqrt(jnp.maximum(lam, 0.0))
            u = e
            v = jnp.einsum("imn,imk->ink", w, u) * _safe_inv(s)[:, None, :]
        else:
            g = jnp.einsum("imn,imk->ink", w, w)          # Wᵀ·W (I,n,n)
            lam, e = jacobi_eigh_batched(g, sweeps=finish_sweeps)
            s = jnp.sqrt(jnp.maximum(lam, 0.0))
            v = e
            u = jnp.einsum("imn,ink->imk", w, v) * _safe_inv(s)[:, None, :]
        return u[:, :, :k], s[:, :k], v[:, :, :k]

    # randomized range finder (Halko et al.), one sketch per item
    assert orth in ("jacobi", "newton_schulz"), orth
    if orth == "jacobi":
        orthonormalize = partial(orthonormal_columns_batched,
                                 sweeps=orth_sweeps)
    else:
        orthonormalize = newton_schulz_orthonormalize
    omega = jax.vmap(
        lambda key: jax.random.normal(key, (n, k),
                                      dtype=jnp.float32))(keys)
    y_fresh = jnp.einsum("imn,ink->imk", w, omega)
    if q0 is not None:
        # dead q0 columns (masked ranks, a rank-0 previous Θ, all-zero
        # items) would silently shrink the basis below k — each one is
        # backfilled with the fresh sketch direction it shadows, so the
        # warm basis never has less width than the cold one
        r0 = min(q0.shape[-1], k)
        q0 = q0.astype(jnp.float32)[:, :, :r0]
        live = jnp.sum(q0 * q0, axis=1, keepdims=True) > 0.0
        head = jnp.where(live, q0, y_fresh[:, :, :r0])
        y0 = jnp.concatenate([head, y_fresh[:, :, r0:]], axis=-1)
    else:
        y0 = y_fresh
    q = orthonormalize(y0)
    for _ in range(power_iters):
        y = jnp.einsum("imn,ink->imk", w,
                       jnp.einsum("imn,imk->ink", w, q))
        q = orthonormalize(y)
    b = jnp.einsum("imk,imn->ikn", q, w)                  # (I, k, n)
    g = jnp.einsum("ikn,iln->ikl", b, b)                  # B·Bᵀ (I, k, k)
    lam, e = jacobi_eigh_batched(g, sweeps=finish_sweeps)
    s = jnp.sqrt(jnp.maximum(lam, 0.0))
    u = jnp.einsum("imk,ikl->iml", q, e)
    v = jnp.einsum("ikn,ikl->inl", b, e) * _safe_inv(s)[:, None, :]
    return u, s, v
