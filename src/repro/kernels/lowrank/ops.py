"""Batched low-rank C-step drivers — the ``lowrank_rsvd`` and
``rank_select`` entries of the kernel dispatch registry.

Both consume a packed ``(items, m, n)`` group in one call, with the
per-task hyperparameters (target rank, α) and the per-item sketch keys
riding as *traced per-item operands* — the mixed-κ pattern — so tasks
that differ only in rank or α share ONE group and one launch. Factors
come back padded to the group-level ``r_max`` (the widest member's
target; static, from the packed Θ's trailing dim) with columns at or
beyond each item's own rank exactly zero, so the packed decompress and
the per-task trailing-dim slices are both correct.

Matmul-only (see ``lowrank.py``): no LAPACK custom call anywhere, so
these solvers shard under plain GSPMD and the grouped engine skips the
shard_map miscompile workaround for low-rank groups.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.lowrank.lowrank import rsvd_spectrum_batched

#: sketch oversampling beyond r_max. Higher than the textbook 5–10:
#: the C step's parity budget (distortion within 1e-4 relative of the
#: exact SVD) needs the sketch to separate the top-R subspace from a
#: potentially near-flat bulk, and the extra columns cost only tall
#: matmul width (measured: 8 → 1.4e-3 worst relative excess on the
#: bench suite, 16 → 2e-6).
OVERSAMPLE = 16
#: power (subspace) iterations — sharpens flat spectra
POWER_ITERS = 3


def _scaled_masked_factors(u, s, v, rank, r_max):
    """(U·√s, V·√s) truncated to r_max with columns ≥ rank_i zeroed."""
    u, s, v = u[:, :, :r_max], s[:, :r_max], v[:, :, :r_max]
    mask = (jnp.arange(r_max)[None, :]
            < jnp.asarray(rank, jnp.int32)[:, None])
    rs = jnp.sqrt(jnp.maximum(s, 0.0) * mask)
    return u * rs[:, None, :], v * rs[:, None, :]


def _warm_iters(power_iters: int) -> int:
    """Warm-started sketches need fewer subspace refinements: the seed
    basis already spans (most of) the previous top-R subspace, so one
    iteration is redundant at late μ where Θ barely moves. Only one —
    measured on steep (2^-i) spectra, a single warm iteration leaves
    the Gram orthonormalization half-converged (1e-3 relative excess);
    two keep every stress case (stale q0, zeroed columns, flat spectra)
    under 1e-6, inside the documented ≤1e-4 budget."""
    return max(1, power_iters - 1)


def lowrank_rsvd_batched(w: jnp.ndarray, rank: jnp.ndarray,
                         keys: jnp.ndarray, *, r_max: int,
                         oversample: int = OVERSAMPLE,
                         power_iters: int = POWER_ITERS,
                         orth: str = "jacobi",
                         u0: jnp.ndarray | None = None):
    """Batched rank-R truncated SVD over a packed item stack.

    ``w``: (I, m, n) f32; ``rank``: (I,) i32 per-item target ranks
    (traced — mixed-rank tasks share the launch); ``keys``: (I, 2)
    uint32 per-item sketch keys; ``r_max``: static group-wide factor
    width (max member rank). Returns ``(u (I, m, r_max),
    v (I, n, r_max))`` already scaled by √s and masked to each item's
    rank — i.e. Θ = (U√s, V√s) exactly as ``LowRank.compress`` lays it
    out.

    ``u0`` (optional, (I, m, r)) warm-starts the range finder with the
    previous Θ's U factor (ROADMAP: warm-started sketches); a thin
    fresh Gaussian sketch tops the basis up to the full width and the
    power-iteration count drops (:func:`_warm_iters`) — the ≤1e-4
    relative-distortion budget still holds (asserted in
    tests/test_planner.py).
    """
    n_items, m, n = w.shape
    k = min(r_max + oversample, m, n)
    iters = power_iters if u0 is None else _warm_iters(power_iters)
    u, s, v = rsvd_spectrum_batched(w.astype(jnp.float32), keys, k,
                                    power_iters=iters, orth=orth, q0=u0)
    return _scaled_masked_factors(u, s, v, rank, r_max)


def rank_select_batched(w: jnp.ndarray, alpha: jnp.ndarray,
                        keys: jnp.ndarray, mu, *, r_max: int,
                        cost: str = "storage",
                        oversample: int = OVERSAMPLE,
                        power_iters: int = POWER_ITERS,
                        orth: str = "jacobi",
                        u0: jnp.ndarray | None = None):
    """Batched automatic rank selection (Idelbayev & CP, CVPR'20).

    Minimizes ``λ·α_i·C(r) + μ/2·E_i(r)`` over r ∈ {0..r_max} per item,
    with α a traced (I,) operand (mixed-α tasks share the launch). The
    tail energy is computed *sketch-side*: ``E_i(r) = ‖w_i‖² −
    Σ_{j≤r} ŝ_ij²`` — relative to the exact-spectrum objective this
    adds the constant ``Σ_{j>r_max} σ_j²`` to every candidate, so the
    argmin is unchanged, and needs only the top-r_max singular values.
    Returns ``(u (I, m, r_max), v (I, n, r_max), rank (I,) i32)`` with
    the factors scaled and masked like ``RankSelection.compress``.
    """
    n_items, m, n = w.shape
    w = w.astype(jnp.float32)
    k = min(r_max + oversample, m, n)
    iters = power_iters if u0 is None else _warm_iters(power_iters)
    u, s, v = rsvd_spectrum_batched(w, keys, k, power_iters=iters,
                                    orth=orth, q0=u0)
    s2 = jnp.maximum(s[:, :r_max], 0.0) ** 2                 # (I, r_max)
    captured = jnp.concatenate(
        [jnp.zeros((n_items, 1), jnp.float32), jnp.cumsum(s2, axis=-1)],
        axis=-1)                                             # (I, r_max+1)
    total = jnp.sum(w * w, axis=(1, 2), keepdims=False)[:, None]
    tail = jnp.maximum(total - captured, 0.0)
    unit = float(m + n) if cost == "storage" else 2.0 * float(m + n)
    ranks = jnp.arange(r_max + 1, dtype=jnp.float32)[None, :]
    obj = (jnp.asarray(alpha, jnp.float32)[:, None] * unit * ranks
           + 0.5 * mu * tail)
    r_star = jnp.argmin(obj, axis=-1).astype(jnp.int32)
    u, v = _scaled_masked_factors(u, s, v, r_star, r_max)
    return u, v, r_star
