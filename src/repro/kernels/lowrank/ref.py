"""Exact-SVD oracles for the batched low-rank solvers (LAPACK; tests
and benches only — the dispatch path never calls these)."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def svd_topr_batched_ref(w: jnp.ndarray, r: int):
    """Exact per-item SVD, truncated to rank r.

    ``w``: (I, m, n) → (u (I, m, r), s (I, r), v (I, n, r)). The oracle
    the randomized solver's reconstruction distortion is measured
    against.
    """
    def one(wi):
        u, s, vt = jnp.linalg.svd(wi.astype(jnp.float32),
                                  full_matrices=False)
        return u[:, :r], s[:r], vt[:r, :].T

    return jax.vmap(one)(w)


def tail_distortion_ref(w: jnp.ndarray, r: jnp.ndarray) -> jnp.ndarray:
    """Per-item optimal rank-r distortion Σ_{i>r} σ_i² (exact SVD).

    ``w``: (I, m, n); ``r``: (I,) int → (I,) f32. This is the
    Eckart–Young lower bound any rank-r factorization's ‖w − UVᵀ‖² is
    compared to.
    """
    def one(wi, ri):
        s = jnp.linalg.svd(wi.astype(jnp.float32), compute_uv=False)
        mask = jnp.arange(s.shape[0]) >= ri
        return jnp.sum(jnp.where(mask, s * s, 0.0))

    return jax.vmap(one)(w, jnp.asarray(r))
