"""Batched low-rank C-step solvers (matmul-only randomized SVD)."""
