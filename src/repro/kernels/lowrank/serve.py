"""Serving op for low-rank-factored weights.

After the LC low-rank C step, a weight W (K, N) of rank r is stored as
factors U (K, r), Vᵀ (r, N). Decode is memory-bound: streaming the
factors costs r·(K+N) weight reads instead of K·N, so for r ≪ KN/(K+N)
the factored matmul is the roofline win — W is never materialized, in
HBM or in the kernel.

Two thin chained GEMMs lower to plain XLA dots (MXU-friendly on TPU,
no custom call), so there is no Pallas body here — the "kernel" is the
contraction order.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def lowrank_matmul(x: jnp.ndarray, u: jnp.ndarray,
                   vt: jnp.ndarray) -> jnp.ndarray:
    """y = x @ (u @ vt) computed as (x @ u) @ vt.

    x: (..., K); u: (K, r); vt: (r, N) → y: (..., N). The parenthesized
    order is the entire point: FLOPs and weight bytes scale with r, not
    K·N.
    """
    with jax.named_scope("lowrank_matmul"):
        h = x @ u.astype(x.dtype)
        return h @ vt.astype(x.dtype)


def materialize_lowrank(u: jnp.ndarray, vt: jnp.ndarray) -> jnp.ndarray:
    """Dense W = u @ vt — for parity checks and non-matmul uses (embed
    lookup); never on the decode hot path."""
    return u @ vt
