"""Jit'd public wrappers for the k-means C-step kernel.

``use_pallas="auto"`` runs the Pallas kernel in interpret mode on CPU
(for validation) and compiled on TPU; the jnp reference path produces
identical results and is what the GSPMD-sharded C step uses when the
weight vector is distributed (the kernel is a per-shard building block).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.kmeans import ref
from repro.kernels.kmeans.kmeans import LANES, ROWS, kmeans_assign_moments


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def assign_moments(w: jnp.ndarray, codebook: jnp.ndarray,
                   use_pallas: bool | str = "auto"):
    """Nearest-centroid assignment + cluster moments; pads internally."""
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.kmeans_assign_moments_ref(w, codebook)
    p = w.shape[0]
    tile = ROWS * LANES
    pad = (-p) % tile
    if pad:
        # pad with +inf-distance sentinel: clone of codebook[0] so padded
        # elements land in cluster 0; subtract them from the moments after
        wp = jnp.concatenate([w, jnp.full((pad,), codebook[0], w.dtype)])
    else:
        wp = w
    assign, sums, counts = kmeans_assign_moments(
        wp, codebook, interpret=not _on_tpu())
    if pad:
        sums = sums.at[0].add(-float(pad) * codebook[0])
        counts = counts.at[0].add(-float(pad))
        assign = assign[:p]
    return assign, sums, counts


def lloyd_step(w: jnp.ndarray, codebook: jnp.ndarray,
               use_pallas: bool | str = "auto") -> jnp.ndarray:
    _, sums, counts = assign_moments(w, codebook, use_pallas)
    new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), codebook)
    return jnp.sort(new)


def kmeans(w: jnp.ndarray, codebook0: jnp.ndarray, iters: int = 25,
           use_pallas: bool | str = "auto"):
    """Full Lloyd loop on the kernel; returns (codebook, assignments)."""
    cb = jnp.sort(codebook0)
    for _ in range(iters):
        cb = lloyd_step(w, cb, use_pallas)
    assign, _, _ = assign_moments(w, cb, use_pallas)
    return cb, assign
