"""Jit'd public wrappers for the k-means C-step kernel.

``use_pallas="auto"`` runs the Pallas kernel in interpret mode on CPU
(for validation) and compiled on TPU; the jnp reference path produces
identical results and is what the GSPMD-sharded C step uses when the
weight vector is distributed (the kernel is a per-shard building block).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.kmeans import ref
from repro.kernels.kmeans.kmeans import (
    LANES, ROWS, kmeans_assign_moments, kmeans_assign_moments_batched)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def assign_moments(w: jnp.ndarray, codebook: jnp.ndarray,
                   use_pallas: bool | str = "auto"):
    """Nearest-centroid assignment + cluster moments; pads internally."""
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.kmeans_assign_moments_ref(w, codebook)
    p = w.shape[0]
    tile = ROWS * LANES
    pad = (-p) % tile
    if pad:
        # pad with +inf-distance sentinel: clone of codebook[0] so padded
        # elements land in cluster 0; subtract them from the moments after
        wp = jnp.concatenate([w, jnp.full((pad,), codebook[0], w.dtype)])
    else:
        wp = w
    assign, sums, counts = kmeans_assign_moments(
        wp, codebook, interpret=not _on_tpu())
    if pad:
        sums = sums.at[0].add(-float(pad) * codebook[0])
        counts = counts.at[0].add(-float(pad))
        assign = assign[:p]
    return assign, sums, counts


def lloyd_step(w: jnp.ndarray, codebook: jnp.ndarray,
               use_pallas: bool | str = "auto") -> jnp.ndarray:
    _, sums, counts = assign_moments(w, codebook, use_pallas)
    new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), codebook)
    return jnp.sort(new)


def kmeans(w: jnp.ndarray, codebook0: jnp.ndarray, iters: int = 25,
           use_pallas: bool | str = "auto"):
    """Full Lloyd loop on the kernel; returns (codebook, assignments)."""
    cb = jnp.sort(codebook0)
    for _ in range(iters):
        cb = lloyd_step(w, cb, use_pallas)
    assign, _, _ = assign_moments(w, cb, use_pallas)
    return cb, assign


# ----------------------------------------------------------------------
# batched solver — the "kmeans_lloyd" entry of the kernel dispatch layer
# ----------------------------------------------------------------------
def assign_moments_batched(w: jnp.ndarray, codebooks: jnp.ndarray,
                           interpret: bool = True,
                           block_rows: int = ROWS):
    """Batched assignment + moments over a packed (I, P) item stack;
    pads each row internally (pad values clone each item's
    ``codebook[0]`` so padded elements land in cluster 0, then their
    contribution is subtracted from the moments). ``block_rows`` is the
    planner-chosen items-grid tile height (padding adapts to it)."""
    n_items, p = w.shape
    tile = int(block_rows) * LANES
    pad = (-p) % tile
    if pad:
        wp = jnp.concatenate(
            [w, jnp.broadcast_to(codebooks[:, :1], (n_items, pad))
             .astype(w.dtype)], axis=1)
    else:
        wp = w
    assign, sums, counts = kmeans_assign_moments_batched(
        wp, codebooks, interpret=interpret, block_rows=int(block_rows))
    if pad:
        sums = sums.at[:, 0].add(-float(pad) * codebooks[:, 0])
        counts = counts.at[:, 0].add(-float(pad))
        assign = assign[:, :p]
    return assign, sums, counts


def kmeans_batched(w: jnp.ndarray, codebooks0: jnp.ndarray,
                   kvalid: jnp.ndarray | None = None,
                   iters: int = 25, impl: str = "jnp",
                   block_rows: int = ROWS):
    """Per-item Lloyd loop over a packed (I, P) item stack with per-item
    (I, K) warm-start codebooks → (codebooks (I, K), assign (I, P)).

    ``kvalid`` (optional, (I,) i32) is the traced per-item count of
    *live* codebook entries — the mixed-K grouping operand. Codebooks
    arrive padded to the group-wide ``K_max`` (trailing entries are
    don't-care); entries at or beyond ``kvalid_i`` are pinned to +inf,
    so no weight ever assigns to them (distance +inf), their cluster
    moments stay empty, and the ascending sort keeps each item's live
    entries in the first ``kvalid_i`` slots — which is what lets the
    grouped engine slice per-task codebooks back out of the padded
    stack. With ``kvalid=None`` (or all-K_max) the masking is the
    identity and the solve is unchanged (bit-identical on ``"jnp"``).

    ``impl``: ``"jnp"`` vmaps the core compare-count solver
    (bit-identical to the per-task scheme path); ``"interpret"`` /
    ``"pallas"`` run the batched items-grid kernel — one pallas_call per
    Lloyd step for the whole group, per-item codebooks VMEM-resident.
    The kernel's moment accumulation order differs from the jnp masked
    reduce, so codebooks agree to float tolerance (not bitwise); see
    tests/test_kernel_dispatch.py for the enforced bounds.
    """
    if kvalid is not None:
        k_max = codebooks0.shape[-1]
        live = (jnp.arange(k_max)[None, :]
                < jnp.asarray(kvalid, jnp.int32)[:, None])
        codebooks0 = jnp.where(live, codebooks0.astype(jnp.float32),
                               jnp.inf)
    if impl == "jnp":
        # deferred import: kernels must stay importable without core
        # (core.grouping imports the dispatch layer at module load)
        from repro.core.schemes.quantize import kmeans_1d
        return jax.vmap(lambda wi, ci: kmeans_1d(wi, ci, iters))(
            w, codebooks0)
    interpret = impl != "pallas"
    w = w.astype(jnp.float32)
    cb = jnp.sort(codebooks0.astype(jnp.float32), axis=-1)
    for _ in range(iters):
        _, sums, counts = assign_moments_batched(
            w, cb, interpret=interpret, block_rows=block_rows)
        cb = jnp.sort(jnp.where(counts > 0,
                                sums / jnp.maximum(counts, 1.0), cb),
                      axis=-1)
    assign, _, _ = assign_moments_batched(w, cb, interpret=interpret,
                                          block_rows=block_rows)
    return cb, assign
