from repro.kernels.kmeans.ops import assign_moments, kmeans, lloyd_step

__all__ = ["assign_moments", "kmeans", "lloyd_step"]
