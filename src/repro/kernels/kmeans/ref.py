"""Pure-jnp oracle for the k-means assignment + cluster-moment kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def kmeans_assign_moments_ref(w: jnp.ndarray, codebook: jnp.ndarray):
    """w: (P,) f32; codebook: (K,) f32 →
    (assign (P,) int32, sums (K,) f32, counts (K,) f32).

    Nearest-centroid by explicit distance argmin (the semantics the Pallas
    kernel must match bit-for-bit up to ties)."""
    d = (w[:, None] - codebook[None, :]) ** 2          # (P, K)
    assign = jnp.argmin(d, axis=-1).astype(jnp.int32)
    k = codebook.shape[0]
    sums = jax.ops.segment_sum(w, assign, num_segments=k)
    counts = jax.ops.segment_sum(jnp.ones_like(w), assign, num_segments=k)
    return assign, sums, counts


def lloyd_step_ref(w: jnp.ndarray, codebook: jnp.ndarray):
    _, sums, counts = kmeans_assign_moments_ref(w, codebook)
    return jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), codebook)


def kmeans_assign_moments_batched_ref(w: jnp.ndarray,
                                      codebooks: jnp.ndarray):
    """Per-item oracle for the batched items-grid kernel:
    w (I, P), codebooks (I, K) → (assign (I, P), sums (I, K),
    counts (I, K))."""
    return jax.vmap(kmeans_assign_moments_ref)(w, codebooks)
