"""Pallas TPU kernel: fused k-means assignment + cluster moments.

The adaptive-quantization C step (paper §4.1) assigns every weight to its
nearest codebook entry and accumulates per-cluster Σw / counts. On GPU
this is a gather + atomicAdd pattern; the TPU-native shape is:

* weights stream HBM→VMEM in (ROWS, 128) tiles (lane dim = 128);
* the codebook (K ≤ 256 f32) stays VMEM-resident across the whole grid
  (BlockSpec index_map pins block (0,) for every grid step);
* distance/argmin run on the VPU via broadcast-subtract-square over the
  K axis (K is small — the (r, 128, K) intermediate fits VMEM);
* cluster moments use **grid-sequential accumulation** into the output
  ref — TPU Pallas grids execute sequentially per core, which replaces
  CUDA atomics (`@pl.when(step == 0)` zero-init, then `+=`).

Two entry points share the kernel body:

* :func:`kmeans_assign_moments` — one weight vector, grid ``(n_tiles,)``.
* :func:`kmeans_assign_moments_batched` — a packed *group* of items
  (the grouped C step's stacked leading axis), grid
  ``(items, n_tiles)``. Each item brings its own VMEM-resident codebook
  (BlockSpec ``(1, K)`` indexed by the item coordinate) and its own
  moment accumulators; the tile coordinate is the fast axis, so the
  per-item accumulation runs grid-sequentially exactly like the
  unbatched kernel, and one ``pallas_call`` solves the whole group
  instead of vmapping the jnp solver.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

ROWS = 8           # sublane tile rows
LANES = 128        # TPU lane width


def _kernel(w_ref, cb_ref, assign_ref, sums_ref, counts_ref, *, k: int):
    step = pl.program_id(0)
    w = w_ref[...]                                    # (ROWS, LANES) f32
    cb = cb_ref[...]                                  # (1, K) f32
    d = (w[:, :, None] - cb[0][None, None, :]) ** 2   # (ROWS, LANES, K)
    assign = jnp.argmin(d, axis=-1).astype(jnp.int32)
    assign_ref[...] = assign
    onehot = (assign[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2))
    onehot = onehot.astype(jnp.float32)
    part_sums = jnp.sum(w[:, :, None] * onehot, axis=(0, 1))[None, :]
    part_counts = jnp.sum(onehot, axis=(0, 1))[None, :]

    @pl.when(step == 0)
    def _init():
        sums_ref[...] = part_sums
        counts_ref[...] = part_counts

    @pl.when(step != 0)
    def _accum():
        sums_ref[...] += part_sums
        counts_ref[...] += part_counts


@partial(jax.jit, static_argnames=("interpret",))
def kmeans_assign_moments(w: jnp.ndarray, codebook: jnp.ndarray,
                          interpret: bool = True):
    """w: (P,) f32 (P % (ROWS·LANES) == 0 after ops.py padding);
    codebook: (K,) f32 → (assign (P,) i32, sums (K,), counts (K,))."""
    p = w.shape[0]
    k = codebook.shape[0]
    tile = ROWS * LANES
    assert p % tile == 0, f"pad to a multiple of {tile} in ops.py"
    n_tiles = p // tile
    w2 = w.astype(jnp.float32).reshape(n_tiles * ROWS, LANES)
    cb2 = codebook.astype(jnp.float32).reshape(1, k)

    assign2, sums2, counts2 = pl.pallas_call(
        partial(_kernel, k=k),
        grid=(n_tiles,),
        in_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),   # pinned in VMEM
        ],
        out_specs=[
            pl.BlockSpec((ROWS, LANES), lambda i: (i, 0)),
            pl.BlockSpec((1, k), lambda i: (0, 0)),   # sequential accum
            pl.BlockSpec((1, k), lambda i: (0, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_tiles * ROWS, LANES), jnp.int32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
            jax.ShapeDtypeStruct((1, k), jnp.float32),
        ],
        interpret=interpret,
    )(w2, cb2)
    return assign2.reshape(p), sums2[0], counts2[0]


def _batched_kernel(w_ref, cb_ref, assign_ref, sums_ref, counts_ref,
                    *, k: int):
    tile = pl.program_id(1)                           # fast axis: tiles
    w = w_ref[0]                                      # (ROWS, LANES) f32
    cb = cb_ref[0]                                    # (K,) f32
    d = (w[:, :, None] - cb[None, None, :]) ** 2      # (ROWS, LANES, K)
    assign = jnp.argmin(d, axis=-1).astype(jnp.int32)
    assign_ref[0] = assign
    onehot = (assign[:, :, None]
              == jax.lax.broadcasted_iota(jnp.int32, (1, 1, k), 2))
    onehot = onehot.astype(jnp.float32)
    part_sums = jnp.sum(w[:, :, None] * onehot, axis=(0, 1))[None, :]
    part_counts = jnp.sum(onehot, axis=(0, 1))[None, :]

    # the item's accumulator block is revisited once per tile; the grid
    # is row-major (tile fastest), so `tile == 0` re-inits per item
    @pl.when(tile == 0)
    def _init():
        sums_ref[...] = part_sums
        counts_ref[...] = part_counts

    @pl.when(tile != 0)
    def _accum():
        sums_ref[...] += part_sums
        counts_ref[...] += part_counts


@partial(jax.jit, static_argnames=("interpret", "block_rows"))
def kmeans_assign_moments_batched(w: jnp.ndarray, codebooks: jnp.ndarray,
                                  interpret: bool = True,
                                  block_rows: int = ROWS):
    """w: (I, P) f32 (P % (block_rows·LANES) == 0 after ops.py padding);
    codebooks: (I, K) f32 → (assign (I, P) i32, sums (I, K),
    counts (I, K)) — one pallas_call for the whole packed item group.

    ``block_rows`` is the planner-tunable sublane tile height (default
    the f32 minimum, 8; must be a multiple of 8). Larger tiles amortize
    grid overhead at the cost of VMEM per step — the group planner
    (``analysis/cost.choose_block_rows``) picks it per group.
    """
    n_items, p = w.shape
    k = codebooks.shape[-1]
    rows = int(block_rows)
    assert rows >= ROWS and rows % ROWS == 0, rows
    tile = rows * LANES
    assert p % tile == 0, f"pad to a multiple of {tile} in ops.py"
    n_tiles = p // tile
    w3 = w.astype(jnp.float32).reshape(n_items, n_tiles * rows, LANES)
    cb2 = codebooks.astype(jnp.float32).reshape(n_items, k)

    assign3, sums2, counts2 = pl.pallas_call(
        partial(_batched_kernel, k=k),
        grid=(n_items, n_tiles),
        in_specs=[
            pl.BlockSpec((1, rows, LANES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),  # per-item VMEM
        ],
        out_specs=[
            pl.BlockSpec((1, rows, LANES), lambda i, j: (i, j, 0)),
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),  # per-item accum
            pl.BlockSpec((1, k), lambda i, j: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n_items, n_tiles * rows, LANES),
                                 jnp.int32),
            jax.ShapeDtypeStruct((n_items, k), jnp.float32),
            jax.ShapeDtypeStruct((n_items, k), jnp.float32),
        ],
        interpret=interpret,
    )(w3, cb2)
    return assign3.reshape(n_items, p), sums2, counts2
