"""Pure-jnp oracle for the codebook-dequant GEMM."""
from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(x: jnp.ndarray, idx: jnp.ndarray,
                     codebook: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K) f32/bf16; idx: (K, N) uint8 codebook indices;
    codebook: (C,) f32 → y (M, N) f32 = x @ codebook[idx]."""
    w = codebook[idx.astype(jnp.int32)]            # (K, N) f32
    return x.astype(jnp.float32) @ w
