"""Pure-jnp oracle for the codebook-dequant GEMM."""
from __future__ import annotations

import jax.numpy as jnp


def quant_matmul_ref(x: jnp.ndarray, idx: jnp.ndarray,
                     codebook: jnp.ndarray) -> jnp.ndarray:
    """x: (M, K) f32/bf16; idx: (K, N) uint8 codebook indices;
    codebook: (C,) f32 → y (M, N) f32 = x @ codebook[idx]."""
    w = codebook[idx.astype(jnp.int32)]            # (K, N) f32
    return x.astype(jnp.float32) @ w


def unpack4_ref(packed: jnp.ndarray) -> jnp.ndarray:
    """(K/2, N) packed bytes → (K, N) uint8 indices (row 2r = low
    nibble, row 2r+1 = high nibble)."""
    lo = packed & jnp.uint8(0x0F)
    hi = packed >> jnp.uint8(4)
    k2, n = packed.shape
    return jnp.stack([lo, hi], axis=1).reshape(2 * k2, n)


def quant_matmul_packed_ref(x: jnp.ndarray, packed: jnp.ndarray,
                            codebook: jnp.ndarray) -> jnp.ndarray:
    """Oracle for the 4-bit path: unpack to full uint8 indices, then the
    dense dequant matmul."""
    return quant_matmul_ref(x, unpack4_ref(packed), codebook)
