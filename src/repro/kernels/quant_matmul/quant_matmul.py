"""Pallas TPU kernels: fused codebook-dequant GEMM for compressed serving.

After LC adaptive quantization, weights are stored as uint8 codebook
indices (+ a K≤16-entry f32 codebook). Serving decode is memory-bound —
streaming uint8 indices instead of bf16 weights cuts the dominant HBM
term ~2× and **4-bit packing** (two indices per byte, unpacked with
nibble bitwise ops *inside* the kernel) cuts it ~4×; full-width weights
never touch HBM in either form.

TPU adaptation of the GPU LUT-gather: Mosaic has no fast VMEM gather by
vector index, so dequant is a **compare–select accumulation over the K
codebook entries** (K ≤ 16 ⇒ 16 VPU select-FMAs per tile element,
amortized over the MXU matmul): W_tile = Σ_c cb[c]·(idx_tile == c).

Grid (M/bm, N/bn, K/bk), k innermost; the f32 accumulator lives in the
output ref block, zero-initialized at k==0 (grid-sequential revisiting).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _kernel(x_ref, idx_ref, cb_ref, y_ref, *, n_codes: int):
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    x = x_ref[...]                                   # (bm, bk)
    idx = idx_ref[...]                               # (bk, bn) uint8
    cb = cb_ref[...]                                 # (1, C)
    # compare–select dequant: W = Σ_c cb[c] · (idx == c)
    w = jnp.zeros(idx.shape, jnp.float32)
    for c in range(n_codes):
        w += jnp.where(idx == c, cb[0, c], 0.0)
    y_ref[...] += jnp.dot(x.astype(jnp.float32), w,
                          preferred_element_type=jnp.float32)


@partial(jax.jit, static_argnames=("bm", "bn", "bk", "interpret"))
def quant_matmul(x: jnp.ndarray, idx: jnp.ndarray, codebook: jnp.ndarray,
                 bm: int = 128, bn: int = 128, bk: int = 512,
                 interpret: bool = True) -> jnp.ndarray:
    """y = x @ codebook[idx]. Shapes must tile exactly (ops.py pads)."""
    m, k = x.shape
    k2, n = idx.shape
    assert k == k2
    c = codebook.shape[0]
    assert c <= 16, "compare-select dequant is for K ≤ 16 codebooks"
    bm, bn, bk = min(bm, m), min(bn, n), min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0

    return pl.pallas_call(
        partial(_kernel, n_codes=c),
        grid=(m // bm, n // bn, k // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, c), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x, idx, codebook.reshape(1, c).astype(jnp.float32))


# ----------------------------------------------------------------------
# 4-bit packed variant (two indices per byte)
# ----------------------------------------------------------------------
def _packed_kernel(xe_ref, xo_ref, packed_ref, cb_ref, y_ref, *,
                   n_codes: int):
    """Packed byte b at (r, j) holds indices of W rows 2r (low nibble)
    and 2r+1 (high nibble), column j. The caller pre-splits x into its
    even and odd K-columns, so unpacking never reshapes/interleaves in
    VMEM: y += x_even @ W_low + x_odd @ W_high.
    """
    k_step = pl.program_id(2)

    @pl.when(k_step == 0)
    def _init():
        y_ref[...] = jnp.zeros_like(y_ref)

    xe = xe_ref[...].astype(jnp.float32)             # (bm, bk2)
    xo = xo_ref[...].astype(jnp.float32)             # (bm, bk2)
    packed = packed_ref[...]                          # (bk2, bn) uint8
    cb = cb_ref[...]                                  # (1, C)
    lo = packed & jnp.uint8(0x0F)
    hi = packed >> jnp.uint8(4)
    w_lo = jnp.zeros(packed.shape, jnp.float32)
    w_hi = jnp.zeros(packed.shape, jnp.float32)
    for c in range(n_codes):
        w_lo += jnp.where(lo == c, cb[0, c], 0.0)
        w_hi += jnp.where(hi == c, cb[0, c], 0.0)
    y_ref[...] += (jnp.dot(xe, w_lo, preferred_element_type=jnp.float32)
                   + jnp.dot(xo, w_hi,
                             preferred_element_type=jnp.float32))


@partial(jax.jit, static_argnames=("bm", "bn", "bk2", "interpret"))
def quant_matmul_packed(x_even: jnp.ndarray, x_odd: jnp.ndarray,
                        packed: jnp.ndarray, codebook: jnp.ndarray,
                        bm: int = 128, bn: int = 128, bk2: int = 256,
                        interpret: bool = True) -> jnp.ndarray:
    """y = x @ codebook[unpack4(packed)] with x pre-split into even/odd
    K-columns (x_even = x[:, 0::2], x_odd = x[:, 1::2]). Shapes must
    tile exactly (ops.py pads)."""
    m, k2 = x_even.shape
    assert x_odd.shape == (m, k2)
    k2b, n = packed.shape
    assert k2 == k2b
    c = codebook.shape[0]
    assert c <= 16, "4-bit packing needs a K ≤ 16 codebook"
    bm, bn, bk2 = min(bm, m), min(bn, n), min(bk2, k2)
    assert m % bm == 0 and n % bn == 0 and k2 % bk2 == 0

    return pl.pallas_call(
        partial(_packed_kernel, n_codes=c),
        grid=(m // bm, n // bn, k2 // bk2),
        in_specs=[
            pl.BlockSpec((bm, bk2), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm, bk2), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk2, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, c), lambda i, j, kk: (0, 0)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=interpret,
    )(x_even, x_odd, packed, codebook.reshape(1, c).astype(jnp.float32))
