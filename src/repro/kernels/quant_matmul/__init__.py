from repro.kernels.quant_matmul.ops import matmul, pack_quantized

__all__ = ["matmul", "pack_quantized"]
