"""Public wrappers: padded/tiled codebook-dequant GEMMs (uint8 and
4-bit packed) + helpers to put a model's quantized weights into kernel
layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul import ref
from repro.kernels.quant_matmul.quant_matmul import (
    quant_matmul, quant_matmul_packed)


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(x: jnp.ndarray, idx: jnp.ndarray, codebook: jnp.ndarray,
           use_pallas: bool | str = "auto", **tiles) -> jnp.ndarray:
    """y = x @ codebook[idx], padding to tile boundaries as needed."""
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.quant_matmul_ref(x, idx, codebook)
    m, k = x.shape
    n = idx.shape[1]
    bm = min(tiles.get("bm", 128), max(8, m))
    bn = min(tiles.get("bn", 128), n)
    bk = min(tiles.get("bk", 512), k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    idxp = jnp.pad(idx, ((0, pk), (0, pn)))
    y = quant_matmul(xp, idxp, codebook, bm=bm, bn=bn, bk=bk,
                     interpret=not _on_tpu())
    return y[:m, :n]


def matmul_packed(x: jnp.ndarray, packed: jnp.ndarray,
                  codebook: jnp.ndarray, use_pallas: bool | str = "auto",
                  **tiles) -> jnp.ndarray:
    """y = x @ codebook[unpack4(packed)] — the 4-bit serving GEMM.

    ``packed``: (ceil(K/2), N) bytes from :func:`pack4`. x: (M, K) with
    K = 2·packed.shape[0] (pad x with a zero column for odd K before
    packing). The x split into even/odd K-columns happens here, outside
    the kernel, so the kernel body is two dequant-matmuls per tile with
    no VMEM interleave.
    """
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.quant_matmul_packed_ref(x, packed, codebook)
    m, k = x.shape
    k2, n = packed.shape
    assert k == 2 * k2, (x.shape, packed.shape)
    x_even, x_odd = x[:, 0::2], x[:, 1::2]
    bm = min(tiles.get("bm", 128), max(8, m))
    bn = min(tiles.get("bn", 128), n)
    bk2 = min(tiles.get("bk2", 256), k2)
    pm, pn, pk2 = (-m) % bm, (-n) % bn, (-k2) % bk2
    xe = jnp.pad(x_even, ((0, pm), (0, pk2)))
    xo = jnp.pad(x_odd, ((0, pm), (0, pk2)))
    pp = jnp.pad(packed, ((0, pk2), (0, pn)))
    y = quant_matmul_packed(xe, xo, pp, codebook, bm=bm, bn=bn, bk2=bk2,
                            interpret=not _on_tpu())
    return y[:m, :n]


def pack_quantized(w: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Dense weight matrix → uint8 index matrix under ``codebook``."""
    mid = (codebook[1:] + codebook[:-1]) * 0.5
    return jnp.searchsorted(mid, w).astype(jnp.uint8)


def pack4(idx: jnp.ndarray) -> jnp.ndarray:
    """(K, N) uint8 indices (< 16) → (ceil(K/2), N) packed bytes.

    Row 2r lands in the low nibble, row 2r+1 in the high nibble. Odd K
    pads one index-0 row — harmless as long as the matching x column is
    zero (ops-level padding guarantees this).
    """
    k, n = idx.shape
    if k % 2:
        idx = jnp.pad(idx, ((0, 1), (0, 0)))
    lo = idx[0::2]
    hi = idx[1::2]
    return (lo | (hi << jnp.uint8(4))).astype(jnp.uint8)


def unpack4(packed: jnp.ndarray) -> jnp.ndarray:
    """Inverse of :func:`pack4` (up to the odd-K pad row)."""
    return ref.unpack4_ref(packed)
