"""Public wrapper: padded/tiled codebook-dequant GEMM + helpers to put a
model's quantized weights into kernel layout."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul import ref
from repro.kernels.quant_matmul.quant_matmul import quant_matmul


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def matmul(x: jnp.ndarray, idx: jnp.ndarray, codebook: jnp.ndarray,
           use_pallas: bool | str = "auto", **tiles) -> jnp.ndarray:
    """y = x @ codebook[idx], padding to tile boundaries as needed."""
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if not use_pallas:
        return ref.quant_matmul_ref(x, idx, codebook)
    m, k = x.shape
    n = idx.shape[1]
    bm = min(tiles.get("bm", 128), max(8, m))
    bn = min(tiles.get("bn", 128), n)
    bk = min(tiles.get("bk", 512), k)
    pm, pn, pk = (-m) % bm, (-n) % bn, (-k) % bk
    xp = jnp.pad(x, ((0, pm), (0, pk)))
    idxp = jnp.pad(idx, ((0, pk), (0, pn)))
    y = quant_matmul(xp, idxp, codebook, bm=bm, bn=bn, bk=bk,
                     interpret=not _on_tpu())
    return y[:m, :n]


def pack_quantized(w: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Dense weight matrix → uint8 index matrix under ``codebook``."""
    mid = (codebook[1:] + codebook[:-1]) * 0.5
    return jnp.searchsorted(mid, w).astype(jnp.uint8)
