"""Kernel dispatch layer: named batched C-step solvers, per backend.

The paper's decoupling claim — the C step is a swappable signal-
compression subroutine — only stays free if the *implementation* of a
solve can change underneath a scheme without the scheme (or the grouped
engine, or the trainer) noticing. This registry is that seam:

* a scheme declares a **solver name** (``CompressionScheme.solver``,
  e.g. ``"kmeans_lloyd"``, ``"topk_mask"``) and implements
  ``compress_batched`` against the solver's calling convention;
* the grouped C step (``core/grouping.py``) resolves the name to a
  concrete implementation **per backend** at trace time:

  ============  =====================================================
  backend       implementation
  ============  =====================================================
  ``pallas``    batched items-grid Pallas kernel, compiled (TPU)
  ``interpret`` the same Pallas kernel, ``interpret=True`` (CPU/CI —
                exercises the kernel path without a TPU)
  ``jnp``       pure-jnp batched solver, bit-identical to the legacy
                vmapped scheme program
  ============  =====================================================

* requests are resolved honestly: ``"auto"`` picks ``pallas`` on TPU
  and ``jnp`` elsewhere; an explicit ``"pallas"`` off-TPU falls back to
  ``interpret`` (the kernel still runs, slowly) rather than silently
  switching algorithms; unknown solver names resolve to ``(None,
  None)`` so callers fall back to the vmap path and
  ``describe_groups`` reports what actually ran.

Solver calling conventions (all arrays carry the packed leading item
axis ``I``):

* ``kmeans_lloyd(w (I,P) f32, codebooks0 (I,K_max) f32,
  kvalid (I,) i32, *, iters) -> (codebooks (I,K_max) f32,
  assign (I,P) i32)`` — codebooks are padded to the group-wide
  ``K_max``; ``kvalid`` is the traced per-item live-entry count, so
  tasks differing only in K share one launch (mixed-K grouping).
* ``topk_mask(w (I,P) f32, kappa (I,) i32) -> theta (I,P) f32`` —
  κ is a *traced per-item operand*, which is what lets tasks that
  differ only in κ share one kernel launch (mixed-κ grouping).
* ``project_l1_ball(w (I,P) f32, radius (I,) f32) -> theta (I,P)
  f32`` — per-item ℓ1-ball projection, one sort+cumsum over the item
  axis (mixed-radius grouping).
* ``soft_threshold(w (I,P) f32, alpha (I,) f32, mu) -> theta (I,P)
  f32`` — the ℓ1-penalty prox at α_i/μ (mixed-α grouping).
* ``lowrank_rsvd(w (I,m,n) f32, rank (I,) i32, keys (I,2) u32, *,
  r_max) -> (u (I,m,r_max), v (I,n,r_max))`` — batched randomized
  SVD, matmul-only (``kernels/lowrank``); factors pre-scaled by √s
  and masked to each item's rank, padded to the static group ``r_max``
  (mixed-rank grouping). ``keys`` are the engine-appended per-item
  sketch keys (``CompressionScheme.wants_key``).
* ``rank_select(w (I,m,n) f32, alpha (I,) f32, keys (I,2) u32, mu, *,
  r_max, cost) -> (u, v, rank (I,) i32)`` — batched automatic rank
  selection over the same spectrum (mixed-α grouping).

The matmul-only solvers (``lowrank_rsvd``, ``rank_select``,
``project_l1_ball``, ``soft_threshold``) register a ``jnp``
implementation only — they contain no Pallas kernel and no LAPACK
custom call; ``interpret``/``pallas`` requests fall back to the same
batched jnp program via the registry's backend-gap rule.
"""
from __future__ import annotations

from functools import partial
from typing import Callable

import jax

BACKENDS = ("jnp", "interpret", "pallas")
#: user-facing request values (TrainerConfig.cstep_backend etc.)
REQUESTS = ("auto", "jnp", "interpret", "pallas", "off")

_REGISTRY: dict[str, dict[str, Callable]] = {}

#: solvers whose kernel path takes a planner-tunable items-grid tile
#: (``block_rows=`` kwarg on the registered implementation). The group
#: planner (``analysis/cost``) only offers tile choices for these.
TILED_SOLVERS: dict[str, str] = {
    "kmeans_lloyd": "block_rows",
    "topk_mask": "block_rows",
}


def register(solver: str, backend: str, fn: Callable) -> None:
    """Register ``fn`` as the ``backend`` implementation of ``solver``."""
    assert backend in BACKENDS, backend
    _REGISTRY.setdefault(solver, {})[backend] = fn


def registered_backends(solver: str | None) -> tuple[str, ...]:
    """Backends actually carrying ``solver`` (planner input)."""
    if solver is None or solver not in _REGISTRY:
        return ()
    return tuple(sorted(_REGISTRY[solver]))


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(requested: str | None = "auto") -> str | None:
    """Requested backend → the backend that will actually run.

    ``None``/``"off"`` disables kernel dispatch entirely (pure vmapped
    scheme programs, κ static). ``"auto"`` is ``pallas`` on TPU and
    ``jnp`` elsewhere. ``"pallas"`` without a TPU degrades to
    ``interpret`` — the kernel path, emulated — so tests and CI
    exercise the same program the TPU compiles.
    """
    if requested is None or requested == "off":
        return None
    if requested not in REQUESTS:
        raise ValueError(
            f"cstep backend must be one of {REQUESTS}, got {requested!r}")
    if requested == "auto":
        return "pallas" if _on_tpu() else "jnp"
    if requested == "pallas" and not _on_tpu():
        return "interpret"
    return requested


def lookup(solver: str | None,
           requested: str | None = "auto",
           tile: int | None = None) -> tuple[Callable | None,
                                             str | None]:
    """(implementation, actual backend) for a solver name, or
    ``(None, None)`` when dispatch is off / the name is unregistered —
    the caller then uses its vmap fallback. A backend gap (name known,
    backend missing) falls back to the registered ``jnp`` solver so the
    result is still batched.

    ``tile`` (planner-chosen ``block_rows``) is bound onto the
    implementation when the solver is tile-parameterized
    (:data:`TILED_SOLVERS`) and the resolved backend runs the kernel
    path; the jnp implementations ignore tiles by construction.
    """
    backend = resolve_backend(requested)
    if backend is None or solver is None or solver not in _REGISTRY:
        return None, None
    impls = _REGISTRY[solver]
    if backend not in impls:
        if "jnp" in impls:
            return impls["jnp"], "jnp"
        return None, None
    fn = impls[backend]
    if tile is not None and backend in ("pallas", "interpret") and \
            solver in TILED_SOLVERS:
        fn = partial(fn, **{TILED_SOLVERS[solver]: int(tile)})
    return fn, backend


def solver_table() -> dict[str, tuple[str, ...]]:
    """{solver name: registered backends} — for docs and diagnostics."""
    return {name: tuple(sorted(impls)) for name, impls in
            sorted(_REGISTRY.items())}


def registry_entries() -> dict[str, dict[str, Callable]]:
    """Shallow copy of the raw registry: {solver: {backend: impl}}.

    Metadata accessor for tooling (``repro.analysis.lint`` contract
    layer) — callers must treat the inner callables as opaque; use
    :func:`lookup` for dispatch so the honest-fallback rules apply.
    """
    return {name: dict(impls) for name, impls in _REGISTRY.items()}


def solver_signature(solver: str,
                     backend: str = "jnp") -> tuple[str, ...] | None:
    """Positional parameter names of a registered solver implementation
    (keyword-only config like ``iters``/``r_max`` excluded), unwrapping
    ``functools.partial``. ``None`` when the (solver, backend) entry is
    missing or the underlying callable is not introspectable.

    This is the machine-readable half of the calling conventions in the
    module docstring — the lint contract layer checks each scheme's
    declared ``solver_operands`` against it.
    """
    import inspect

    impls = _REGISTRY.get(solver, {})
    fn = impls.get(backend)
    if fn is None:
        return None
    while isinstance(fn, partial):
        fn = fn.func
    try:
        sig = inspect.signature(fn)
    except (TypeError, ValueError):
        return None
    return tuple(
        p.name for p in sig.parameters.values()
        if p.kind in (p.POSITIONAL_ONLY, p.POSITIONAL_OR_KEYWORD))


# ----------------------------------------------------------------------
# built-in solvers (import at the bottom: ops modules must exist before
# registration, and this module must define lookup() before core code
# importing it mid-cycle resolves anything)
# ----------------------------------------------------------------------
from repro.kernels.kmeans import ops as _kops    # noqa: E402
from repro.kernels.prune import ops as _pops     # noqa: E402

register("kmeans_lloyd", "jnp", partial(_kops.kmeans_batched, impl="jnp"))
register("kmeans_lloyd", "interpret",
         partial(_kops.kmeans_batched, impl="interpret"))
register("kmeans_lloyd", "pallas",
         partial(_kops.kmeans_batched, impl="pallas"))

register("topk_mask", "jnp", partial(_pops.topk_mask_batched, impl="jnp"))
register("topk_mask", "interpret",
         partial(_pops.topk_mask_batched, impl="interpret"))
register("topk_mask", "pallas",
         partial(_pops.topk_mask_batched, impl="pallas"))

# matmul-only solvers: jnp registration only (no kernel to emulate; the
# backend-gap rule serves interpret/pallas requests the same program)
from repro.kernels.lowrank import ops as _lops  # noqa: E402

register("lowrank_rsvd", "jnp", _lops.lowrank_rsvd_batched)
register("rank_select", "jnp", _lops.rank_select_batched)
register("project_l1_ball", "jnp", _pops.project_l1_ball_batched)
register("soft_threshold", "jnp", _pops.soft_threshold_batched)
