"""Pallas TPU kernel: fused flash attention (fwd), GQA-native.

The training/prefill memory bottleneck of every assigned arch is the
(qc × kc) attention score tile materializing in HBM (EXPERIMENTS.md
§Roofline). This kernel keeps the whole online-softmax loop in VMEM:

* grid (B, KV, G, nq, nk), nk innermost (sequential on TPU);
* the K/V BlockSpec index_map **ignores the g axis** — grouped query
  heads reuse the same VMEM-resident K/V tile with zero extra HBM
  traffic (the GQA-native alternative to materializing repeated KV);
* running (m, l) live in VMEM scratch; the output block is revisited
  across nk steps and rescaled in place; division by l happens on the
  last step;
* causal/sliding-window masking from absolute positions via iota —
  no mask tensor is ever formed.

HBM traffic = q + k + v + o exactly (the boundary I/O the dry-run's
fused-scope accounting charges).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
import jax.experimental.pallas.tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, *,
            scale: float, window: int, qc: int, kc: int, nk: int):
    iq = pl.program_id(3)
    ik = pl.program_id(4)

    @pl.when(ik == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        o_ref[...] = jnp.zeros_like(o_ref)

    q = q_ref[0, 0, 0].astype(jnp.float32)            # (qc, D)
    k = k_ref[0, 0].astype(jnp.float32)               # (kc, D)
    v = v_ref[0, 0].astype(jnp.float32)               # (kc, D)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32) * scale   # (qc, kc)
    qpos = iq * qc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 0)
    kpos = ik * kc + jax.lax.broadcasted_iota(jnp.int32, (qc, kc), 1)
    mask = kpos <= qpos
    if window > 0:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    l_prev = l_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1))
    p = jnp.exp(s - m_new[:, None])
    alpha = jnp.exp(m_prev - m_new)
    l_new = l_prev * alpha + jnp.sum(p, axis=-1)
    m_ref[...] = m_new
    l_ref[...] = l_new

    acc = o_ref[0, 0, 0] * alpha[:, None]
    acc = acc + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)
    o_ref[0, 0, 0] = acc

    @pl.when(ik == nk - 1)
    def _finish():
        o_ref[0, 0, 0] = o_ref[0, 0, 0] / jnp.maximum(
            l_ref[...], 1e-30)[:, None]


@partial(jax.jit,
         static_argnames=("window", "q_chunk", "kv_chunk", "interpret"))
def flash_attention(q, k, v, *, window: int = 0, q_chunk: int = 128,
                    kv_chunk: int = 128, interpret: bool = True):
    """q: (B, KV, G, S, D); k, v: (B, KV, S, D) → (B, KV, G, S, D) f32."""
    b, kvh, g, s, d = q.shape
    qc = min(q_chunk, s)
    kc = min(kv_chunk, s)
    assert s % qc == 0 and s % kc == 0
    nq, nk = s // qc, s // kc
    scale = 1.0 / np.sqrt(d)

    return pl.pallas_call(
        partial(_kernel, scale=scale, window=window, qc=qc, kc=kc, nk=nk),
        grid=(b, kvh, g, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, 1, qc, d),
                         lambda b_, k_, g_, iq, ik: (b_, k_, g_, iq, 0)),
            # K/V index_map ignores g: grouped heads share the VMEM tile
            pl.BlockSpec((1, 1, kc, d),
                         lambda b_, k_, g_, iq, ik: (b_, k_, ik, 0)),
            pl.BlockSpec((1, 1, kc, d),
                         lambda b_, k_, g_, iq, ik: (b_, k_, ik, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, 1, qc, d),
            lambda b_, k_, g_, iq, ik: (b_, k_, g_, iq, 0)),
        out_shape=jax.ShapeDtypeStruct((b, kvh, g, s, d), jnp.float32),
        scratch_shapes=[
            pltpu.VMEM((qc,), jnp.float32),   # running max m
            pltpu.VMEM((qc,), jnp.float32),   # running sum l
        ],
        interpret=interpret,
    )(q, k, v)
