"""Wrapper: model-layout (B, S, H, D) GQA attention on the flash kernel."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention import ref
from repro.kernels.flash_attention.flash_attention import flash_attention


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def attention(q, k, v, *, window: int = 0, q_chunk: int = 128,
              kv_chunk: int = 128, use_pallas: bool | str = "auto"):
    """q: (B, S, H, D); k, v: (B, S, KV, D) → (B, S, H, D)."""
    b, s, h, d = q.shape
    kvh = k.shape[2]
    g = h // kvh
    qg = jnp.moveaxis(q.reshape(b, s, kvh, g, d), 1, 3)   # (B,KV,G,S,D)
    kg = jnp.moveaxis(k, 1, 2)                            # (B,KV,S,D)
    vg = jnp.moveaxis(v, 1, 2)
    if use_pallas == "auto":
        use_pallas = _on_tpu()
    if use_pallas:
        out = flash_attention(qg, kg, vg, window=window,
                              q_chunk=q_chunk, kv_chunk=kv_chunk,
                              interpret=not _on_tpu())
    else:
        out = ref.flash_attention_ref(qg, kg, vg, window=window)
    return jnp.moveaxis(out, 3, 1).reshape(b, s, h, d).astype(q.dtype)
