from repro.kernels.flash_attention.ops import attention

__all__ = ["attention"]
