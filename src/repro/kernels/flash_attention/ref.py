"""Pure-jnp oracle for the flash-attention kernel (causal + window)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, window: int = 0):
    """q: (B, KV, G, Sq, D); k, v: (B, KV, Sk, D) → (B, KV, G, Sq, D).

    Causal over absolute positions (Sq == Sk)."""
    sq, sk = q.shape[3], k.shape[2]
    d = q.shape[-1]
    s = jnp.einsum("bkgqd,bkcd->bkgqc", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    qpos = jnp.arange(sq)
    kpos = jnp.arange(sk)
    mask = kpos[None, :] <= qpos[:, None]
    if window > 0:
        mask &= kpos[None, :] > qpos[:, None] - window
    s = jnp.where(mask[None, None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bkgqc,bkcd->bkgqd", p,
                      v.astype(jnp.float32))
