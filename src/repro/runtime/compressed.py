"""Compressed weight forms for serving: param-tree leaves that execute
without ever materializing the dense matrix.

LC training ends with Θ per scheme family — codebook+assignments
(quantize), thin factors (lowrank), a sparse survivor set (prune). For
deployment each 2-D weight leaf is *replaced* in the param tree by one
of the pytree classes below; the model code dispatches matmuls through
``layers.apply_w``, which routes each form to its streaming kernel:

==============  =======================  ==========================
form            HBM read per decode      kernel
==============  =======================  ==========================
dense (bf16)    K·N·2 B                  plain MXU matmul
QuantizedWeight K·N/2 B (4-bit) + cb     kernels/quant_matmul (fused
                or K·N B (8-bit)         nibble-unpack + dequant)
LowRankWeight   r·(K+N)·2 B              kernels/lowrank/serve (two
                                         thin matmuls, W never built)
SparseWeight    nnz·(2+4+4) B            kernels/prune/serve (COO
                                         gather/scatter)
==============  =======================  ==========================

Decode is HBM-bound, so these byte counts are the roofline; the modeled
ceilings surface in ``BENCH_serve.json`` via :func:`weight_form_bytes`.

The classes register with ``layers.register_weight_form`` on import
(registry lives in layers to avoid a models→runtime import cycle), are
registered jax pytrees (arrays as children, shape/bits as static aux),
and keep ``__init__`` free of array ops so tracers flow through
flatten/unflatten untouched.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.analysis import cost
from repro.kernels.lowrank import serve as lowrank_serve
from repro.kernels.prune import serve as prune_serve
from repro.kernels.quant_matmul import ops as quant_ops
from repro.kernels.quant_matmul import ref as quant_ref
from repro.models import layers


@jax.tree_util.register_pytree_node_class
class QuantizedWeight:
    """Codebook-quantized weight. ``bits=4``: ``packed`` is
    (ceil(K/2), N) uint8, two indices per byte; ``bits=8``: (K, N)
    uint8 plain indices. ``shape`` = (K, N) of the dense weight."""

    def __init__(self, packed, codebook, shape, bits):
        self.packed = packed
        self.codebook = codebook
        self.shape = tuple(shape)
        self.bits = int(bits)

    def tree_flatten(self):
        return (self.packed, self.codebook), (self.shape, self.bits)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def __repr__(self):
        return (f"QuantizedWeight(shape={self.shape}, bits={self.bits}, "
                f"codes={self.codebook.shape[0]})")


@jax.tree_util.register_pytree_node_class
class LowRankWeight:
    """Factored weight W = u @ vt. u: (K, r); vt: (r, N)."""

    def __init__(self, u, vt):
        self.u = u
        self.vt = vt

    @property
    def shape(self):
        return (self.u.shape[0], self.vt.shape[1])

    def tree_flatten(self):
        return (self.u, self.vt), ()

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children)

    def __repr__(self):
        return f"LowRankWeight(shape={self.shape}, rank={self.u.shape[1]})"


@jax.tree_util.register_pytree_node_class
class SparseWeight:
    """Pruned weight in COO form: W[rows[i], cols[i]] = values[i],
    zeros elsewhere. ``shape`` = (K, N), static (the scatter needs N at
    trace time)."""

    def __init__(self, values, rows, cols, shape):
        self.values = values
        self.rows = rows
        self.cols = cols
        self.shape = tuple(shape)

    def tree_flatten(self):
        return (self.values, self.rows, self.cols), (self.shape,)

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(*children, *aux)

    def __repr__(self):
        return (f"SparseWeight(shape={self.shape}, "
                f"nnz={self.values.shape[0]})")


WEIGHT_FORMS = (QuantizedWeight, LowRankWeight, SparseWeight)


# ----------------------------------------------------------------------
# Execution (apply = x @ W without materializing W; load = dense W)
# ----------------------------------------------------------------------
def _quant_apply(x, w: QuantizedWeight, dt):
    k, n = w.shape
    lead = x.shape[:-1]
    x2 = x.reshape(-1, k).astype(jnp.float32)
    # roofline-sized tile hints (repro.analysis.cost): static shapes in,
    # static block sizes out — pure trace-time arithmetic, and the jnp
    # reference path (CPU) ignores them entirely
    tiles = cost.gemm_tiles(int(x2.shape[0]), n, k, packed=w.bits == 4)
    if w.bits == 4:
        if k % 2:  # odd K: packed has a pad row of index 0; feed zero x
            x2 = jnp.pad(x2, ((0, 0), (0, 1)))
        y = quant_ops.matmul_packed(x2, w.packed, w.codebook,
                                    bm=tiles["block_m"],
                                    bn=tiles["block_n"],
                                    bk2=max(tiles["block_k"] // 2, 128))
    else:
        y = quant_ops.matmul(x2, w.packed, w.codebook,
                             bm=tiles["block_m"], bn=tiles["block_n"],
                             bk=tiles["block_k"])
    return y.reshape(*lead, n).astype(dt)


def _quant_load(w: QuantizedWeight, dt):
    k, _ = w.shape
    idx = quant_ref.unpack4_ref(w.packed)[:k] if w.bits == 4 else w.packed
    return w.codebook[idx.astype(jnp.int32)].astype(dt)


def _lowrank_apply(x, w: LowRankWeight, dt):
    return lowrank_serve.lowrank_matmul(x, w.u, w.vt).astype(dt)


def _lowrank_load(w: LowRankWeight, dt):
    return lowrank_serve.materialize_lowrank(w.u, w.vt).astype(dt)


def _sparse_apply(x, w: SparseWeight, dt):
    return prune_serve.sparse_matmul(
        x, w.values, w.rows, w.cols, w.shape[1]).astype(dt)


def _sparse_load(w: SparseWeight, dt):
    return prune_serve.densify(
        w.values, w.rows, w.cols, w.shape).astype(dt)


layers.register_weight_form(QuantizedWeight, _quant_apply, _quant_load)
layers.register_weight_form(LowRankWeight, _lowrank_apply, _lowrank_load)
layers.register_weight_form(SparseWeight, _sparse_apply, _sparse_load)


def materialize(leaf, dt=jnp.float32):
    """Dense array for any weight-form leaf (parity checks, embed
    lookups). Dense leaves pass through as ``leaf.astype(dt)``."""
    return layers.wload(leaf, dt)


# ----------------------------------------------------------------------
# HBM accounting (modeled bf16 deployment)
# ----------------------------------------------------------------------
def is_weight_form(leaf) -> bool:
    return isinstance(leaf, WEIGHT_FORMS) or (
        isinstance(leaf, dict) and "idx" in leaf)


def weight_form_bytes(leaf) -> int:
    """Modeled HBM bytes to stream this leaf once at decode. Dense
    leaves count at 2 B/elem (bf16 deployment) regardless of the host
    dtype the bench runs in; codebooks/coordinates at their true
    width."""
    if isinstance(leaf, QuantizedWeight):
        return int(leaf.packed.size) + 4 * int(leaf.codebook.size)
    if isinstance(leaf, LowRankWeight):
        return 2 * (int(leaf.u.size) + int(leaf.vt.size))
    if isinstance(leaf, SparseWeight):
        return (2 * int(leaf.values.size)
                + 4 * (int(leaf.rows.size) + int(leaf.cols.size)))
    if isinstance(leaf, dict) and "idx" in leaf:  # legacy uint8 pack
        return int(leaf["idx"].size) + 4 * int(leaf["cb"].size)
    return 2 * int(leaf.size)


def tree_weight_bytes(params) -> int:
    """Total modeled weight-stream bytes for one decode step over the
    whole param tree."""
    total = 0

    def visit(leaf):
        nonlocal total
        total += weight_form_bytes(leaf)

    jax.tree_util.tree_map(visit, params, is_leaf=is_weight_form)
    return total


def decode_hbm_bytes_per_token(params, batch: int = 1) -> float:
    """Roofline model for batched decode: weights stream once per step
    and are amortized over the ``batch`` tokens produced. Ceiling
    tokens/sec = HBM_BW / this."""
    return tree_weight_bytes(params) / max(batch, 1)
