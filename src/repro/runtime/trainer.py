"""LCTrainer: the production training loop.

Composes the paper's LC algorithm with the distributed substrate:

    for each LC step k (μ = μ0·aᵏ):
        L step  — ``steps_per_l`` compiled train steps (loss + penalty)
        C step  — jitted sharded projections Θ ← Π(w − λ/μ)
        λ step  — multiplier update
        monitors — L-step loss decrease, C-step distortion decrease (§7)

    throughout: checkpoint every N steps (async), retry transient
    failures, restore-from-checkpoint on hard failure, straggler
    tracking, deterministic seekable data (exact resume).

Two execution modes (``TrainerConfig.overlap``):

* ``"off"`` — the strictly serial loop above: every C step drains the
  accelerator (block_until_ready) before the next L step starts. Simple,
  and the bit-exact reference the overlapped mode is tested against.
* ``"on"`` — the double-buffered pipeline (ROADMAP "Async L/C overlap").
  The C step at an LC boundary depends only on (w, λ, μ), so it is
  dispatched *without blocking* and the next L step begins immediately
  against the previous Δ(Θ)/λ penalty refs; the fresh refs are swapped
  in mid-L-step once the C-step future resolves (or after a fixed
  ``swap_after`` microbatches). The accelerator-idle bubble per μ
  disappears; the cost is a documented stale-refs window — see
  docs/architecture.md ("Async L/C overlap") for the exact semantics
  and the donation rules that make the overlap safe.
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, replace
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.algorithm import LCAlgorithm
from repro.core.state import probe_is_ready, ready_probe
from repro.core.tasks import get_path
from repro.data.pipeline import Prefetcher
from repro.distributed.sharding import use_mesh
from repro.launch.steps import make_train_step, stable_lc_refs
from repro.optim import AdamW
from repro.runtime.fault_tolerance import (
    FaultInjector, RetryPolicy, StragglerMonitor)

log = logging.getLogger("repro.trainer")


@dataclass
class TrainerConfig:
    steps_per_l: int = 20
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    keep_last: int = 3
    lr: float = 3e-4
    clip_norm: float = 1.0
    straggler_factor: float = 3.0
    # paper §7 monitor: the C step must not increase its own objective
    # ‖(w − λ/μ) − Δ(Θ)‖² at fixed (w, λ, μ); violations mean a broken
    # scheme warm start and are logged as errors.
    monitor_distortion: bool = True
    # give up (re-raise) after this many consecutive hard-failure
    # restores with no completed step in between — a deterministic
    # failure would otherwise rewind-and-replay forever.
    max_restores: int = 3
    # async L/C overlap: "off" = serial reference loop (bit-exact with
    # the pre-overlap trainer), "on" = double-buffered pipeline.
    overlap: str = "off"
    # with overlap on: force the ref swap after this many microbatches
    # of the next L step; None = swap as soon as the C-step future
    # resolves (polled non-blockingly between microbatches).
    swap_after: int | None = None
    # kernel dispatch backend for the C step's named scheme solvers
    # ("auto" | "jnp" | "interpret" | "pallas" | "off") — threaded to
    # LCAlgorithm.set_backend when set; None (default) inherits
    # whatever backend the algorithm was constructed with, so an
    # explicit LCAlgorithm(cstep_backend=...) is never clobbered.
    cstep_backend: str | None = None
    # overlap the next L step's first batch construction with the LC
    # boundary dispatch (Prefetcher in data/pipeline.py); the data
    # contract (batch_at pure in step) makes this bit-neutral.
    prefetch_data: bool = True


class LCTrainer:
    def __init__(self, cfg, lc: LCAlgorithm, data, mesh=None,
                 tcfg: TrainerConfig | None = None,
                 optimizer: AdamW | None = None,
                 fault_injector: FaultInjector | None = None,
                 overlap: str | None = None):
        self.cfg = cfg
        self.lc = lc
        self.data = data
        self.mesh = mesh
        if mesh is not None and lc.mesh is None:
            # the trainer owns the mesh: hand it to the algorithm so the
            # grouped C step shards its packed item axes over "data"
            lc.set_mesh(mesh)
        self.tcfg = tcfg or TrainerConfig()
        if overlap is not None:
            self.tcfg = replace(self.tcfg, overlap=overlap)
        if self.tcfg.overlap not in ("off", "on"):
            raise ValueError(
                f"overlap must be 'off' or 'on', got {self.tcfg.overlap!r}")
        if self.tcfg.cstep_backend is not None \
                and self.tcfg.cstep_backend != lc.cstep_backend:
            # an explicit trainer request wins: rebuilds the jitted
            # steps so the solver backend is baked into the C-step HLO
            lc.set_backend(self.tcfg.cstep_backend)
        self._prefetcher = (Prefetcher(data)
                            if self.tcfg.prefetch_data else None)
        self.optimizer = optimizer or AdamW()
        self.retry = RetryPolicy()
        self.straggler = StragglerMonitor(
            factor=self.tcfg.straggler_factor)
        self.faults = fault_injector or FaultInjector()
        self.ckpt = (CheckpointManager(self.tcfg.ckpt_dir,
                                       self.tcfg.keep_last)
                     if self.tcfg.ckpt_dir else None)
        self._train_step = jax.jit(make_train_step(
            cfg, self.optimizer, lr=self.tcfg.lr,
            clip_norm=self.tcfg.clip_norm, with_lc=True))
        self.history: list[dict] = []
        # in-flight LC boundary of the overlapped pipeline (None when
        # nothing is in flight / overlap is off)
        self._pending: dict | None = None

    # ------------------------------------------------------------------
    def init_state(self, key):
        from repro.launch.steps import init_train_state
        with use_mesh(self.mesh):
            state = init_train_state(key, self.cfg, self.optimizer,
                                     with_lc=True)
        # attach real LC state (Θ, λ) from the algorithm
        lc_state = self.lc.init(state["params"])
        state["lc"] = self._refs_from_lc(state["params"], lc_state)
        self._lc_state = lc_state
        return state

    def _refs_from_lc(self, params, lc_state):
        """Flatten LC (a, λ) into the train-state penalty refs."""
        a, lam = {}, {}
        for t in self.lc.tasks:
            ts = lc_state["tasks"][t.name]
            for p in t.paths:
                a[p] = ts["a"][p]
                lam[p] = ts["lam"][p]
        return {"a": a, "lam": lam, "mu": lc_state["mu"]}

    # ------------------------------------------------------------------
    def _one_step(self, state, step: int):
        self.faults.maybe_fail(step)
        if self._prefetcher is not None:
            batch = self._prefetcher.batch_at(step)
        else:
            batch = self.data.batch_at(step) \
                if hasattr(self.data, "batch_at") else self.data(step)
        return self._train_step(state, batch)

    def _restore_state(self, state):
        """Hard-failure restore with consistent LC bookkeeping.

        Three things a naive ``ckpt.restore(state)`` leaves wrong, fixed
        here:

        * restored leaves are host numpy — ``jax.device_put`` them back
          onto the shardings of the leaves they replace, so the compiled
          train step keeps its layouts instead of consuming unsharded
          host arrays;
        * the step counter must REWIND to the checkpoint step: the data
          is deterministic and seekable, so training replays from the
          restored weights rather than marching the old counters over
          rewound state;
        * the checkpointed ``state["lc"]`` refs are whatever (μ, λ, Θ)
          was live at save time — re-sync them from the algorithm's
          current LC state at the *current* μ.

        Returns ``(state, next_step)`` where ``next_step`` is the first
        step index to (re)run.
        """
        # elastic-reload path: restore() device_puts every leaf onto the
        # live state's shardings, so no host numpy reaches the train step
        shardings = jax.tree_util.tree_map(
            lambda x: x.sharding, state)
        restored, _ = self.ckpt.restore(state, shardings=shardings)
        # the saved state["step"] is the authoritative resume point (the
        # manifest label is off by one between mid-L-step saves, written
        # after the counter advanced, and final blocking saves)
        next_step = int(np.asarray(restored["step"]))
        refs = self._refs_from_lc(restored["params"], self._lc_state)
        restored["lc"] = dict(refs, mu=state["lc"]["mu"])
        return restored, next_step

    def _l_step(self, state, lc_k: int, global_step: int,
                on_microbatch: Callable | None = None):
        """One full L step = steps_per_l optimizer steps.

        Returns ``(state, last_metrics, next_global_step)``. On a hard
        failure (retries exhausted) the latest checkpoint is restored
        and the step counter rewinds to it (see ``_restore_state``), so
        ``next_global_step`` always equals the step count actually
        reflected in ``state``. ``on_microbatch(state, done) -> state``
        runs after every completed microbatch — the overlapped
        pipeline's swap hook; ``done`` counts microbatches completed in
        this L step.
        """
        metrics = {}
        step = global_step
        end_step = global_step + self.tcfg.steps_per_l
        done = 0
        restores = 0  # consecutive, reset by any completed step
        while step < end_step:
            t0 = time.time()
            try:
                state, metrics = self.retry.run(
                    self._one_step, state, step,
                    on_retry=lambda a, e: log.warning(
                        "step %d retry %d: %s", step, a, e))
            except RuntimeError:
                if self.ckpt:
                    # let an in-flight background save commit (and its
                    # errors surface) before deciding whether/where to
                    # restore — latest_step() only sees _COMPLETE dirs
                    self.ckpt.wait()
                if self.ckpt and self.ckpt.latest_step() is not None \
                        and restores < self.tcfg.max_restores:
                    restores += 1
                    log.error("step %d hard failure — restoring (%d/%d)",
                              step, restores, self.tcfg.max_restores)
                    state, step = self._restore_state(state)
                    continue
                raise
            restores = 0
            dt = time.time() - t0
            if self.straggler.observe(dt):
                log.warning("straggler: step %d took %.3fs", step, dt)
            if self.ckpt and step > 0 \
                    and step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(state, step)
            step += 1
            done += 1
            if on_microbatch is not None:
                state = on_microbatch(state, done)
        return state, metrics, step

    # ------------------------------------------------------------------
    def run(self, key, n_lc_steps: int | None = None):
        state = self.init_state(key)
        schedule = self.lc.mu_schedule[:n_lc_steps] \
            if n_lc_steps else self.lc.mu_schedule
        global_step = int(state["step"])

        for g in self.lc.group_summary(state["params"]):
            log.info("c-step group: %s over %s (%d items, tasks=%s, "
                     "spec=%s, padding=%d)",
                     g["scheme"], g["item_shape"], g["items"], g["tasks"],
                     g["spec"], g["padding"])

        if self.tcfg.overlap == "on":
            return self._run_overlapped(state, schedule, global_step)
        return self._run_serial(state, schedule, global_step)

    # ------------------------------------------------------------------
    def _run_serial(self, state, schedule, global_step: int):
        """The reference loop: C step and monitors drain the device at
        every LC boundary. Step-for-step identical to the pre-overlap
        trainer (enforced by tests/test_trainer_overlap.py)."""
        lc_state = self._lc_state
        for k, mu in enumerate(schedule):
            lc_state = self.lc.set_mu(lc_state, mu, k)
            self._lc_state = lc_state
            state["lc"] = self._refs_from_lc(state["params"], lc_state)
            pen0 = float(self.lc.penalty(state["params"], lc_state))

            state, metrics, global_step = self._l_step(
                state, k, global_step)

            params = state["params"]
            if self.tcfg.monitor_distortion:
                d_pre = self.lc.shifted_distortion(params, lc_state)
                jax.block_until_ready(d_pre)
            # drain in-flight L-step work so c_step_ms times the C step
            # alone, not the async dispatch chain behind it
            jax.block_until_ready(params)
            t0 = time.time()
            lc_state = self.lc.c_step(params, lc_state)
            jax.block_until_ready(lc_state)
            c_step_ms = (time.time() - t0) * 1e3
            c_violations = []
            if self.tcfg.monitor_distortion:
                d_post = self.lc.shifted_distortion(params, lc_state)
                c_violations = self._check_violations(d_pre, d_post)
            lc_state = self.lc.multiplier_step(params, lc_state)
            self._lc_state = lc_state
            state["lc"] = self._refs_from_lc(params, lc_state)

            dist = {n: float(v) for n, v in
                    self.lc.distortion(params, lc_state).items()}
            rec = {
                "lc_step": k, "mu": float(mu),
                "loss": float(metrics.get("loss", np.nan)),
                "ce": float(metrics.get("ce", np.nan)),
                "penalty_start": pen0,
                "distortion": dist,
                "c_step_ms": c_step_ms,
                "c_step_violations": c_violations,
                "compression_ratio": float(
                    self.lc.compression_ratio(params, lc_state)),
                "stragglers": self.straggler.stragglers,
            }
            self.history.append(rec)
            log.info("LC step %d: %s", k, rec)

        self._lc_state = lc_state
        if self.ckpt:
            self.ckpt.save(state, global_step, blocking=True)
        return state, lc_state

    # ------------------------------------------------------------------
    def _run_overlapped(self, state, schedule, global_step: int):
        """Double-buffered pipeline: dispatch the C step at each LC
        boundary without blocking, run the next L step against the
        previous Δ(Θ)/λ refs, swap the fresh refs in mid-L-step.

        ::

            L step k  ──────────────┤ boundary k ├────────────────────
            C step                  └─ dispatch ──► C(w_k, λ_k, μ_k) ─┐
            L step k+1  [stale refs ....................][fresh refs] │
                                                  swap ◄──────────────┘

        Only the boundary snapshot (w, λ, μ) feeds the C step, so its
        result is independent of the L-step microbatches it overlaps
        with; the first microbatches of L step k+1 simply optimize
        against the previous Δ(Θ)/λ (at the *new* μ — μ is a host
        scalar and advances immediately). Monitors (§7 distortion,
        penalty, compression ratio) are dispatched at the boundary and
        materialized only when the step's record is emitted, so they
        ride the pipeline instead of draining it; ``c_step_ms`` is the
        dispatch→ready wall time of the C+λ chain, measured by polling
        (granularity: one microbatch).
        """
        lc_state = self._lc_state
        self._pending = None  # a prior aborted run must not leak in
        swap_after = self.tcfg.swap_after

        def on_microbatch(st, done):
            if self._pending is None:
                return st
            deadline = swap_after is not None and done >= swap_after
            if deadline or (swap_after is None
                            and probe_is_ready(self._pending["probe"])):
                st = self._apply_pending(st, block=deadline, done=done)
            return st

        for k, mu in enumerate(schedule):
            lc_state = self.lc.set_mu(lc_state, mu, k)
            self._lc_state = lc_state
            if self._pending is None:
                # cold boundary (first LC step): fresh refs, as serial
                state["lc"] = self._refs_from_lc(state["params"], lc_state)
            else:
                # stale-refs window: keep the previous Δ(Θ)/λ in the
                # penalty while the C step runs; only μ advances now
                state["lc"] = dict(state["lc"], mu=jnp.float32(mu))
            pen0 = self.lc.penalty(state["params"], lc_state)  # async

            state, metrics, global_step = self._l_step(
                state, k, global_step, on_microbatch=on_microbatch)

            # boundary k consumes post-multiplier λ from boundary k-1:
            # if the swap hasn't happened yet (slow C step or large
            # swap_after), force it now
            if self._pending is not None:
                state = self._apply_pending(
                    state, block=True, done=self.tcfg.steps_per_l)

            # ---- LC boundary k: dispatch everything, block on nothing
            params = state["params"]
            d_pre = (self.lc.shifted_distortion(params, lc_state)
                     if self.tcfg.monitor_distortion else None)
            t_dispatch = time.time()
            lc_after_c = self.lc.c_step_async(params, lc_state)
            d_post = (self.lc.shifted_distortion(params, lc_after_c)
                      if self.tcfg.monitor_distortion else None)
            lc_state = self.lc.multiplier_step_async(params, lc_after_c)
            # compression_ratio only reads parameter *shapes* from w —
            # keep shape structs, not the arrays, so the boundary
            # snapshot doesn't pin a second full parameter generation
            # on device for the length of the stale window
            param_shapes = jax.tree_util.tree_map(
                lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), params)
            self._pending = {
                "k": k, "mu": float(mu), "metrics": metrics,
                "pen0": pen0, "params": param_shapes, "lc_state": lc_state,
                "d_pre": d_pre, "d_post": d_post,
                "dist": self.lc.distortion(params, lc_state),
                "t_dispatch": t_dispatch, "t_ready": None,
                "probe": ready_probe(lc_state),
            }
            # the C step also overlaps *data loading*: start building
            # the next L step's first microbatch while the boundary
            # chain is in flight (global_step is exactly the step index
            # the next _l_step consumes first). The final boundary has
            # no next L step — don't strand a batch nobody consumes.
            if self._prefetcher is not None and k + 1 < len(schedule):
                self._prefetcher.prefetch(global_step)

        # drain the final boundary (no L step left to overlap with);
        # an empty μ schedule never dispatched one
        if self._pending is not None:
            state = self._apply_pending(state, block=True, done=None)
        self._lc_state = lc_state
        if self.ckpt:
            self.ckpt.save(state, global_step, blocking=True)
        return state, lc_state

    def _apply_pending(self, state, block: bool, done: int | None):
        """Swap the in-flight boundary's fresh Δ(Θ)/λ into the penalty
        refs (layout-stable, see ``stable_lc_refs``) and emit the
        finished LC step's record. ``done`` is the microbatch count the
        stale window lasted (None = drained after the final L step)."""
        p = self._pending
        if block:
            jax.block_until_ready(p["probe"])
        if p["t_ready"] is None:
            p["t_ready"] = time.time()
        refs = self._refs_from_lc(state["params"], p["lc_state"])
        state["lc"] = stable_lc_refs(refs, state["lc"])
        self._pending = None

        c_violations = []
        if p["d_pre"] is not None:
            c_violations = self._check_violations(p["d_pre"], p["d_post"])
        dist = {n: float(v) for n, v in p["dist"].items()}
        rec = {
            "lc_step": p["k"], "mu": p["mu"],
            "loss": float(p["metrics"].get("loss", np.nan)),
            "ce": float(p["metrics"].get("ce", np.nan)),
            "penalty_start": float(p["pen0"]),
            "distortion": dist,
            "c_step_ms": (p["t_ready"] - p["t_dispatch"]) * 1e3,
            "c_step_violations": c_violations,
            "compression_ratio": float(
                self.lc.compression_ratio(p["params"], p["lc_state"])),
            "stragglers": self.straggler.stragglers,
            "swap_after_microbatches": done,
        }
        self.history.append(rec)
        log.info("LC step %d: %s", p["k"], rec)
        return state

    def _check_violations(self, d_pre, d_post) -> list[str]:
        out = []
        for n in d_pre:
            pre, post = float(d_pre[n]), float(d_post[n])
            if post > pre * (1 + 1e-5) + 1e-8:
                out.append(n)
                log.error(
                    "C step increased ‖(w−λ/μ)−Δ(Θ)‖² for task "
                    "%s: %.6g → %.6g (broken warm start?)",
                    n, pre, post)
        return out

    # ------------------------------------------------------------------
    def compressed_params(self, state, lc_state):
        """Final model: w ← Δ(Θ)."""
        from repro.core.tasks import set_path
        params = state["params"]
        for t in self.lc.tasks:
            ts = lc_state["tasks"][t.name]
            for p in t.paths:
                leaf = get_path(params, p)
                params = set_path(params, p,
                                  ts["a"][p].astype(leaf.dtype))
        return params
