"""LCTrainer: the production training loop.

Composes the paper's LC algorithm with the distributed substrate:

    for each LC step k (μ = μ0·aᵏ):
        L step  — ``steps_per_l`` compiled train steps (loss + penalty)
        C step  — jitted sharded projections Θ ← Π(w − λ/μ)
        λ step  — multiplier update
        monitors — L-step loss decrease, C-step distortion decrease (§7)

    throughout: checkpoint every N steps (async), retry transient
    failures, restore-from-checkpoint on hard failure, straggler
    tracking, deterministic seekable data (exact resume).
"""
from __future__ import annotations

import logging
import time
from dataclasses import dataclass, field
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core.algorithm import LCAlgorithm
from repro.core.tasks import get_path
from repro.distributed.sharding import use_mesh
from repro.launch.steps import make_train_step
from repro.optim import AdamW
from repro.runtime.fault_tolerance import (
    FaultInjector, RetryPolicy, StragglerMonitor)

log = logging.getLogger("repro.trainer")


@dataclass
class TrainerConfig:
    steps_per_l: int = 20
    ckpt_every: int = 50
    ckpt_dir: str | None = None
    keep_last: int = 3
    lr: float = 3e-4
    clip_norm: float = 1.0
    straggler_factor: float = 3.0
    # paper §7 monitor: the C step must not increase its own objective
    # ‖(w − λ/μ) − Δ(Θ)‖² at fixed (w, λ, μ); violations mean a broken
    # scheme warm start and are logged as errors.
    monitor_distortion: bool = True


class LCTrainer:
    def __init__(self, cfg, lc: LCAlgorithm, data, mesh=None,
                 tcfg: TrainerConfig | None = None,
                 optimizer: AdamW | None = None,
                 fault_injector: FaultInjector | None = None):
        self.cfg = cfg
        self.lc = lc
        self.data = data
        self.mesh = mesh
        if mesh is not None and lc.mesh is None:
            # the trainer owns the mesh: hand it to the algorithm so the
            # grouped C step shards its packed item axes over "data"
            lc.set_mesh(mesh)
        self.tcfg = tcfg or TrainerConfig()
        self.optimizer = optimizer or AdamW()
        self.retry = RetryPolicy()
        self.straggler = StragglerMonitor(
            factor=self.tcfg.straggler_factor)
        self.faults = fault_injector or FaultInjector()
        self.ckpt = (CheckpointManager(self.tcfg.ckpt_dir,
                                       self.tcfg.keep_last)
                     if self.tcfg.ckpt_dir else None)
        self._train_step = jax.jit(make_train_step(
            cfg, self.optimizer, lr=self.tcfg.lr,
            clip_norm=self.tcfg.clip_norm, with_lc=True))
        self.history: list[dict] = []

    # ------------------------------------------------------------------
    def init_state(self, key):
        from repro.launch.steps import init_train_state
        with use_mesh(self.mesh):
            state = init_train_state(key, self.cfg, self.optimizer,
                                     with_lc=True)
        # attach real LC state (Θ, λ) from the algorithm
        lc_state = self.lc.init(state["params"])
        state["lc"] = self._refs_from_lc(state["params"], lc_state)
        self._lc_state = lc_state
        return state

    def _refs_from_lc(self, params, lc_state):
        """Flatten LC (a, λ) into the train-state penalty refs."""
        a, lam = {}, {}
        for t in self.lc.tasks:
            ts = lc_state["tasks"][t.name]
            for p in t.paths:
                a[p] = ts["a"][p]
                lam[p] = ts["lam"][p]
        return {"a": a, "lam": lam, "mu": lc_state["mu"]}

    # ------------------------------------------------------------------
    def _one_step(self, state, step: int):
        self.faults.maybe_fail(step)
        batch = self.data.batch_at(step) if hasattr(self.data, "batch_at") \
            else self.data(step)
        return self._train_step(state, batch)

    def _l_step(self, state, lc_k: int, global_step: int):
        """One full L step = steps_per_l optimizer steps."""
        metrics = {}
        for i in range(self.tcfg.steps_per_l):
            step = global_step + i
            t0 = time.time()
            try:
                state, metrics = self.retry.run(
                    self._one_step, state, step,
                    on_retry=lambda a, e: log.warning(
                        "step %d retry %d: %s", step, a, e))
            except RuntimeError:
                if self.ckpt and self.ckpt.latest_step() is not None:
                    log.error("step %d hard failure — restoring", step)
                    state, _ = self.ckpt.restore(state)
                else:
                    raise
            dt = time.time() - t0
            if self.straggler.observe(dt):
                log.warning("straggler: step %d took %.3fs", step, dt)
            if self.ckpt and step > 0 \
                    and step % self.tcfg.ckpt_every == 0:
                self.ckpt.save(state, step)
        return state, metrics

    # ------------------------------------------------------------------
    def run(self, key, n_lc_steps: int | None = None):
        state = self.init_state(key)
        lc_state = self._lc_state
        schedule = self.lc.mu_schedule[:n_lc_steps] \
            if n_lc_steps else self.lc.mu_schedule
        global_step = int(state["step"])

        for g in self.lc.group_summary(state["params"]):
            log.info("c-step group: %s over %s (%d items, tasks=%s, "
                     "spec=%s, padding=%d)",
                     g["scheme"], g["item_shape"], g["items"], g["tasks"],
                     g["spec"], g["padding"])

        for k, mu in enumerate(schedule):
            lc_state = self.lc.set_mu(lc_state, mu, k)
            state["lc"] = self._refs_from_lc(state["params"], lc_state)
            pen0 = float(self.lc.penalty(state["params"], lc_state))

            state, metrics = self._l_step(state, k, global_step)
            global_step += self.tcfg.steps_per_l

            params = state["params"]
            if self.tcfg.monitor_distortion:
                d_pre = self.lc.shifted_distortion(params, lc_state)
                jax.block_until_ready(d_pre)
            # drain in-flight L-step work so c_step_ms times the C step
            # alone, not the async dispatch chain behind it
            jax.block_until_ready(params)
            t0 = time.time()
            lc_state = self.lc.c_step(params, lc_state)
            jax.block_until_ready(lc_state)
            c_step_ms = (time.time() - t0) * 1e3
            c_violations = []
            if self.tcfg.monitor_distortion:
                d_post = self.lc.shifted_distortion(params, lc_state)
                for n in d_pre:
                    pre, post = float(d_pre[n]), float(d_post[n])
                    if post > pre * (1 + 1e-5) + 1e-8:
                        c_violations.append(n)
                        log.error(
                            "C step increased ‖(w−λ/μ)−Δ(Θ)‖² for task "
                            "%s: %.6g → %.6g (broken warm start?)",
                            n, pre, post)
            lc_state = self.lc.multiplier_step(params, lc_state)
            state["lc"] = self._refs_from_lc(params, lc_state)

            dist = {n: float(v) for n, v in
                    self.lc.distortion(params, lc_state).items()}
            rec = {
                "lc_step": k, "mu": float(mu),
                "loss": float(metrics.get("loss", np.nan)),
                "ce": float(metrics.get("ce", np.nan)),
                "penalty_start": pen0,
                "distortion": dist,
                "c_step_ms": c_step_ms,
                "c_step_violations": c_violations,
                "compression_ratio": float(
                    self.lc.compression_ratio(params, lc_state)),
                "stragglers": self.straggler.stragglers,
            }
            self.history.append(rec)
            log.info("LC step %d: %s", k, rec)

        self._lc_state = lc_state
        if self.ckpt:
            self.ckpt.save(state, global_step, blocking=True)
        return state, lc_state

    # ------------------------------------------------------------------
    def compressed_params(self, state, lc_state):
        """Final model: w ← Δ(Θ)."""
        from repro.core.tasks import set_path
        params = state["params"]
        for t in self.lc.tasks:
            ts = lc_state["tasks"][t.name]
            for p in t.paths:
                leaf = get_path(params, p)
                params = set_path(params, p,
                                  ts["a"][p].astype(leaf.dtype))
        return params
