"""Fault-tolerance policies for the training loop.

Designed for 1000+-node behavior, exercised here via fault injection:

* ``RetryPolicy`` — transient step failures (preempted host, flaky ICI
  link surfacing as RuntimeError) retry with exponential backoff; after
  ``max_retries`` the trainer falls back to restore-from-checkpoint.
* ``StragglerMonitor`` — per-step wall times vs a rolling median; a step
  slower than ``factor``× median marks a straggler. The trainer's
  response is pluggable (log / re-shard via elastic reload / evict).
* ``FaultInjector`` — deterministic fault schedule for tests ("fail step
  17 twice, then succeed"), so recovery paths are unit-testable.
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass


@dataclass
class RetryPolicy:
    max_retries: int = 3
    backoff_s: float = 0.05
    backoff_mult: float = 2.0

    def run(self, fn, *args, on_retry=None, **kwargs):
        delay = self.backoff_s
        last = None
        for attempt in range(self.max_retries + 1):
            try:
                return fn(*args, **kwargs)
            except (RuntimeError, OSError) as e:  # transient class
                last = e
                if attempt == self.max_retries:
                    raise
                if on_retry:
                    on_retry(attempt, e)
                time.sleep(delay)
                delay *= self.backoff_mult
        raise last  # unreachable


@dataclass
class StragglerMonitor:
    factor: float = 3.0
    window: int = 32
    times: deque | None = None
    stragglers: int = 0

    def __post_init__(self):
        if self.times is None:
            self.times = deque(maxlen=self.window)
        elif self.times.maxlen != self.window:
            # caller handed in samples: keep the newest `window` of them
            self.times = deque(self.times, maxlen=self.window)

    def observe(self, dt: float) -> bool:
        """Record a step time; True if this step straggled."""
        is_straggler = False
        if len(self.times) >= 8:
            med = sorted(self.times)[len(self.times) // 2]
            is_straggler = dt > self.factor * med
        self.times.append(dt)
        if is_straggler:
            self.stragglers += 1
        return is_straggler


class FaultInjector:
    """fail_at: {step: n_failures} — raise RuntimeError n times at step."""

    def __init__(self, fail_at: dict[int, int] | None = None):
        self.fail_at = dict(fail_at or {})
        self.injected = 0

    def maybe_fail(self, step: int):
        n = self.fail_at.get(step, 0)
        if n > 0:
            self.fail_at[step] = n - 1
            self.injected += 1
            raise RuntimeError(f"injected fault at step {step}")
