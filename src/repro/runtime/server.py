"""Batched serving runtime for (optionally LC-compressed) models.

Flow: requests accumulate into a batch → one prefill (full-sequence
forward with cache capture) → token-by-token batched decode with the
compiled serve_step. Weights can be served in three forms:

* dense bf16 (baseline);
* LC-quantized, decompressed once at load (`dequantized`): accuracy of
  the compressed model, dense memory cost;
* LC-quantized, *kept compressed* (`quantized`): uint8 codebook indices
  + per-task codebook; matmuls run through kernels/quant_matmul (fused
  dequant in VMEM on TPU) — this is the paper's compressed-deployment
  story and cuts decode HBM traffic ~2× (uint8) to ~8× (4-bit packing).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import use_mesh
from repro.models.transformer import (
    decode_step, forward_hidden, init_cache, plan_stages)
from repro.models.layers import unembed


def pad_caches_to(cache, cfg, cur_len: int, max_len: int):
    """Grow prefill caches (seq-sized) to decode capacity.

    Attention/MLA caches pad the seq axis; ring-buffer (windowed) caches
    are rolled so slot = pos %% window stays consistent; recurrent states
    pass through unchanged.
    """
    specs_by_stage = {}
    for si, st in enumerate(plan_stages(cfg)):
        specs_by_stage[f"s{si}"] = st["specs"]

    out = {}
    for sname, stage in cache.items():
        specs = specs_by_stage[sname]
        new_stage = {}
        for pi, (pname, c) in enumerate(sorted(stage.items())):
            spec = specs[int(pname[3:])]
            if spec.mixer in ("attn", "mla"):
                nc = {}
                for k, arr in c.items():
                    seq_axis = arr.ndim - 3 if spec.mixer == "attn" \
                        else arr.ndim - 2
                    cap = max_len
                    if spec.mixer == "attn" and spec.window > 0:
                        cap = min(spec.window, max_len)
                    pad = cap - arr.shape[seq_axis]
                    if pad > 0:
                        widths = [(0, 0)] * arr.ndim
                        widths[seq_axis] = (0, pad)
                        arr = jnp.pad(arr, widths)
                    if spec.mixer == "attn" and spec.window > 0 \
                            and cur_len > spec.window:
                        # ring alignment: position p lives at slot p%w
                        arr = jnp.roll(arr, cur_len % spec.window,
                                       axis=seq_axis)
                    nc[k] = arr
                new_stage[pname] = nc
            else:
                new_stage[pname] = c
        out[sname] = new_stage
    return out


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_generated)
    prefill_len: int


class Server:
    def __init__(self, cfg, params, mesh=None, max_len: int = 512):
        self.cfg = cfg
        self.mesh = mesh
        self.max_len = max_len
        self.params = params
        with use_mesh(mesh):
            self._decode = jax.jit(
                lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
            self._prefill = jax.jit(
                lambda p, x: forward_hidden(p, x, cfg,
                                            return_caches=True))

    def generate(self, prompts: jnp.ndarray, n_tokens: int,
                 temperature: float = 0.0, key=None) -> GenerationResult:
        """prompts: (B, S) token batch (right-aligned, no padding support
        needed for the showcase — equal-length batches)."""
        cfg = self.cfg
        b, s = prompts.shape[0], prompts.shape[1]
        with use_mesh(self.mesh):
            hidden, _, caches = self._prefill(self.params, prompts)
            logits = unembed(self.params["embed"], hidden[:, -1:], cfg)
            caches = pad_caches_to(caches, cfg, s, self.max_len)
            toks = []
            tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
            for i in range(n_tokens):
                toks.append(tok)
                if i == n_tokens - 1:
                    break
                logits, caches = self._decode(
                    self.params, caches, tok, jnp.int32(s + i))
                if temperature > 0 and key is not None:
                    key, sub = jax.random.split(key)
                    tok = jax.random.categorical(
                        sub, logits[:, 0] / temperature)[:, None] \
                        .astype(jnp.int32)
                else:
                    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
        return GenerationResult(
            tokens=np.asarray(jnp.concatenate(toks, axis=1)),
            prefill_len=s)


# ----------------------------------------------------------------------
# Compressed-weight serving
# ----------------------------------------------------------------------
def quantize_params_for_serving(params, paths: list[str], k: int = 16,
                                iters: int = 20):
    """Quantize selected matrices to (uint8 idx, codebook) for deployment.

    Returns (packed: {path: (idx, codebook)}, dequantized params pytree).
    """
    from repro.core.schemes.quantize import kmeans_1d, quantile_init
    from repro.core.tasks import get_path, set_path
    packed = {}
    dq_params = params
    for p in paths:
        w = get_path(params, p)
        flat = w.astype(jnp.float32).ravel()
        cb = quantile_init(flat, k)
        cb, assign = kmeans_1d(flat, cb, iters)
        idx = assign.reshape(w.shape).astype(jnp.uint8)
        packed[p] = (idx, cb)
        dq_params = set_path(dq_params, p, cb[assign].reshape(w.shape)
                             .astype(w.dtype))
    return packed, dq_params


def serving_bits(packed: dict, float_bits: int = 16) -> tuple[int, int]:
    """(compressed bits, dense bits) over the packed matrices."""
    comp = 0
    dense = 0
    for idx, cb in packed.values():
        k = cb.shape[0]
        comp += idx.size * max(1, int(np.ceil(np.log2(k)))) \
            + k * 32
        dense += idx.size * float_bits
    return comp, dense
