"""Serving runtime: continuous batching over compressed-form weights.

Two layers:

* :class:`Server` — the simple batch API (one equal-length batch in, one
  jitted prefill + one jitted generate-scan out; sampling runs inside
  the scan, so decode never round-trips logits to host).
* :class:`ServingEngine` — slot-based continuous batching for request
  traffic: a queue with admission/eviction, chunked prefill into free
  slots, per-slot position/ring-cache bookkeeping, and exactly three
  compiled programs (decode tick, prefill tick, slot reset) whose
  signatures never change across a mixed-length trace — zero recompiles
  after warmup, counted by ``trace_counts``.

Weights are served in any mix of forms (see ``runtime/compressed``):
dense bf16, 4/8-bit codebook-quantized (fused-dequant GEMM), low-rank
factored (two thin matmuls, W never materialized), or pruned-sparse
(COO streaming). :func:`load_compressed_for_serving` maps an LC
checkpoint's Θ — codebooks/factors/masks from the quantize / lowrank /
prune schemes — straight into those forms, replacing the ad-hoc
re-k-means of :func:`quantize_params_for_serving` (kept for the legacy
path).
"""
from __future__ import annotations

import time
from collections import deque
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import use_mesh
from repro.models.transformer import (
    cache_axes, decode_step, forward_hidden, init_cache, plan_stages)
from repro.models.layers import unembed
from repro.runtime import compressed as cforms


def pad_caches_to(cache, cfg, cur_len: int, max_len: int):
    """Grow prefill caches (seq-sized) to decode capacity.

    Attention/MLA caches pad the seq axis; ring-buffer (windowed) caches
    are rolled so slot = pos %% window stays consistent; recurrent states
    pass through unchanged.
    """
    specs_by_stage = {}
    for si, st in enumerate(plan_stages(cfg)):
        specs_by_stage[f"s{si}"] = st["specs"]

    out = {}
    for sname, stage in cache.items():
        specs = specs_by_stage[sname]
        new_stage = {}
        for pi, (pname, c) in enumerate(sorted(stage.items())):
            spec = specs[int(pname[3:])]
            if spec.mixer in ("attn", "mla"):
                nc = {}
                for k, arr in c.items():
                    seq_axis = arr.ndim - 3 if spec.mixer == "attn" \
                        else arr.ndim - 2
                    cap = max_len
                    if spec.mixer == "attn" and spec.window > 0:
                        cap = min(spec.window, max_len)
                    pad = cap - arr.shape[seq_axis]
                    if pad > 0:
                        widths = [(0, 0)] * arr.ndim
                        widths[seq_axis] = (0, pad)
                        arr = jnp.pad(arr, widths)
                    if spec.mixer == "attn" and spec.window > 0 \
                            and cur_len > spec.window:
                        # ring alignment: position p lives at slot p%w
                        arr = jnp.roll(arr, cur_len % spec.window,
                                       axis=seq_axis)
                    nc[k] = arr
                new_stage[pname] = nc
            else:
                new_stage[pname] = c
        out[sname] = new_stage
    return out


def sample_tokens(logits, key, temperature: float):
    """Greedy (temperature == 0) or temperature sampling over the vocab
    axis. logits: (B, V) → (B,) int32. Runs inside jit — ``temperature``
    is static so the greedy path compiles without a categorical."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    return jax.random.categorical(
        key, logits.astype(jnp.float32) / temperature).astype(jnp.int32)


@dataclass
class GenerationResult:
    tokens: np.ndarray          # (B, n_generated)
    prefill_len: int


class Server:
    """Equal-length batch serving: prefill once, then one jitted scan
    generates every token with in-jit sampling (no per-token host
    sync)."""

    def __init__(self, cfg, params, mesh=None, max_len: int = 512):
        self.cfg = cfg
        self.mesh = mesh
        self.max_len = max_len
        self.params = params
        with use_mesh(mesh):
            self._decode = jax.jit(
                lambda p, c, t, pos: decode_step(p, c, t, pos, cfg))
            self._prefill = jax.jit(
                lambda p, x: forward_hidden(p, x, cfg,
                                            return_caches=True))

            def _generate(params, caches, logits0, start_pos, key, *,
                          n_tokens, temperature):
                key, sub = jax.random.split(key)
                tok0 = sample_tokens(logits0[:, 0], sub,
                                     temperature)[:, None]

                def body(carry, i):
                    tok, caches, key = carry
                    logits, caches = decode_step(
                        params, caches, tok, start_pos + i, cfg)
                    key, sub = jax.random.split(key)
                    nxt = sample_tokens(logits[:, 0], sub,
                                        temperature)[:, None]
                    return (nxt, caches, key), nxt

                _, toks = jax.lax.scan(
                    body, (tok0, caches, key),
                    jnp.arange(n_tokens - 1, dtype=jnp.int32))
                allt = jnp.concatenate([tok0[None], toks], axis=0)
                return jnp.moveaxis(allt[..., 0], 0, 1)    # (B, n_tokens)

            self._generate = jax.jit(
                _generate, static_argnames=("n_tokens", "temperature"))

    def generate(self, prompts: jnp.ndarray, n_tokens: int,
                 temperature: float = 0.0, key=None) -> GenerationResult:
        """prompts: (B, S) token batch (equal-length; for mixed-length
        traffic use :class:`ServingEngine`)."""
        cfg = self.cfg
        s = prompts.shape[1]
        if key is None:
            key = jax.random.PRNGKey(0)
        with use_mesh(self.mesh):
            hidden, _, caches = self._prefill(self.params, prompts)
            logits = unembed(self.params["embed"], hidden[:, -1:], cfg)
            caches = pad_caches_to(caches, cfg, s, self.max_len)
            toks = self._generate(
                self.params, caches, logits, jnp.int32(s), key,
                n_tokens=int(n_tokens), temperature=float(temperature))
        return GenerationResult(tokens=np.asarray(toks), prefill_len=s)


# ======================================================================
# Continuous batching
# ======================================================================
@dataclass
class Request:
    """One generation request on the synthetic-traffic timeline.
    ``arrival`` is in virtual seconds (the engine clock advances by the
    measured wall time of each device tick)."""

    id: int
    prompt: np.ndarray              # (S,) int32 tokens
    max_new: int
    arrival: float = 0.0


@dataclass
class FinishedRequest:
    id: int
    tokens: np.ndarray              # (n_generated,) int32
    prompt_len: int
    arrival: float
    first_token_at: float           # virtual time of first sampled token
    finished_at: float

    @property
    def latency(self) -> float:
        return self.finished_at - self.arrival

    @property
    def ttft(self) -> float:
        return self.first_token_at - self.arrival


_FREE, _PREFILL, _DECODE = "free", "prefill", "decode"


def engine_programs(cfg, slots: int, max_len: int, temperature: float,
                    trace_counts: dict):
    """The engine's three device programs, unjitted.

    Exposed at module level so the Layer-3 lint can lower the exact
    production programs on abstract shapes (f64 / callback / donation
    rules) without building an engine. ``trace_counts`` is mutated on
    every call — jitted, each increment marks one jit cache miss.
    Returns ``(decode_impl, prefill_impl, reset_impl)``; see
    :class:`ServingEngine` for signatures and jit/donation setup.
    """
    axes = cache_axes(cfg)

    def _merge(new, old, active):
        # per-slot select: active slots take the updated cache leaves,
        # inactive keep the old; the batch axis of every leaf comes from
        # cache_axes (scan stages carry a leading "layers" axis)
        def m(ax, n, o):
            shape = [1] * n.ndim
            shape[ax.index("batch")] = active.shape[0]
            return jnp.where(active.reshape(shape), n, o)

        return jax.tree_util.tree_map(
            m, axes, new, old, is_leaf=lambda x: isinstance(x, tuple))

    def decode_impl(params, cache, tok, pos, active, key):
        trace_counts["decode"] += 1
        logits, new_cache = decode_step(params, cache, tok[:, None],
                                        pos, cfg)
        cache = _merge(new_cache, cache, active)
        nxt = sample_tokens(logits[:, 0], key, temperature)
        return jnp.where(active, nxt, tok), cache

    def prefill_impl(params, cache, chunk, pos0, n_valid, active, key):
        trace_counts["prefill"] += 1
        b, c = chunk.shape

        def body(carry, t):
            cache, tok = carry
            step_active = active & (t < n_valid)
            logits, new_cache = decode_step(
                params, cache, chunk[:, t][:, None], pos0 + t, cfg)
            cache = _merge(new_cache, cache, step_active)
            sampled = sample_tokens(
                logits[:, 0], jax.random.fold_in(key, t), temperature)
            tok = jnp.where(step_active & (t == n_valid - 1),
                            sampled, tok)
            return (cache, tok), None

        (cache, tok), _ = jax.lax.scan(
            body, (cache, jnp.zeros((b,), jnp.int32)),
            jnp.arange(c, dtype=jnp.int32))
        return tok, cache

    def reset_impl(cache, mask):
        trace_counts["reset"] += 1
        fresh = init_cache(cfg, slots, max_len)
        return _merge(fresh, cache, mask)

    return decode_impl, prefill_impl, reset_impl


class ServingEngine:
    """Slot-based continuous batching.

    ``slots`` sequences decode together; finished slots are refilled
    from the queue mid-flight. Prompts stream in through chunked
    prefill (``prefill_chunk`` tokens per tick) so a long prompt never
    stalls decoding slots for more than one tick. All device work runs
    through three jitted programs with fixed shapes:

    * ``_decode(params, cache, tok (B,), pos (B,), active (B,), key)``
      → (next_tok, cache): one token for every active slot, per-slot
      positions, sampling in-jit, inactive slots' cache merged back
      unchanged.
    * ``_prefill(params, cache, chunk (B,C), pos0, n_valid, active,
      key)`` → (first_tok, cache): scan of C decode sub-steps feeding
      prompt tokens; slot b consumes ``n_valid[b]`` of them; the token
      sampled where ``t == n_valid-1`` seeds decode when the prompt
      ends this tick.
    * ``_reset(cache, mask)``: admitted slots restored to ``init_cache``
      values (recurrent states carry garbage otherwise — mlstm/slstm
      ``m`` must return to −30, not 0).

    ``trace_counts`` counts impl invocations (= jit cache misses): after
    warmup every value stays at 1 across arbitrary mixed-length traffic,
    which the bench and the Layer-3 lint assert.
    """

    def __init__(self, cfg, params, *, slots: int = 4, max_len: int = 256,
                 prefill_chunk: int = 8, temperature: float = 0.0,
                 mesh=None, seed: int = 0):
        self.cfg = cfg
        self.params = params
        self.slots = int(slots)
        self.max_len = int(max_len)
        self.prefill_chunk = int(prefill_chunk)
        self.temperature = float(temperature)
        self.mesh = mesh
        self.trace_counts = {"decode": 0, "prefill": 0, "reset": 0}
        self._key = jax.random.PRNGKey(seed)

        decode_impl, prefill_impl, reset_impl = engine_programs(
            cfg, self.slots, self.max_len, self.temperature,
            self.trace_counts)
        self._decode = jax.jit(decode_impl, donate_argnums=(1,))
        self._prefill = jax.jit(prefill_impl, donate_argnums=(1,))
        self._reset = jax.jit(reset_impl, donate_argnums=(0,))

        # host-side slot state
        self._cache = init_cache(cfg, self.slots, self.max_len)
        self._phase = [_FREE] * self.slots
        self._req: list[Request | None] = [None] * self.slots
        self._fed = np.zeros(self.slots, np.int64)   # prompt tokens fed
        self._pos = np.zeros(self.slots, np.int32)   # next write position
        self._tok = np.zeros(self.slots, np.int32)   # decode feed token
        self._gen: list[list[int]] = [[] for _ in range(self.slots)]
        self._meta: list[dict] = [{} for _ in range(self.slots)]
        self._now = 0.0

    # ------------------------------------------------------------------
    def _next_key(self):
        self._key, sub = jax.random.split(self._key)
        return sub

    def _timed(self, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        jax.block_until_ready(out)
        self._now += time.perf_counter() - t0
        return out

    def _admit(self, queue: deque, rejected):
        newly = np.zeros(self.slots, bool)
        for b in range(self.slots):
            if self._phase[b] != _FREE:
                continue
            # drop unservable requests (too long / empty) at the head
            while queue and queue[0].arrival <= self._now and (
                    len(queue[0].prompt) == 0
                    or len(queue[0].prompt) + queue[0].max_new
                    > self.max_len):
                rejected.append(queue.popleft())
            if not queue or queue[0].arrival > self._now:
                break
            req = queue.popleft()
            self._phase[b] = _PREFILL
            self._req[b] = req
            self._fed[b] = 0
            self._pos[b] = 0
            self._gen[b] = []
            self._meta[b] = {"arrival": req.arrival}
            newly[b] = True
        if newly.any():
            self._cache = self._timed(
                self._reset, self._cache, jnp.asarray(newly))

    def _prefill_tick(self):
        b = self.slots
        c = self.prefill_chunk
        chunk = np.zeros((b, c), np.int32)
        pos0 = np.zeros(b, np.int32)
        n_valid = np.zeros(b, np.int32)
        active = np.zeros(b, bool)
        for i in range(b):
            if self._phase[i] != _PREFILL:
                continue
            req = self._req[i]
            take = min(c, len(req.prompt) - int(self._fed[i]))
            chunk[i, :take] = req.prompt[self._fed[i]:self._fed[i] + take]
            pos0[i] = self._fed[i]
            n_valid[i] = take
            active[i] = True
        tok, self._cache = self._timed(
            self._prefill, self.params, self._cache, jnp.asarray(chunk),
            jnp.asarray(pos0), jnp.asarray(n_valid), jnp.asarray(active),
            self._next_key())
        tok = np.asarray(tok)
        for i in range(b):
            if not active[i]:
                continue
            self._fed[i] += int(n_valid[i])
            if self._fed[i] == len(self._req[i].prompt):
                self._phase[i] = _DECODE
                self._pos[i] = self._fed[i]
                self._tok[i] = tok[i]
                self._gen[i].append(int(tok[i]))
                self._meta[i]["first_token_at"] = self._now

    def _decode_tick(self, finished):
        active = np.array([p == _DECODE for p in self._phase])
        nxt, self._cache = self._timed(
            self._decode, self.params, self._cache,
            jnp.asarray(self._tok), jnp.asarray(self._pos),
            jnp.asarray(active), self._next_key())
        nxt = np.asarray(nxt)
        for i in range(self.slots):
            if not active[i]:
                continue
            self._pos[i] += 1
            req = self._req[i]
            if len(self._gen[i]) < req.max_new:
                self._gen[i].append(int(nxt[i]))
                self._tok[i] = nxt[i]
            if len(self._gen[i]) >= req.max_new:
                finished.append(FinishedRequest(
                    id=req.id, tokens=np.asarray(self._gen[i], np.int32),
                    prompt_len=len(req.prompt),
                    arrival=self._meta[i]["arrival"],
                    first_token_at=self._meta[i]["first_token_at"],
                    finished_at=self._now))
                self._phase[i] = _FREE
                self._req[i] = None

    # ------------------------------------------------------------------
    def run(self, requests: list[Request]) -> dict:
        """Serve a request trace to completion. Returns
        ``{"finished", "rejected", "stats"}`` — latencies on the virtual
        timeline (arrival offsets + measured device time per tick)."""
        queue = deque(sorted(requests, key=lambda r: (r.arrival, r.id)))
        finished: list[FinishedRequest] = []
        rejected: list[Request] = []
        decode_turn = False
        t_start = self._now
        with use_mesh(self.mesh):
            while queue or any(p != _FREE for p in self._phase):
                if all(p == _FREE for p in self._phase) and queue:
                    # idle: fast-forward the virtual clock to next arrival
                    self._now = max(self._now, queue[0].arrival)
                self._admit(queue, rejected)
                prefilling = any(p == _PREFILL for p in self._phase)
                decoding = any(p == _DECODE for p in self._phase)
                if prefilling and not (decoding and decode_turn):
                    self._prefill_tick()
                    decode_turn = True
                elif decoding:
                    self._decode_tick(finished)
                    decode_turn = False
                else:
                    # nothing runnable: queued arrivals are in the future
                    if queue:
                        self._now = max(self._now, queue[0].arrival)
        return {"finished": finished, "rejected": rejected,
                "stats": self.stats(finished, t_start)}

    def stats(self, finished: list[FinishedRequest],
              t_start: float = 0.0) -> dict:
        if not finished:
            return {"requests": 0, "tokens": 0, "tokens_per_sec": 0.0,
                    "p50_latency_s": 0.0, "p99_latency_s": 0.0,
                    "p50_ttft_s": 0.0, "p99_ttft_s": 0.0}
        toks = int(sum(len(f.tokens) for f in finished))
        span = max(self._now - t_start, 1e-9)
        lats = np.asarray([f.latency for f in finished])
        ttfts = np.asarray([f.ttft for f in finished])
        return {
            "requests": len(finished),
            "tokens": toks,
            "tokens_per_sec": toks / span,
            "p50_latency_s": float(np.percentile(lats, 50)),
            "p99_latency_s": float(np.percentile(lats, 99)),
            "p50_ttft_s": float(np.percentile(ttfts, 50)),
            "p99_ttft_s": float(np.percentile(ttfts, 99)),
        }


# ======================================================================
# Checkpoint bridge: LC Θ → serving weight forms
# ======================================================================
def load_compressed_for_serving(params, lc_state, tasks, *, bits: int = 4,
                                sparse_density_cutoff: float = 0.25):
    """Map an LC checkpoint's Θ straight into serving form.

    ``tasks`` must be resolved against ``params`` and match the names in
    ``lc_state["tasks"]`` (e.g. ``LCAlgorithm.tasks`` after ``init`` /
    training). Per task, by Θ structure:

    * quantize (``QuantTheta``): assignments split per leaf (AsVector
      offsets); 2-D leaves become :class:`~repro.runtime.compressed.
      QuantizedWeight` — 4-bit packed when the codebook has ≤ 16 entries
      and ``bits == 4``, else 8-bit indices. Non-2-D / stacked leaves
      fall back to the dense decompressed leaf.
    * lowrank (``{"u", "v"[, "rank"]}``): 2-D single-leaf views become
      :class:`LowRankWeight` with factors sliced to the selected rank.
    * prune (``{"theta"}``): 2-D leaves at density ≤
      ``sparse_density_cutoff`` become :class:`SparseWeight` (COO);
      denser ones stay dense-with-zeros (scatter only wins when sparse).

    Every fallback is the exact decompressed leaf ``a[path]``, so the
    bridged model always computes the compressed model's function.
    Returns ``(serving_params, report)`` — report maps each path to its
    chosen form.
    """
    from repro.core.schemes.quantize import QuantTheta
    from repro.core.tasks import set_path
    from repro.kernels.quant_matmul import ops as quant_ops

    serving = params
    report = {}

    for task in tasks:
        t = task if task.paths else task.resolve(params)
        ts = lc_state["tasks"][t.name]
        theta = ts["theta"]
        leaves = t.leaves(params)
        forms = {}

        def fallback(p):
            return np.asarray(ts["a"][p], np.float32)

        stacked = t.view.stacked

        if isinstance(theta, QuantTheta) and not stacked:
            cb = jnp.asarray(theta.codebook, jnp.float32)
            assign = np.asarray(theta.assign).ravel()
            n_codes = int(cb.shape[0])
            off = 0
            for p, w in zip(t.paths, leaves):
                size = int(np.prod(w.shape))
                idx = assign[off:off + size].reshape(w.shape)
                off += size
                if w.ndim == 2 and bits == 4 and n_codes <= 16:
                    packed = quant_ops.pack4(
                        jnp.asarray(idx, jnp.uint8))
                    leaf = cforms.QuantizedWeight(packed, cb, w.shape, 4)
                    forms[p] = "quant4"
                elif w.ndim == 2 and n_codes <= 256:
                    leaf = cforms.QuantizedWeight(
                        jnp.asarray(idx, jnp.uint8), cb, w.shape, 8)
                    forms[p] = "quant8"
                else:
                    leaf = jnp.asarray(fallback(p))
                    forms[p] = "dense"
                serving = set_path(serving, p, leaf)
        elif (isinstance(theta, dict) and "u" in theta and "v" in theta
              and not stacked and len(t.paths) == 1
              and leaves[0].ndim == 2):
            (p,), (w,) = t.paths, leaves
            r = int(theta.get("rank", theta["u"].shape[-1]))
            r = max(min(r, theta["u"].shape[-1]), 1)
            u = jnp.asarray(theta["u"][:, :r], jnp.float32)
            vt = jnp.asarray(theta["v"][:, :r], jnp.float32).T
            if (u.shape[0], vt.shape[1]) == tuple(w.shape):
                serving = set_path(serving, p, cforms.LowRankWeight(u, vt))
                forms[p] = f"lowrank(r={r})"
            else:                        # AsMatrix over a non-2-D leaf
                serving = set_path(serving, p, jnp.asarray(fallback(p)))
                forms[p] = "dense"
        elif isinstance(theta, dict) and set(theta) == {"theta"}:
            for p, w in zip(t.paths, leaves):
                dense = fallback(p)       # dense-with-zeros = Δ(Θ)
                density = float((dense != 0).mean()) if dense.size else 1.0
                if w.ndim == 2 and density <= sparse_density_cutoff:
                    rows, cols = np.nonzero(dense)
                    leaf = cforms.SparseWeight(
                        jnp.asarray(dense[rows, cols]),
                        jnp.asarray(rows, jnp.int32),
                        jnp.asarray(cols, jnp.int32), dense.shape)
                    forms[p] = f"sparse(d={density:.2f})"
                else:
                    leaf = jnp.asarray(dense)
                    forms[p] = f"dense(d={density:.2f})"
                serving = set_path(serving, p, leaf)
        else:
            for p in t.paths:
                serving = set_path(serving, p, jnp.asarray(fallback(p)))
                forms[p] = "dense"
        report[t.name] = forms
    return serving, report


def densified_for_serving(params, lc_state, tasks):
    """The dequantized/densified counterpart: every compressed path
    replaced by its exact dense decompressed leaf Δ(Θ). Parity
    reference for :func:`load_compressed_for_serving`."""
    from repro.core.tasks import set_path

    out = params
    for task in tasks:
        t = task if task.paths else task.resolve(params)
        ts = lc_state["tasks"][t.name]
        for p in t.paths:
            out = set_path(out, p, jnp.asarray(ts["a"][p], jnp.float32))
    return out


# ----------------------------------------------------------------------
# Legacy compressed-weight serving (re-k-means at load time)
# ----------------------------------------------------------------------
def quantize_params_for_serving(params, paths: list[str], k: int = 16,
                                iters: int = 20):
    """Quantize selected matrices to (uint8 idx, codebook) for deployment.

    Returns (packed: {path: (idx, codebook)}, dequantized params pytree).
    Prefer :func:`load_compressed_for_serving` when an LC checkpoint is
    available — this re-runs k-means from scratch on the dense weights.
    """
    from repro.core.schemes.quantize import kmeans_1d, quantile_init
    from repro.core.tasks import get_path, set_path
    packed = {}
    dq_params = params
    for p in paths:
        w = get_path(params, p)
        flat = w.astype(jnp.float32).ravel()
        cb = quantile_init(flat, k)
        cb, assign = kmeans_1d(flat, cb, iters)
        idx = assign.reshape(w.shape).astype(jnp.uint8)
        packed[p] = (idx, cb)
        dq_params = set_path(dq_params, p, cb[assign].reshape(w.shape)
                             .astype(w.dtype))
    return packed, dq_params


def serving_bits(packed: dict, float_bits: int = 16) -> tuple[int, int]:
    """(compressed bits, dense bits) over the packed matrices."""
    comp = 0
    dense = 0
    for idx, cb in packed.values():
        k = cb.shape[0]
        comp += idx.size * max(1, int(np.ceil(np.log2(k)))) \
            + k * 32
        dense += idx.size * float_bits
    return comp, dense
