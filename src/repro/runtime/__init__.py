from repro.runtime.fault_tolerance import (
    FaultInjector, RetryPolicy, StragglerMonitor)
from repro.runtime.trainer import LCTrainer, TrainerConfig
from repro.runtime.server import Server, quantize_params_for_serving

__all__ = ["FaultInjector", "RetryPolicy", "StragglerMonitor",
           "LCTrainer", "TrainerConfig", "Server",
           "quantize_params_for_serving"]
