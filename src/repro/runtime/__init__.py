from repro.runtime.fault_tolerance import (
    FaultInjector, RetryPolicy, StragglerMonitor)
from repro.runtime.trainer import LCTrainer, TrainerConfig
from repro.runtime.compressed import (
    LowRankWeight, QuantizedWeight, SparseWeight)
from repro.runtime.server import (
    FinishedRequest, Request, Server, ServingEngine,
    densified_for_serving, load_compressed_for_serving,
    quantize_params_for_serving)

__all__ = ["FaultInjector", "RetryPolicy", "StragglerMonitor",
           "LCTrainer", "TrainerConfig", "Server", "ServingEngine",
           "Request", "FinishedRequest", "QuantizedWeight",
           "LowRankWeight", "SparseWeight", "load_compressed_for_serving",
           "densified_for_serving", "quantize_params_for_serving"]
