"""Checkpointing: sharded-state save/restore with elastic reload.

Layout (one directory per step):
    ckpt_dir/step_000123/
        manifest.json      # paths, shapes, dtypes, step, mesh shape
        <flat//path>.npy   # one array per leaf ('/' → '::')
        _COMPLETE          # commit marker (atomicity)

* saves run on a background thread (training continues through I/O);
* restore maps leaves onto ANY mesh via the caller-provided shardings —
  elastic re-scaling = restore the same manifest with a different mesh;
* a missing _COMPLETE marker ⇒ the checkpoint is ignored (crash during
  write never corrupts restart state);
* ``keep_last`` old checkpoints are pruned after each commit.

On a real multi-host pod each host writes only its addressable shards;
here (single-process dry-run container) leaves are fully addressable, so
we np.asarray them — the manifest format is host-count-agnostic.
"""
from __future__ import annotations

import json
import os
import shutil
import threading
import time

import jax
import numpy as np

from repro.core.tasks import flatten_params

_SEP = "::"


def _flatten(state) -> dict[str, np.ndarray]:
    flat = flatten_params(state)
    return {p.replace("/", _SEP): v for p, v in flat.items()}


def _unflatten_into(template, flat: dict):
    """Rebuild the nested structure of ``template`` from flat arrays."""
    out = {}

    def rec(node, prefix):
        if isinstance(node, dict):
            return {k: rec(v, f"{prefix}/{k}" if prefix else str(k))
                    for k, v in node.items()}
        key = prefix.replace("/", _SEP)
        return flat[key]

    return rec(template, "")


class CheckpointManager:
    def __init__(self, directory: str, keep_last: int = 3,
                 async_save: bool = True):
        self.dir = directory
        self.keep_last = keep_last
        self.async_save = async_save
        self._thread: threading.Thread | None = None
        # a failed background save is re-raised from the next wait()/
        # save() on the training thread — a daemon thread dying silently
        # would otherwise turn "no checkpoints being written" into a
        # surprise at restore time
        self._error: BaseException | None = None
        os.makedirs(directory, exist_ok=True)

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.dir, f"step_{step:08d}")

    def steps(self) -> list[int]:
        out = []
        for d in os.listdir(self.dir):
            if d.startswith("step_") and os.path.exists(
                    os.path.join(self.dir, d, "_COMPLETE")):
                out.append(int(d.split("_")[1]))
        return sorted(out)

    def latest_step(self) -> int | None:
        s = self.steps()
        return s[-1] if s else None

    # ------------------------------------------------------------------
    def save(self, state, step: int, blocking: bool = False):
        # snapshot to host memory synchronously (cheap vs training step),
        # write to disk on the background thread
        flat = {k: np.asarray(v) for k, v in _flatten(state).items()}
        self.wait()

        def write():
            d = self._step_dir(step)
            tmp = d + ".tmp"
            if os.path.exists(tmp):
                shutil.rmtree(tmp)
            os.makedirs(tmp)
            manifest = {"step": step, "leaves": {}}
            for k, v in flat.items():
                np.save(os.path.join(tmp, k + ".npy"), v)
                manifest["leaves"][k] = {
                    "shape": list(v.shape), "dtype": str(v.dtype)}
            with open(os.path.join(tmp, "manifest.json"), "w") as f:
                json.dump(manifest, f)
            with open(os.path.join(tmp, "_COMPLETE"), "w") as f:
                f.write(str(time.time()))
            if os.path.exists(d):
                shutil.rmtree(d)
            os.rename(tmp, d)
            self._prune()

        if self.async_save and not blocking:
            def guarded():
                try:
                    write()
                except BaseException as e:  # surfaced by the next wait()
                    self._error = e

            self._thread = threading.Thread(target=guarded, daemon=True)
            self._thread.start()
        else:
            write()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None
        if self._error is not None:
            e, self._error = self._error, None
            raise RuntimeError(
                f"background checkpoint save failed: {e!r}") from e

    def _prune(self):
        steps = self.steps()
        for s in steps[:-self.keep_last]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def restore(self, template, step: int | None = None,
                shardings=None):
        """Load a checkpoint into the structure of ``template``.

        ``shardings``: optional pytree (same structure) of NamedShardings
        — pass shardings built against a *different* mesh to elastically
        re-scale; jax.device_put reshards on the fly.
        """
        step = step if step is not None else self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no complete checkpoint in {self.dir}")
        d = self._step_dir(step)
        flat = {}
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        for k in manifest["leaves"]:
            flat[k] = np.load(os.path.join(d, k + ".npy"))
        state = _unflatten_into(template, flat)
        if shardings is not None:
            state = jax.tree_util.tree_map(
                lambda x, s: jax.device_put(x, s), state, shardings)
        return state, step
