"""Gemma3-27B-class config [hf:google/gemma-3 family]: 62L, d=5376,
32H GQA(kv=16), d_ff=21504, vocab=262144, 5:1 local:global attention
(local window 1024). 62 = 10×(5 local + 1 global) + 2 local tail."""
from repro.configs.base import LayerSpec, ModelConfig

_LOCAL = LayerSpec("attn", "dense", window=1024)
_GLOBAL = LayerSpec("attn", "dense", window=0)

CONFIG = ModelConfig(
    name="gemma3-27b",
    d_model=5376, n_heads=32, n_kv_heads=16, head_dim=128,
    d_ff=21504, vocab_size=262144,
    pattern=(_LOCAL, _LOCAL, _LOCAL, _LOCAL, _LOCAL, _GLOBAL),
    pattern_reps=10,
    tail=(_LOCAL, _LOCAL),
    rope_theta=1e6, tie_embeddings=True,
    # 5-in-6 layers are O(window); the periodic global layers keep full KV
    # (the arch's own design) — long_500k runs with ring-buffer local KV.
    subquadratic=True,
)
