"""MusicGen-large [arXiv:2306.05284]: decoder-only over EnCodec tokens.
48L, d=2048, 32H MHA, d_ff=8192, vocab=2048 (one EnCodec codebook head).
The EnCodec frontend is a STUB — input_specs supplies precomputed frame
embeddings (sum of the 4 codebook embeddings, dim d_model)."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    d_model=2048, n_heads=32, n_kv_heads=32, head_dim=64,
    d_ff=8192, vocab_size=2048,
    pattern=(LayerSpec("attn", "dense"),),
    pattern_reps=48,
    rope_theta=10000.0, tie_embeddings=False,
    input_mode="embeddings", d_input=2048,
    subquadratic=False,
)
