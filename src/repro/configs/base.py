"""Model configuration dataclasses.

Every assigned architecture is expressed as a *pattern* of layer specs
(mixer × ffn) repeated ``pattern_reps`` times plus an optional unrolled
``tail`` — the transformer scans over pattern repetitions so compile time
is O(|pattern|), not O(n_layers).
"""
from __future__ import annotations

from dataclasses import dataclass, field, replace
from typing import Optional


@dataclass(frozen=True)
class MoECfg:
    n_experts: int
    top_k: int
    d_expert: int
    n_shared: int = 0
    capacity_factor: float = 1.25
    router_aux_weight: float = 0.01


@dataclass(frozen=True)
class MLACfg:
    """Multi-head Latent Attention (DeepSeek-V2 / MiniCPM3)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_dim: int = 64
    qk_rope_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class MambaCfg:
    d_state: int = 16
    d_conv: int = 4
    expand: int = 2
    dt_rank: int = 0  # 0 → ceil(d_model/16)


@dataclass(frozen=True)
class XLSTMCfg:
    proj_factor_m: float = 2.0     # mLSTM up-projection
    proj_factor_s: float = 4 / 3   # sLSTM post-MLP
    conv_kernel: int = 4
    chunk: int = 256               # mLSTM chunkwise-parallel chunk length


@dataclass(frozen=True)
class LayerSpec:
    mixer: str          # attn | mla | mamba | mlstm | slstm
    ffn: str            # dense | moe | none
    window: int = 0     # sliding-window size for mixer="attn" (0 = full)

    def __post_init__(self):
        assert self.mixer in ("attn", "mla", "mamba", "mlstm", "slstm")
        assert self.ffn in ("dense", "moe", "none")


@dataclass(frozen=True)
class ModelConfig:
    name: str
    d_model: int
    n_heads: int
    n_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    pattern: tuple[LayerSpec, ...]
    pattern_reps: int
    lead: tuple[LayerSpec, ...] = ()    # unrolled layers before the scan
    tail: tuple[LayerSpec, ...] = ()    # unrolled layers after the scan
    moe: Optional[MoECfg] = None
    mla: Optional[MLACfg] = None
    mamba: Optional[MambaCfg] = None
    xlstm: Optional[XLSTMCfg] = None
    rope_theta: float = 10000.0
    norm_eps: float = 1e-6
    tie_embeddings: bool = True
    input_mode: str = "tokens"      # tokens | embeddings (stub frontend)
    d_input: int = 0                # embeddings mode: frontend embed dim
    dtype: str = "bfloat16"
    remat: bool = True
    attn_chunk_q: int = 1024        # blockwise-attention chunk sizes
    attn_chunk_kv: int = 1024
    # treat attention as a fused Pallas flash kernel (kernels/
    # flash_attention) for the dry-run accounting — beyond-paper perf
    fused_attention: bool = False
    # long-context capability flag (sub-quadratic mechanism present);
    # used by the dry-run to decide long_500k applicability.
    subquadratic: bool = False

    @property
    def n_layers(self) -> int:
        return (len(self.lead) + len(self.pattern) * self.pattern_reps
                + len(self.tail))

    @property
    def q_dim(self) -> int:
        return self.n_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.n_kv_heads * self.head_dim

    def with_(self, **kw) -> "ModelConfig":
        return replace(self, **kw)

    def all_layer_specs(self) -> list[LayerSpec]:
        return (list(self.lead) + list(self.pattern) * self.pattern_reps
                + list(self.tail))

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks + head)."""
        from repro.models.transformer import count_params
        return count_params(self)

    def active_param_count(self) -> int:
        from repro.models.transformer import count_params
        return count_params(self, active_only=True)


@dataclass(frozen=True)
class ShapeCfg:
    """One input-shape cell from the assignment."""
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES: dict[str, ShapeCfg] = {
    "train_4k": ShapeCfg("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCfg("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCfg("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCfg("long_500k", 524288, 1, "decode"),
}
