"""Architecture registry: ``get_config(arch_id)`` + reduced smoke configs."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import (
    LayerSpec, MLACfg, MambaCfg, MoECfg, ModelConfig, ShapeCfg, SHAPES,
    XLSTMCfg)

_MODULES = {
    "deepseek-moe-16b": "deepseek_moe_16b",
    "mixtral-8x7b": "mixtral_8x7b",
    "internvl2-1b": "internvl2_1b",
    "musicgen-large": "musicgen_large",
    "gemma3-27b": "gemma3_27b",
    "phi3-mini-3.8b": "phi3_mini_3_8b",
    "mistral-nemo-12b": "mistral_nemo_12b",
    "minicpm3-4b": "minicpm3_4b",
    "jamba-v0.1-52b": "jamba_v01_52b",
    "xlstm-125m": "xlstm_125m",
}

ARCHS = list(_MODULES)


def get_config(name: str) -> ModelConfig:
    if name not in _MODULES:
        raise KeyError(f"unknown arch {name!r}; known: {ARCHS}")
    mod = importlib.import_module(f"repro.configs.{_MODULES[name]}")
    return mod.CONFIG


def reduced_config(cfg: ModelConfig) -> ModelConfig:
    """Tiny same-family config for CPU smoke tests: identical pattern
    structure (mixers/ffn kinds/windows scaled), small dims."""
    def shrink_spec(s: LayerSpec) -> LayerSpec:
        return LayerSpec(s.mixer, s.ffn, window=min(s.window, 8)
                         if s.window else 0)

    kw = dict(
        name=cfg.name + "-reduced",
        d_model=64, n_heads=2, n_kv_heads=min(cfg.n_kv_heads, 2),
        head_dim=16, d_ff=128 if cfg.d_ff else 0, vocab_size=256,
        pattern=tuple(shrink_spec(s) for s in cfg.pattern),
        pattern_reps=min(cfg.pattern_reps, 2),
        lead=tuple(shrink_spec(s) for s in cfg.lead),
        tail=tuple(shrink_spec(s) for s in cfg.tail[:1]),
        attn_chunk_q=8, attn_chunk_kv=8,
    )
    if cfg.moe:
        kw["moe"] = dataclasses.replace(
            cfg.moe, n_experts=4, top_k=min(cfg.moe.top_k, 2),
            d_expert=32, n_shared=min(cfg.moe.n_shared, 1))
    if cfg.mla:
        kw["mla"] = MLACfg(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8,
                           qk_rope_dim=8, v_head_dim=8)
    if cfg.mamba:
        kw["mamba"] = MambaCfg(d_state=4, d_conv=4, expand=2, dt_rank=8)
    if cfg.xlstm:
        kw["xlstm"] = XLSTMCfg(chunk=8)
    if cfg.input_mode == "embeddings":
        kw["input_mode"] = "embeddings"
        kw["d_input"] = 32
        kw["tie_embeddings"] = False
    return dataclasses.replace(cfg, **kw)


__all__ = ["ARCHS", "get_config", "reduced_config", "ModelConfig",
           "LayerSpec", "MoECfg", "MLACfg", "MambaCfg", "XLSTMCfg",
           "ShapeCfg", "SHAPES"]
