"""DeepSeekMoE-16B [arXiv:2401.06066]: 28L, d=2048, 16H (MHA), fine-grained
MoE — 64 routed experts top-6 + 2 shared, expert d_ff=1408; layer 0 is a
dense FFN (d_ff=10944) as in the released checkpoint."""
from repro.configs.base import LayerSpec, MoECfg, ModelConfig

CONFIG = ModelConfig(
    name="deepseek-moe-16b",
    d_model=2048, n_heads=16, n_kv_heads=16, head_dim=128,
    d_ff=10944,  # the single dense layer's FFN width
    vocab_size=102400,
    lead=(LayerSpec("attn", "dense"),),
    pattern=(LayerSpec("attn", "moe"),),
    pattern_reps=27,
    moe=MoECfg(n_experts=64, top_k=6, d_expert=1408, n_shared=2),
    rope_theta=10000.0, tie_embeddings=False,
    subquadratic=False,
)
