"""MiniCPM3-4B [hf:openbmb/MiniCPM3-4B]: 62L, d=2560, 40H, d_ff=6400,
vocab=73448, Multi-head Latent Attention (q_lora=768, kv_lora=256,
qk_nope=64, qk_rope=32, v_head=64) — the KV cache stores only the
(kv_lora+rope)-dim latents."""
from repro.configs.base import LayerSpec, MLACfg, ModelConfig

CONFIG = ModelConfig(
    name="minicpm3-4b",
    d_model=2560, n_heads=40, n_kv_heads=40, head_dim=64,
    d_ff=6400, vocab_size=73448,
    pattern=(LayerSpec("mla", "dense"),),
    pattern_reps=62,
    mla=MLACfg(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
               qk_rope_dim=32, v_head_dim=64),
    rope_theta=10000.0, tie_embeddings=True,
    subquadratic=False,
)
