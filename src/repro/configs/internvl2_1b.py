"""InternVL2-1B [arXiv:2404.16821]: InternViT-300M frontend (STUB — the
dry-run feeds precomputed patch embeddings via input_specs) + Qwen2-0.5B
LM backbone: 24L, d=896, 14H GQA(kv=2), d_ff=4864, vocab=151655."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    d_model=896, n_heads=14, n_kv_heads=2, head_dim=64,
    d_ff=4864, vocab_size=151655,
    pattern=(LayerSpec("attn", "dense"),),
    pattern_reps=24,
    rope_theta=1e6, tie_embeddings=False,
    input_mode="embeddings", d_input=1024,  # InternViT hidden size
    subquadratic=False,
)
