"""Jamba-v0.1 (52B total) [arXiv:2403.19887]: 32L, d=4096. Period-8
super-block: attention at index 4, Mamba elsewhere (1:7 attn:mamba);
MoE (16 experts, top-2, d_expert=14336) on odd layers, dense FFN on even.
GQA kv=8 on the attention layers."""
from repro.configs.base import LayerSpec, MambaCfg, MoECfg, ModelConfig

_P = []
for i in range(8):
    mixer = "attn" if i == 4 else "mamba"
    ffn = "moe" if i % 2 == 1 else "dense"
    _P.append(LayerSpec(mixer, ffn))

CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=65536,
    pattern=tuple(_P),
    pattern_reps=4,
    moe=MoECfg(n_experts=16, top_k=2, d_expert=14336, n_shared=0),
    mamba=MambaCfg(d_state=16, d_conv=4, expand=2),
    rope_theta=10000.0, tie_embeddings=False,
    subquadratic=True,  # Mamba states + 4 attention layers
)
