"""xLSTM-125M [arXiv:2405.04517]: 12 blocks, d=768, 4 heads, vocab=50304,
d_ff=0 (projections live inside the xLSTM blocks). mLSTM:sLSTM ≈ 5:1
interleave (pattern of 6, ×2). Pure recurrent state → long_500k capable."""
from repro.configs.base import LayerSpec, ModelConfig, XLSTMCfg

_M = LayerSpec("mlstm", "none")
_S = LayerSpec("slstm", "none")

CONFIG = ModelConfig(
    name="xlstm-125m",
    d_model=768, n_heads=4, n_kv_heads=4, head_dim=192,
    d_ff=0, vocab_size=50304,
    pattern=(_M, _M, _M, _M, _M, _S),
    pattern_reps=2,
    xlstm=XLSTMCfg(proj_factor_m=2.0, proj_factor_s=4 / 3,
                   conv_kernel=4, chunk=256),
    tie_embeddings=True,
    subquadratic=True,
)
