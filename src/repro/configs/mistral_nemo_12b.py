"""Mistral-Nemo-12B [hf:mistralai/Mistral-Nemo-Base-2407]: 40L, d=5120,
32H GQA(kv=8), head_dim=128 (q_dim=4096 ≠ d_model), d_ff=14336,
vocab=131072, full attention, 128k context."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="mistral-nemo-12b",
    d_model=5120, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=131072,
    pattern=(LayerSpec("attn", "dense"),),
    pattern_reps=40,
    rope_theta=1e6, tie_embeddings=False,
    subquadratic=False,
)
