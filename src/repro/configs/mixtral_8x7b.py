"""Mixtral-8x7B [arXiv:2401.04088]: 32L, d=4096, 32H GQA(kv=8), 8 experts
top-2 (d_ff=14336 per expert), sliding-window attention (w=4096)."""
from repro.configs.base import LayerSpec, MoECfg, ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    d_model=4096, n_heads=32, n_kv_heads=8, head_dim=128,
    d_ff=14336, vocab_size=32000,
    pattern=(LayerSpec("attn", "moe", window=4096),),
    pattern_reps=32,
    moe=MoECfg(n_experts=8, top_k=2, d_expert=14336, n_shared=0),
    rope_theta=1e6, tie_embeddings=False,
    subquadratic=True,  # SWA → ring-buffer KV, O(window) per token
)
