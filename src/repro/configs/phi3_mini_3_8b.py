"""Phi-3-mini 3.8B [arXiv:2404.14219]: 32L, d=3072, 32H MHA (kv=32),
head_dim=96, d_ff=8192, vocab=32064. RoPE + SwiGLU, full attention."""
from repro.configs.base import LayerSpec, ModelConfig

CONFIG = ModelConfig(
    name="phi3-mini-3.8b",
    d_model=3072, n_heads=32, n_kv_heads=32, head_dim=96,
    d_ff=8192, vocab_size=32064,
    pattern=(LayerSpec("attn", "dense"),),
    pattern_reps=32,
    rope_theta=10000.0, tie_embeddings=False,
    subquadratic=False,
)
