"""LR and μ schedules."""
from __future__ import annotations

import numpy as np


def constant(lr: float):
    return lambda step: lr


def cosine_warmup(peak: float, warmup: int, total: int, floor: float = 0.0):
    def f(step):
        import jax.numpy as jnp
        w = jnp.minimum(step / max(warmup, 1), 1.0)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return peak * w * (floor + (1 - floor)
                           * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return f


def lstep_decay(base: float, decay: float = 0.98):
    """Paper §6: lr_base · decay^lc_step, constant within each L step."""
    return lambda lc_step: base * (decay ** lc_step)


def mu_exponential(mu0: float, a: float, n: int) -> list[float]:
    return [mu0 * a**k for k in range(n)]
