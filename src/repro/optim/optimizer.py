"""Optimizers (pure JAX, sharded-state-friendly).

AdamW for LM pretraining, SGD+Nesterov-momentum for paper-faithful L
steps (the paper's showcase uses SGD momentum 0.9 nesterov). States are
f32 pytrees with the same structure (and therefore sharding) as params.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp


def _tmap(f, *trees):
    return jax.tree_util.tree_map(f, *trees)


@dataclass(frozen=True)
class AdamW:
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0

    def init(self, params):
        z = _tmap(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": z, "v": _tmap(jnp.copy, z),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, lr):
        step = state["step"] + 1
        t = step.astype(jnp.float32)
        c1 = 1.0 - self.b1 ** t
        c2 = 1.0 - self.b2 ** t
        m = _tmap(lambda m_, g: self.b1 * m_
                  + (1 - self.b1) * g.astype(jnp.float32),
                  state["m"], grads)
        v = _tmap(lambda v_, g: self.b2 * v_
                  + (1 - self.b2) * jnp.square(g.astype(jnp.float32)),
                  state["v"], grads)
        new_params = _tmap(
            lambda p, m_, v_: (p.astype(jnp.float32)
                               - lr * ((m_ / c1)
                                       / (jnp.sqrt(v_ / c2) + self.eps)
                                       + self.weight_decay
                                       * p.astype(jnp.float32))
                               ).astype(p.dtype),
            params, m, v)
        return new_params, {"m": m, "v": v, "step": step}


@dataclass(frozen=True)
class SGDM:
    """SGD + (Nesterov) momentum — the paper's L-step optimizer."""
    momentum: float = 0.9
    nesterov: bool = True

    def init(self, params):
        return {"mom": _tmap(lambda p: jnp.zeros(p.shape, jnp.float32),
                             params),
                "step": jnp.zeros((), jnp.int32)}

    def update(self, grads, state, params, lr):
        mom = _tmap(lambda b, g: self.momentum * b + g.astype(jnp.float32),
                    state["mom"], grads)
        if self.nesterov:
            upd = _tmap(lambda g, b: g.astype(jnp.float32)
                        + self.momentum * b, grads, mom)
        else:
            upd = mom
        new_params = _tmap(
            lambda p, u: (p.astype(jnp.float32) - lr * u).astype(p.dtype),
            params, upd)
        return new_params, {"mom": mom, "step": state["step"] + 1}


def global_norm(tree) -> jnp.ndarray:
    leaves = jax.tree_util.tree_leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    n = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(n, 1e-9))
    return _tmap(lambda l: l * scale.astype(l.dtype), tree), n
