from repro.optim.optimizer import (
    AdamW, SGDM, clip_by_global_norm, global_norm)
from repro.optim import schedules

__all__ = ["AdamW", "SGDM", "clip_by_global_norm", "global_norm",
           "schedules"]
