"""CLI training driver.

    PYTHONPATH=src python -m repro.launch.train --arch xlstm-125m \
        --reduced --lc-steps 4 --steps-per-l 10 --batch 4 --seq 128

Runs LC-compressed training end-to-end: data stream → L steps (compiled
train step with the LC penalty) → C steps → multipliers, with
checkpointing and fault tolerance. ``--reduced`` uses the smoke config
(CPU-sized); full configs expect a real TPU mesh.
"""
from __future__ import annotations

import argparse
import logging

import jax

from repro.configs import ARCHS, get_config, reduced_config
from repro.core import (
    AsStacked, AsVector, CompressionTask, LCAlgorithm,
    exponential_mu_schedule)
from repro.core.schemes import AdaptiveQuantization, ConstraintL0Pruning
from repro.data import TokenStream, embedding_stream
from repro.launch.mesh import make_debug_mesh
from repro.runtime import FaultInjector, LCTrainer, TrainerConfig


def default_tasks(cfg, compression: str = "quantize"):
    """The flagship per-arch compression tasks: per-layer adaptive
    codebooks on the scanned stacks (AsStacked ⇒ vmapped C steps)."""
    if compression == "quantize":
        return [CompressionTask(
            "quantize-stacks", r"stages/.*/(w_gate|w_up|w_down|wq|wk|wv|wo|in_proj|out_proj|up_proj|down_proj|w)$",
            AsStacked("vector"), AdaptiveQuantization(k=16, iters=10))]
    if compression == "prune":
        return [CompressionTask(
            "prune-all", r"stages/.*/(w_gate|w_up|w_down|wq|wk|wv|wo)$",
            AsVector(), ConstraintL0Pruning(kappa=0))]  # κ set by caller
    raise ValueError(compression)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--lc-steps", type=int, default=3)
    ap.add_argument("--steps-per-l", type=int, default=5)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--mu0", type=float, default=9e-5)
    ap.add_argument("--mu-a", type=float, default=1.2)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    logging.basicConfig(level=logging.INFO)
    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)

    if cfg.input_mode == "tokens":
        data = TokenStream(cfg.vocab_size, args.batch, args.seq)
    else:
        fn = embedding_stream(args.batch, args.seq, cfg.d_input,
                              cfg.vocab_size)
        class _D:  # noqa: N801
            batch_at = staticmethod(fn)
        data = _D()

    lc = LCAlgorithm(
        default_tasks(cfg),
        exponential_mu_schedule(args.mu0, args.mu_a, args.lc_steps))
    mesh = make_debug_mesh()
    trainer = LCTrainer(
        cfg, lc, data, mesh=mesh,
        tcfg=TrainerConfig(steps_per_l=args.steps_per_l, lr=args.lr,
                           ckpt_dir=args.ckpt_dir),
        fault_injector=FaultInjector())
    state, lc_state = trainer.run(jax.random.PRNGKey(0))
    for rec in trainer.history:
        print(rec)
    print("final compression ratio:",
          trainer.history[-1]["compression_ratio"])


if __name__ == "__main__":
    main()
