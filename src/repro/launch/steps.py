"""Compiled step functions: LC train step and serve (decode) step.

``make_train_step`` builds the paper's L-step inner update as one pjit-able
function: model loss + LC quadratic penalty (μ/2‖w − a − λ/μ‖² over the
compressed parameter set) → grads → clip → optimizer. ``a = Δ(Θ)`` and the
multipliers ``λ`` ride in the train state with the same sharding as the
parameters, so the penalty adds zero collectives.

``make_serve_step`` is the 1-token decode step (optionally over
codebook-quantized weights — see kernels/quant_matmul)."""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.tasks import flatten_params, get_path
from repro.models.transformer import decode_step, loss_fn
from repro.optim import AdamW, clip_by_global_norm


def lc_param_paths(params_or_shapes) -> list[str]:
    """The compressed set: every parameter with ndim ≥ 2 (matrices and
    stacked matrices; norms/biases stay uncompressed, per paper practice)."""
    flat = flatten_params(params_or_shapes)
    return [p for p, l in flat.items() if getattr(l, "ndim", 0) >= 2]


def lc_penalty_from_refs(params, a: dict, lam: dict,
                         mu: jnp.ndarray) -> jnp.ndarray:
    total = jnp.float32(0.0)
    for p, a_leaf in a.items():
        w = get_path(params, p).astype(jnp.float32)
        d = w - a_leaf - lam[p] / mu
        total = total + 0.5 * mu * jnp.sum(d * d)
    return total


def init_lc_refs(params, paths: list[str]) -> dict:
    """Direct-compression placeholder: a = w (zero penalty at start),
    λ = 0. The LC driver overwrites ``a`` after each real C step."""
    a = {p: get_path(params, p).astype(jnp.float32) for p in paths}
    lam = {p: jnp.zeros_like(v) for p, v in a.items()}
    return {"a": a, "lam": lam, "mu": jnp.float32(1e-4)}


def stable_lc_refs(new_refs: dict, old_refs: dict) -> dict:
    """Fresh Δ(Θ)/λ refs re-laid onto the refs they replace.

    The overlapped trainer swaps penalty refs *between microbatches of a
    compiled L step*; the swap must be layout-invisible to the already
    compiled executable (same shardings, async device_put only) so the
    only semantic change is the documented stale-refs window. μ is the
    caller's business (it advances at the L-step start, not at the
    swap), so it is carried from ``old_refs`` untouched.
    """
    from repro.distributed.sharding import match_shardings
    out = match_shardings(
        {"a": new_refs["a"], "lam": new_refs["lam"]},
        {"a": old_refs["a"], "lam": old_refs["lam"]})
    return {"a": out["a"], "lam": out["lam"], "mu": old_refs["mu"]}


def make_train_step(cfg, optimizer: AdamW | None = None,
                    lr: float | Callable = 3e-4,
                    clip_norm: float = 1.0,
                    with_lc: bool = True):
    optimizer = optimizer or AdamW()
    lr_fn = lr if callable(lr) else (lambda step: lr)

    def train_step(state, batch):
        def lossf(p):
            loss, metrics = loss_fn(p, batch, cfg)
            if with_lc:
                pen = lc_penalty_from_refs(
                    p, state["lc"]["a"], state["lc"]["lam"],
                    state["lc"]["mu"])
                metrics = dict(metrics, lc_penalty=pen)
                loss = loss + pen
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(
            lossf, has_aux=True)(state["params"])
        grads, gnorm = clip_by_global_norm(grads, clip_norm)
        new_params, opt_state = optimizer.update(
            grads, state["opt"], state["params"], lr_fn(state["step"]))
        new_state = dict(state, params=new_params, opt=opt_state,
                         step=state["step"] + 1)
        metrics = dict(metrics, loss=loss, grad_norm=gnorm)
        return new_state, metrics

    return train_step


def init_train_state(key, cfg, optimizer: AdamW | None = None,
                     with_lc: bool = True):
    from repro.models.transformer import init_params
    optimizer = optimizer or AdamW()
    params = init_params(key, cfg)
    state = {"params": params, "opt": optimizer.init(params),
             "step": jnp.zeros((), jnp.int32)}
    if with_lc:
        state["lc"] = init_lc_refs(params, lc_param_paths(params))
    return state


def make_serve_step(cfg):
    def serve_step(params, cache, inputs, pos):
        return decode_step(params, cache, inputs, pos, cfg)
    return serve_step


def make_prefill_step(cfg):
    from repro.models.transformer import forward_hidden
    from repro.models.layers import unembed

    def prefill_step(params, inputs):
        hidden, _ = forward_hidden(params, inputs, cfg)
        return unembed(params["embed"], hidden[:, -1:], cfg)

    return prefill_step
