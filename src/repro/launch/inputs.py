"""ShapeDtypeStruct stand-ins for every model input and state tree —
weak-type-correct, shardable, zero device allocation. The dry-run lowers
against these; nothing is ever materialized."""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distributed.sharding import (
    SERVE_RULES, resolve_spec, tree_shardings)
from repro.launch.steps import init_train_state, lc_param_paths
from repro.models.transformer import (
    cache_axes, init_cache, init_params, param_axes)


def _sds(shape, dtype, mesh, names, rules=None):
    sharding = NamedSharding(mesh, resolve_spec(tuple(names), tuple(shape),
                                                mesh, rules))
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def _tree_sds(shapes_tree, axes_tree, mesh, rules=None):
    """Match a jax.eval_shape result with a logical-axes tree → SDS tree."""
    def mk(leaf, names):
        return _sds(leaf.shape, leaf.dtype, mesh, names, rules)

    return jax.tree_util.tree_map(
        lambda names, leaf: mk(leaf, names), axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, (tuple, list)) and
        all(isinstance(e, (str, type(None))) for e in x))


def _replicated_sds(shapes_tree, mesh):
    rep = NamedSharding(mesh, P())
    return jax.tree_util.tree_map(
        lambda l: jax.ShapeDtypeStruct(l.shape, l.dtype, sharding=rep),
        shapes_tree)


def batch_specs(cfg, shape_cfg, mesh: Mesh) -> dict:
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    if cfg.input_mode == "tokens":
        inputs = _sds((b, s), jnp.int32, mesh, ("batch", "seq"))
    else:
        inputs = _sds((b, s, cfg.d_input), jnp.bfloat16, mesh,
                      ("batch", "seq", None))
    labels = _sds((b, s), jnp.int32, mesh, ("batch", "seq"))
    return {"inputs": inputs, "labels": labels}


def params_specs(cfg, mesh: Mesh, dtype=None, rules=None):
    shapes = jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg))
    if dtype is not None:  # serving runs on cast weights (bf16)
        shapes = jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(
                l.shape, dtype if l.dtype == jnp.float32 else l.dtype),
            shapes)
    return _tree_sds(shapes, param_axes(cfg), mesh, rules)


def train_state_specs(cfg, mesh: Mesh, with_lc: bool = True):
    shapes = jax.eval_shape(
        lambda: init_train_state(jax.random.PRNGKey(0), cfg,
                                 with_lc=with_lc))
    axes = param_axes(cfg)
    state_axes = {
        "params": axes,
        "opt": {"m": axes, "v": axes, "step": ()},
        "step": (),
    }
    if with_lc:
        paths = lc_param_paths(shapes["params"])
        from repro.core.tasks import get_path
        ref_axes = {p: tuple(get_path(axes, p)) for p in paths}
        state_axes["lc"] = {"a": ref_axes, "lam": ref_axes, "mu": ()}
    return _tree_sds(shapes, state_axes, mesh)


def quantize_params_sds(params_sds, mesh: Mesh, cfg, k: int = 16):
    """Replace every matrix leaf with the LC-quantized serving pack:
    {"idx": uint8 (same shape/sharding), "cb": f32 codebook, replicated}.
    Leaves inside scanned layer stacks get per-layer codebooks with a
    leading stack dim (so lax.scan slices them with the layer)."""
    from jax.sharding import PartitionSpec
    from repro.core.tasks import flatten_params, get_path, set_path
    rep = NamedSharding(mesh, P())
    axes_flat = flatten_params(param_axes(cfg))
    out = params_sds
    for path, leaf in flatten_params(params_sds).items():
        names = tuple(axes_flat[path])
        stacked = bool(names) and names[0] == "layers"
        logical_ndim = getattr(leaf, "ndim", 0) - (1 if stacked else 0)
        if "experts" in names or path.endswith("/router"):
            # MoE leaves cross the shard_map boundary whose in_specs are
            # array-shaped; routed-expert packs need the grouped
            # quant_matmul kernel inside the dispatch — served dense
            continue
        if logical_ndim >= 2 and leaf.dtype in (jnp.float32,
                                                jnp.bfloat16):
            cb_shape = (leaf.shape[0], k) if stacked else (k,)
            cb_shard = NamedSharding(mesh, PartitionSpec(
                *([None] * len(cb_shape))))
            out = set_path(out, path, {
                "idx": jax.ShapeDtypeStruct(leaf.shape, jnp.uint8,
                                            sharding=leaf.sharding),
                "cb": jax.ShapeDtypeStruct(cb_shape, jnp.float32,
                                           sharding=cb_shard)})
    return out


def quantized_weight_bytes_per_chip(params_sds) -> float:
    """Per-chip HBM read of the quantized weights (uint8 indices) —
    the analytic boundary I/O of the fused quant_matmul kernel."""
    import numpy as np
    total = 0.0
    for leaf in jax.tree_util.tree_leaves(params_sds):
        if leaf.dtype == jnp.uint8:
            shard = leaf.sharding.shard_shape(leaf.shape)
            total += float(np.prod(shard))  # 1 byte/elem
    return total


def decode_specs(cfg, shape_cfg, mesh: Mesh, quantized: bool = False):
    """(params, cache, inputs, pos) stand-ins for serve_step.

    ``seq_len`` is the KV-cache length (context already processed);
    the step decodes one new token for every sequence in the batch."""
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    # serving: TP-only weight sharding (no FSDP re-gather per token)
    params = params_specs(cfg, mesh, dtype=jnp.bfloat16,
                          rules=SERVE_RULES)
    if quantized:
        params = quantize_params_sds(params, mesh, cfg)
    cache_shapes = jax.eval_shape(
        lambda: init_cache(cfg, b, s, jnp.bfloat16))
    cache = _tree_sds(cache_shapes, cache_axes(cfg), mesh)
    if cfg.input_mode == "tokens":
        inputs = _sds((b, 1), jnp.int32, mesh, ("batch", None))
    else:
        inputs = _sds((b, 1, cfg.d_input), jnp.bfloat16, mesh,
                      ("batch", None, None))
    pos = jax.ShapeDtypeStruct((), jnp.int32,
                               sharding=NamedSharding(mesh, P()))
    return params, cache, inputs, pos


def prefill_specs(cfg, shape_cfg, mesh: Mesh):
    b, s = shape_cfg.global_batch, shape_cfg.seq_len
    params = params_specs(cfg, mesh, dtype=jnp.bfloat16,
                          rules=SERVE_RULES)
    if cfg.input_mode == "tokens":
        inputs = _sds((b, s), jnp.int32, mesh, ("batch", "seq"))
    else:
        inputs = _sds((b, s, cfg.d_input), jnp.bfloat16, mesh,
                      ("batch", "seq", None))
    return params, inputs
