"""Production mesh construction.

A function, not a module-level constant, so importing this module never
touches jax device state (the dry-run sets XLA_FLAGS before first init).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16×16 = 256 chips per pod; 2 pods = 512 chips multi-pod.

    The "pod" axis is an outer data-parallel axis: batch shards over
    ("pod", "data"), so the only cross-pod (DCN) traffic is the gradient
    all-reduce — see distributed/sharding.py DEFAULT_RULES.
    """
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_debug_mesh(shape=(1, 1), axes=("data", "model")):
    """Tiny mesh for CPU tests/examples (1 device)."""
    return jax.make_mesh(shape, axes)


def make_cstep_mesh(n_data: int | None = None):
    """Data-only mesh for the sharded grouped C step.

    The C step's packed item axes shard over "data"
    (``distributed/sharding.py`` rule ``"items"``), so a bench or test
    that only exercises the C step wants every local device on that
    axis. Defaults to all visible devices; on a forced-host-device CPU
    (``XLA_FLAGS=--xla_force_host_platform_device_count=8``) that is the
    8 fake devices, on a real single-device CPU it degrades to (1, 1)
    and the sharded path becomes an annotated no-op.
    """
    n = n_data if n_data is not None else len(jax.devices())
    return jax.make_mesh((n, 1), ("data", "model"))
