import os
os.environ["XLA_FLAGS"] = os.environ.get(
    "XLA_FLAGS", "") + " --xla_force_host_platform_device_count=512"

# NOTE: no `from __future__ import annotations` here — the XLA_FLAGS export
# above must stay the very first statements, before any jax import.
"""Multi-pod dry-run: lower + compile every (arch × shape × mesh) cell.

For each cell:
    with mesh:
        lowered  = jax.jit(step, in_shardings=…).lower(**input_specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits
        print(compiled.cost_analysis())     # FLOPs/bytes for §Roofline

Results (roofline terms, memory, collective schedule) are cached
incrementally to results/dryrun/<cell>.json so the full matrix is
restartable; EXPERIMENTS.md §Dry-run/§Roofline are generated from these.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch mixtral-8x7b \
        --shape train_4k [--multi-pod] [--all] [--out results/dryrun]
"""
import argparse
import json
import time
import traceback

import jax
import numpy as np

from repro.analysis import roofline as rl
from repro.configs import ARCHS, SHAPES, get_config
from repro.distributed.sharding import use_mesh
from repro.launch import inputs as specs_mod
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import (
    make_prefill_step, make_serve_step, make_train_step)


def cell_applicable(cfg, shape_cfg) -> tuple[bool, str]:
    if shape_cfg.name == "long_500k" and not cfg.subquadratic:
        return False, ("skip: pure full-attention arch has no sub-quadratic "
                       "mechanism (DESIGN.md §Arch-applicability)")
    return True, ""


def run_cell(arch: str, shape: str, multi_pod: bool,
             extra_cfg: dict | None = None,
             variant: str = "") -> dict:
    """``variant``: ""(paper-faithful baseline) | "fused_attn" |
    "quant_serve" | "fused_attn+quant_serve" — the beyond-paper
    optimizations measured in EXPERIMENTS.md §Perf."""
    cfg = get_config(arch)
    if extra_cfg:
        cfg = cfg.with_(**extra_cfg)
    fused_attn = "fused_attn" in variant
    quant_serve = "quant_serve" in variant
    if fused_attn:
        cfg = cfg.with_(fused_attention=True)
    shape_cfg = SHAPES[shape]
    ok, why = cell_applicable(cfg, shape_cfg)
    mesh_name = "2x16x16" if multi_pod else "16x16"
    cell = {"arch": arch, "shape": shape, "mesh": mesh_name,
            "variant": variant}
    if not ok:
        return dict(cell, status="skipped", reason=why)

    mesh = make_production_mesh(multi_pod=multi_pod)
    chips = int(np.prod(mesh.devices.shape))
    skip_scopes = []
    extra_bytes = 0.0
    if fused_attn:
        skip_scopes.append("fused_flash_attention")
        extra_bytes += rl.fused_attention_bytes(cfg, shape_cfg, chips)
    t0 = time.time()
    try:
        with use_mesh(mesh):
            if shape_cfg.kind == "train":
                step = make_train_step(cfg)
                state = specs_mod.train_state_specs(cfg, mesh)
                batch = specs_mod.batch_specs(cfg, shape_cfg, mesh)
                with mesh:
                    # donate the train state: params/opt/LC buffers update
                    # in place (no output copies)
                    lowered = jax.jit(step, donate_argnums=(0,)).lower(
                        state, batch)
                mf = rl.model_flops_train(
                    cfg, shape_cfg.global_batch * shape_cfg.seq_len)
            elif shape_cfg.kind == "decode":
                step = make_serve_step(cfg)
                args = specs_mod.decode_specs(cfg, shape_cfg, mesh,
                                              quantized=quant_serve)
                if quant_serve:
                    skip_scopes.append("fused_quant_matmul")
                    extra_bytes += \
                        specs_mod.quantized_weight_bytes_per_chip(args[0])
                with mesh:
                    # donate the KV cache: in-place ring/linear updates
                    lowered = jax.jit(step, donate_argnums=(1,)).lower(
                        *args)
                mf = rl.model_flops_decode(
                    cfg, shape_cfg.global_batch, shape_cfg.seq_len)
            else:  # prefill
                step = make_prefill_step(cfg)
                args = specs_mod.prefill_specs(cfg, shape_cfg, mesh)
                with mesh:
                    lowered = jax.jit(step).lower(*args)
                mf = rl.model_flops_prefill(
                    cfg, shape_cfg.global_batch * shape_cfg.seq_len)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower

        print(compiled.memory_analysis())
        ca = compiled.cost_analysis() or {}
        if isinstance(ca, (list, tuple)):  # jax ≤ 0.4.x: one dict per module
            ca = ca[0] if ca else {}
        print({k: v for k, v in ca.items()
               if k in ("flops", "bytes accessed")})
        terms = rl.analyze(compiled, arch=arch, shape=shape,
                           mesh_name=mesh_name, chips=chips,
                           model_flops=mf, skip_scopes=tuple(skip_scopes),
                           extra_bytes_per_chip=extra_bytes)
        row = terms.row()
        row.update(status="ok", t_lower_s=t_lower, t_compile_s=t_compile,
                   extra_bytes_per_chip=extra_bytes)
        return dict(cell, **row)
    except Exception as e:  # a failing cell is a bug in our sharding
        return dict(cell, status="error", error=f"{type(e).__name__}: {e}",
                    traceback=traceback.format_exc()[-4000:])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None, choices=ARCHS + [None])
    ap.add_argument("--shape", default=None, choices=list(SHAPES) + [None])
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--all", action="store_true",
                    help="run the full arch × shape matrix")
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--variant", default="",
                    help="fused_attn | quant_serve | "
                         "fused_attn+quant_serve")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    archs = ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = [False, True] if (args.both_meshes or args.all) \
        else [args.multi_pod]

    n_fail = 0
    for arch in archs:
        for shape in shapes:
            for mp in meshes:
                mesh_name = "2x16x16" if mp else "16x16"
                suffix = f"__{args.variant}" if args.variant else ""
                path = os.path.join(
                    args.out,
                    f"{arch}__{shape}__{mesh_name}{suffix}.json")
                if os.path.exists(path) and not args.force:
                    with open(path) as f:
                        prev = json.load(f)
                    if prev.get("status") in ("ok", "skipped"):
                        print(f"[cached] {arch} {shape} {mesh_name}: "
                              f"{prev['status']}")
                        continue
                t0 = time.time()
                res = run_cell(arch, shape, mp, variant=args.variant)
                res["wall_s"] = time.time() - t0
                with open(path, "w") as f:
                    json.dump(res, f, indent=2, default=str)
                status = res["status"]
                extra = ""
                if status == "ok":
                    extra = (f"bottleneck={res['bottleneck']} "
                             f"t=({res['t_compute_s']:.3e},"
                             f"{res['t_memory_s']:.3e},"
                             f"{res['t_collective_s']:.3e})s")
                elif status == "error":
                    extra = res["error"][:200]
                    n_fail += 1
                print(f"[{status}] {arch} {shape} {mesh_name} "
                      f"({res['wall_s']:.0f}s) {extra}", flush=True)
    raise SystemExit(1 if n_fail else 0)


if __name__ == "__main__":
    main()
