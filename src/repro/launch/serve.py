"""CLI serving driver: batched generation on dense or LC-compressed
weights.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m \
        --reduced --batch 4 --prompt-len 32 --gen 16 --quantize
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import ARCHS, get_config, reduced_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import lc_param_paths
from repro.models.transformer import init_params
from repro.runtime.server import (
    Server, quantize_params_for_serving, serving_bits)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quantize", action="store_true",
                    help="serve the LC-quantized model (k=16 codebooks)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    assert cfg.input_mode == "tokens", "serve CLI expects a token model"

    key = jax.random.PRNGKey(0)
    params = init_params(key, cfg)
    if args.quantize:
        paths = lc_param_paths(params)
        packed, params = quantize_params_for_serving(params, paths)
        comp, dense = serving_bits(packed)
        print(f"quantized {len(paths)} matrices: "
              f"{dense / 8e6:.1f} MB → {comp / 8e6:.1f} MB "
              f"({dense / comp:.1f}× smaller)")

    mesh = make_debug_mesh()
    server = Server(cfg, params, mesh=mesh,
                    max_len=args.prompt_len + args.gen)
    prompts = jax.random.randint(
        key, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)
    t0 = time.time()
    res = server.generate(prompts, args.gen)
    dt = time.time() - t0
    print(f"generated {res.tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", res.tokens[0][:16])


if __name__ == "__main__":
    main()
