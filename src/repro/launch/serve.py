"""CLI serving driver: batched or continuous-batching generation on
dense or LC-compressed weights.

    PYTHONPATH=src python -m repro.launch.serve --arch xlstm-125m \
        --reduced --batch 4 --prompt-len 32 --gen 16 --quantize

    PYTHONPATH=src python -m repro.launch.serve --arch phi3-mini-3.8b \
        --reduced --engine --form quant4 --slots 4 --requests 12
"""
from __future__ import annotations

import argparse
import re
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCHS, get_config, reduced_config
from repro.launch.mesh import make_debug_mesh
from repro.launch.steps import lc_param_paths
from repro.models.transformer import init_params
from repro.runtime.server import (
    Request, Server, ServingEngine, load_compressed_for_serving,
    quantize_params_for_serving, serving_bits)

FORMS = ("dense", "quant4", "quant8", "lowrank", "sparse")


def compress_for_form(cfg, params, form: str):
    """Bridge the model's FFN matrices into one serving form via a real
    LC state (direct compression init)."""
    from repro.core import AsIs, AsVector, CompressionTask, LCAlgorithm
    from repro.core.schemes import (
        AdaptiveQuantization, ConstraintL0Pruning, LowRank)
    from repro.core.tasks import get_path

    paths = [p for p in lc_param_paths(params)
             if get_path(params, p).ndim == 2]
    assert paths, "no 2-D compressible matrices (use --reduced?)"
    pattern = "|".join(f"^{re.escape(p)}$" for p in paths)
    if form == "quant4":
        task = CompressionTask("q", pattern, AsVector(),
                               AdaptiveQuantization(k=16))
        bits = 4
    elif form == "quant8":
        task = CompressionTask("q", pattern, AsVector(),
                               AdaptiveQuantization(k=64))
        bits = 8
    elif form == "lowrank":
        rank = max(cfg.d_model // 8, 2)
        task = CompressionTask("lr", pattern, AsIs(), LowRank(rank))
        bits = 4
    else:  # sparse
        total = sum(get_path(params, p).size for p in paths)
        task = CompressionTask("pr", pattern, AsVector(),
                               ConstraintL0Pruning(kappa=total // 10))
        bits = 4
    algo = LCAlgorithm([task], [1e-4])
    state = algo.init(params)
    serving, report = load_compressed_for_serving(params, state,
                                                  algo.tasks, bits=bits)
    n = sum(len(f) for f in report.values())
    kinds = sorted({v.split("(")[0] for f in report.values()
                    for v in f.values()})
    print(f"bridged {n} matrices to {form}: forms={kinds}")
    return serving


def run_engine(cfg, params, args):
    from repro.runtime import compressed as cforms

    engine = ServingEngine(cfg, params, slots=args.slots,
                           max_len=args.prompt_len + args.gen,
                           prefill_chunk=8)
    rng = np.random.default_rng(0)
    t, reqs = 0.0, []
    for i in range(args.requests):
        t += float(rng.exponential(0.02))
        reqs.append(Request(
            id=i,
            prompt=rng.integers(1, cfg.vocab_size,
                                size=int(rng.integers(
                                    4, args.prompt_len + 1)))
            .astype(np.int32),
            max_new=int(rng.integers(2, args.gen + 1)), arrival=t))
    out = engine.run(reqs)
    s = out["stats"]
    print(f"served {s['requests']} requests, {s['tokens']} tokens: "
          f"{s['tokens_per_sec']:.1f} tok/s, "
          f"p50={s['p50_latency_s'] * 1e3:.0f}ms "
          f"p99={s['p99_latency_s'] * 1e3:.0f}ms, "
          f"retraces={ {k: v - 1 for k, v in engine.trace_counts.items()} }")
    print(f"modeled decode HBM/step: "
          f"{cforms.tree_weight_bytes(params)} B")


def run_batch(cfg, params, args, mesh):
    server = Server(cfg, params, mesh=mesh,
                    max_len=args.prompt_len + args.gen)
    prompts = jax.random.randint(
        jax.random.PRNGKey(0), (args.batch, args.prompt_len), 0,
        cfg.vocab_size, jnp.int32)
    t0 = time.perf_counter()
    res = server.generate(prompts, args.gen)
    dt = time.perf_counter() - t0
    print(f"generated {res.tokens.shape} in {dt:.2f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s)")
    print("sample:", res.tokens[0][:16])


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="xlstm-125m", choices=ARCHS)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--quantize", action="store_true",
                    help="legacy: re-k-means quantize then serve the "
                         "dequantized weights")
    ap.add_argument("--form", default="dense", choices=FORMS,
                    help="serve weights in this compressed form "
                         "(bridged from an LC direct-compression state)")
    ap.add_argument("--engine", action="store_true",
                    help="continuous batching over a synthetic Poisson "
                         "trace instead of one equal-length batch")
    ap.add_argument("--slots", type=int, default=4,
                    help="engine decode slots")
    ap.add_argument("--requests", type=int, default=12,
                    help="engine trace length")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.reduced:
        cfg = reduced_config(cfg)
    assert cfg.input_mode == "tokens", "serve CLI expects a token model"
    if args.form != "dense":
        # compressed forms need per-layer (non-stacked) 2-D leaves
        cfg = cfg.with_(pattern_reps=1)

    params = init_params(jax.random.PRNGKey(0), cfg)
    if args.quantize:
        paths = lc_param_paths(params)
        packed, params = quantize_params_for_serving(params, paths)
        comp, dense = serving_bits(packed)
        print(f"quantized {len(paths)} matrices: "
              f"{dense / 8e6:.1f} MB → {comp / 8e6:.1f} MB "
              f"({dense / comp:.1f}× smaller)")
    elif args.form != "dense":
        params = compress_for_form(cfg, params, args.form)

    if args.engine:
        run_engine(cfg, params, args)
    else:
        run_batch(cfg, params, args, make_debug_mesh())


if __name__ == "__main__":
    main()
