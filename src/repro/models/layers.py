"""Shared layers: RMSNorm, RoPE, SwiGLU FFN, embeddings, inits.

Pure functional style: params are nested dicts of jnp arrays; every
forward takes (params, x, cfg) and is shape-polymorphic over batch/seq.
Master params are fp32; compute casts to ``cfg.dtype``.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def cdtype(cfg):
    return jnp.dtype(cfg.dtype)


# Serving weight forms (runtime/compressed.py) register here on import:
# {leaf type: (apply_fn(x, leaf, dt) -> y, load_fn(leaf, dt) -> dense)}.
# The registry lives in layers — not runtime — so models never import
# runtime (which imports models back).
_WEIGHT_FORMS: dict[type, tuple] = {}


def register_weight_form(cls, apply_fn, load_fn) -> None:
    """Register a compressed weight-form leaf class. ``apply_fn`` runs
    x @ W in streaming/compressed form; ``load_fn`` materializes the
    dense matrix (embed lookups, parity checks)."""
    _WEIGHT_FORMS[cls] = (apply_fn, load_fn)


def wload(leaf, dt):
    """Load a weight for compute: dense array, a registered compressed
    weight form (materialized), or an LC-quantized pack
    {"idx": uint8 codebook indices, "cb": (K,) f32 codebook}.

    The quantized path is the paper's compressed-serving deployment —
    on TPU it runs through kernels/quant_matmul (dequant fused in VMEM;
    only uint8 indices touch HBM). The jax.named_scope tag lets the
    dry-run account it as that fused kernel."""
    form = _WEIGHT_FORMS.get(type(leaf))
    if form is not None:
        return form[1](leaf, dt)
    if isinstance(leaf, dict) and "idx" in leaf:
        with jax.named_scope("fused_quant_matmul"):
            return leaf["cb"][leaf["idx"].astype(jnp.int32)].astype(dt)
    return leaf.astype(dt)


def apply_w(x, leaf, dt):
    """x @ W for a param-tree weight leaf, dispatched by form.

    Dense leaves (and legacy quantized dicts) take exactly the
    pre-existing ``x @ wload(leaf, dt)`` path — training math is
    bit-identical. Registered compressed forms (4-bit quantized,
    low-rank factored, pruned-sparse) run their streaming kernel
    without materializing W."""
    form = _WEIGHT_FORMS.get(type(leaf))
    if form is not None:
        return form[0](x, leaf, dt)
    return x @ wload(leaf, dt)


def dense_init(key, shape, in_axis: int = 0) -> jnp.ndarray:
    """Scaled-normal init, std = 1/sqrt(fan_in)."""
    fan_in = shape[in_axis] if in_axis >= 0 else int(np.prod(shape[:-1]))
    std = 1.0 / np.sqrt(max(fan_in, 1))
    return jax.random.normal(key, shape, jnp.float32) * std


def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float) -> jnp.ndarray:
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + scale.astype(jnp.float32))).astype(dt)


# ----------------------------------------------------------------------
# Rotary position embeddings
# ----------------------------------------------------------------------
def rope_frequencies(dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, dim, 2, jnp.float32) / dim))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray,
               theta: float) -> jnp.ndarray:
    """x: (..., S, H, D) with D even; positions: (..., S)."""
    d = x.shape[-1]
    freqs = rope_frequencies(d, theta)                       # (D/2,)
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # (...,S,D/2)
    cos = jnp.cos(angles)[..., :, None, :]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], -1)
    return out.astype(x.dtype)


# ----------------------------------------------------------------------
# SwiGLU dense FFN
# ----------------------------------------------------------------------
def init_dense_ffn(key, d_model: int, d_ff: int) -> dict:
    k1, k2, k3 = jax.random.split(key, 3)
    return {
        "w_gate": dense_init(k1, (d_model, d_ff)),
        "w_up": dense_init(k2, (d_model, d_ff)),
        "w_down": dense_init(k3, (d_ff, d_model)),
    }


def dense_ffn(params: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    dt = cdtype(cfg)
    g = apply_w(x, params["w_gate"], dt)
    u = apply_w(x, params["w_up"], dt)
    return apply_w(jax.nn.silu(g) * u, params["w_down"], dt)


# ----------------------------------------------------------------------
# Embedding / unembedding
# ----------------------------------------------------------------------
def init_embed(key, cfg) -> dict:
    if cfg.input_mode == "tokens":
        # std 1/√d so that (×√d at lookup) hidden inputs are unit-scale and
        # tied-unembed logits stay O(√d) at init
        p = {"tokens": jax.random.normal(
            key, (cfg.vocab_size, cfg.d_model), jnp.float32)
            / np.sqrt(cfg.d_model)}
    else:
        # stub modality frontend: a linear projection of precomputed
        # patch/frame embeddings (input_specs supplies the embeddings)
        p = {"proj": dense_init(key, (cfg.d_input, cfg.d_model))}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(
            jax.random.fold_in(key, 1), (cfg.d_model, cfg.vocab_size))
    return p


def embed(params: dict, inputs: jnp.ndarray, cfg) -> jnp.ndarray:
    dt = cdtype(cfg)
    if cfg.input_mode == "tokens":
        x = wload(params["tokens"], dt)[inputs]
        return x * jnp.asarray(np.sqrt(cfg.d_model), dt)
    return apply_w(inputs.astype(dt), params["proj"], dt)


def unembed(params: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    dt = cdtype(cfg)
    if cfg.tie_embeddings and cfg.input_mode == "tokens":
        return x @ wload(params["tokens"], dt).T
    return apply_w(x, params["unembed"], dt)


def cross_entropy(logits: jnp.ndarray, labels: jnp.ndarray,
                  mask: jnp.ndarray | None = None) -> jnp.ndarray:
    """Mean token cross-entropy in fp32."""
    logits = logits.astype(jnp.float32)
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(
        logits, labels[..., None], axis=-1).squeeze(-1)
    nll = lse - gold
    if mask is not None:
        return jnp.sum(nll * mask) / jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.mean(nll)
