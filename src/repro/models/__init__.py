from repro.models.transformer import (
    init_params, param_axes, forward_hidden, loss_fn, chunked_ce_loss,
    decode_step, init_cache, cache_axes, prefill, count_params,
    plan_stages)

__all__ = [
    "init_params", "param_axes", "forward_hidden", "loss_fn",
    "chunked_ce_loss", "decode_step", "init_cache", "cache_axes",
    "prefill", "count_params", "plan_stages",
]
