"""Mixture-of-Experts FFN with shard_map dispatch.

Routing is computed redundantly on every model-parallel column (router
weights are replicated; tokens are sharded over the data axes), then each
device packs the tokens assigned to *its* experts into a fixed-capacity
(E_local, C, d) buffer via a sort-free rank trick (argsort by expert +
searchsorted positions), runs the expert GEMMs locally, and scatter-adds
gated results back — the only cross-device traffic is the final psum over
the "model" axis, i.e. exactly the all-reduce a dense TP FFN would pay.
No all-to-all, no (T, E, C) GShard dispatch tensor.

Two static strategies, picked by divisibility:
* "ep": n_experts % model_size == 0 → experts sharded over "model"
  (deepseek-moe 64/16, jamba 16/16).
* "tp": otherwise → every column holds all experts but only a 1/model
  slice of d_expert (mixtral 8 experts on a 16-way axis).

Both differentiate cleanly (gather/scatter transposes; argsort indices
are constant wrt params).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import batch_axes, shard_map
from repro.models.layers import dense_init


def init_moe(key, cfg) -> dict:
    m = cfg.moe
    ks = jax.random.split(key, 7)
    e, d, fe = m.n_experts, cfg.d_model, m.d_expert
    p = {
        "router": dense_init(ks[0], (d, e)),
        "w_gate": jax.vmap(lambda k: dense_init(k, (d, fe)))(
            jax.random.split(ks[1], e)),
        "w_up": jax.vmap(lambda k: dense_init(k, (d, fe)))(
            jax.random.split(ks[2], e)),
        "w_down": jax.vmap(lambda k: dense_init(k, (fe, d)))(
            jax.random.split(ks[3], e)),
    }
    if m.n_shared > 0:
        fs = m.n_shared * fe
        p["sw_gate"] = dense_init(ks[4], (d, fs))
        p["sw_up"] = dense_init(ks[5], (d, fs))
        p["sw_down"] = dense_init(ks[6], (fs, d))
    return p


def moe_axes(cfg) -> dict:
    ax = {
        "router": ("embed", None),
        "w_gate": ("experts", "embed", "mlp"),
        "w_up": ("experts", "embed", "mlp"),
        "w_down": ("experts", "mlp", "embed"),
    }
    if cfg.moe.n_shared > 0:
        ax["sw_gate"] = ("embed", "mlp")
        ax["sw_up"] = ("embed", "mlp")
        ax["sw_down"] = ("mlp", "embed")
    return ax


def _dispatch_compute(x, gates, idx, wg, wu, wd, *, e0, e_local,
                      capacity: int, dtype):
    """Pack → expert GEMMs → gated combine, for experts [e0, e0+e_local).

    x: (T, d); gates/idx: (T, k); wg/wu: (eL, d, fe); wd: (eL, fe, d).
    """
    t, k = idx.shape
    d = x.shape[-1]
    c = capacity
    rel = idx.reshape(-1) - e0
    valid = (rel >= 0) & (rel < e_local)
    rel_c = jnp.where(valid, rel, e_local).astype(jnp.int32)
    order = jnp.argsort(rel_c, stable=True)
    sorted_rel = rel_c[order]
    first = jnp.searchsorted(sorted_rel, sorted_rel, side="left")
    pos = jnp.arange(t * k, dtype=jnp.int32) - first.astype(jnp.int32)
    tok = (order // k).astype(jnp.int32)
    gate_sorted = gates.reshape(-1)[order]
    keep = (sorted_rel < e_local) & (pos < c)
    slot = jnp.where(keep, sorted_rel * c + pos, e_local * c)

    buf_tok = jnp.zeros((e_local * c + 1,), jnp.int32).at[slot].set(tok)
    buf_gate = jnp.zeros((e_local * c + 1,), gates.dtype).at[slot].set(
        jnp.where(keep, gate_sorted, 0.0))
    buf_tok = buf_tok[:e_local * c]
    buf_gate = buf_gate[:e_local * c]

    xb = x[buf_tok].reshape(e_local, c, d)
    g = jnp.einsum("ecd,edf->ecf", xb, wg.astype(dtype))
    u = jnp.einsum("ecd,edf->ecf", xb, wu.astype(dtype))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("ecf,efd->ecd", h, wd.astype(dtype))
    y = (y.reshape(e_local * c, d)
         * buf_gate[:, None].astype(dtype))
    return jnp.zeros((t, d), dtype).at[buf_tok].add(y)


def _moe_local(x, router_w, wg, wu, wd, cfg, *, e0, e_local, capacity,
               model_axis=None, batch_ax=None):
    """Per-device MoE body (runs inside shard_map, or directly unsharded).

    x: (T, d) local tokens. Returns (y (T, d), aux scalar).
    """
    m = cfg.moe
    dtype = x.dtype
    logits = (x @ router_w.astype(dtype)).astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gates, idx = jax.lax.top_k(probs, m.top_k)
    gates = gates / jnp.sum(gates, axis=-1, keepdims=True)      # renorm
    gates = gates.astype(dtype)

    y = _dispatch_compute(x, gates, idx, wg, wu, wd, e0=e0,
                          e_local=e_local, capacity=capacity, dtype=dtype)

    # Switch-style load-balance loss: E · Σ_e f_e · P_e
    e = m.n_experts
    onehot = jax.nn.one_hot(idx, e, dtype=jnp.float32)          # (T,k,E)
    f_e = jnp.mean(jnp.sum(onehot, axis=1), axis=0)             # (E,)
    p_e = jnp.mean(probs, axis=0)
    if batch_ax:
        n = jax.lax.psum(1, batch_ax)
        f_e = jax.lax.psum(f_e, batch_ax) / n
        p_e = jax.lax.psum(p_e, batch_ax) / n
    aux = e * jnp.sum(f_e * p_e)

    if model_axis is not None:
        y = jax.lax.psum(y, model_axis)
    return y, aux


def moe_ffn(params, x, cfg, mesh):
    """x: (B, S, d_model) → (y, aux_loss). Routed experts + shared experts."""
    m = cfg.moe
    b, s, d = x.shape
    dtype = x.dtype

    if mesh is not None and "model" in mesh.axis_names \
            and np.prod(mesh.devices.shape) > 1:
        model_size = dict(zip(mesh.axis_names,
                              mesh.devices.shape))["model"]
        baxes = batch_axes(mesh)
        bsz = 1
        for ax, n in zip(mesh.axis_names, mesh.devices.shape):
            if ax in baxes:
                bsz *= n
        shard_batch = (b % bsz == 0) and bsz > 1
        strategy = "ep" if m.n_experts % model_size == 0 else "tp"
        e_local = m.n_experts // model_size if strategy == "ep" \
            else m.n_experts
        t_loc = (b // bsz if shard_batch else b) * s
        capacity = int(np.ceil(t_loc * m.top_k / m.n_experts
                               * m.capacity_factor))

        xs = P(baxes if shard_batch else None, None, None)
        if strategy == "ep":
            wspec = P("model", None, None)
        else:
            wspec = P(None, None, "model")
        wdspec = P("model", None, None) if strategy == "ep" \
            else P(None, "model", None)

        def mapped(x_blk, rw, wg, wu, wd):
            tb, ts, td = x_blk.shape
            e0 = jax.lax.axis_index("model") * e_local \
                if strategy == "ep" else 0
            y, aux = _moe_local(
                x_blk.reshape(tb * ts, td), rw, wg, wu, wd, cfg,
                e0=e0, e_local=e_local, capacity=capacity,
                model_axis="model",
                batch_ax=baxes if shard_batch else None)
            if not shard_batch and baxes:
                # tokens replicated over data axes: aux already equal
                pass
            return y.reshape(tb, ts, td), aux

        y, aux = shard_map(
            mapped, mesh=mesh,
            in_specs=(xs, P(None, None), wspec, wspec, wdspec),
            out_specs=(xs, P()), check_vma=False,
        )(x, params["router"], params["w_gate"], params["w_up"],
          params["w_down"])
    else:
        capacity = int(np.ceil(b * s * m.top_k / m.n_experts
                               * m.capacity_factor))
        y, aux = _moe_local(
            x.reshape(b * s, d), params["router"], params["w_gate"],
            params["w_up"], params["w_down"], cfg,
            e0=0, e_local=m.n_experts, capacity=capacity)
        y = y.reshape(b, s, d)

    if m.n_shared > 0:
        g = x @ params["sw_gate"].astype(dtype)
        u = x @ params["sw_up"].astype(dtype)
        y = y + (jax.nn.silu(g) * u) @ params["sw_down"].astype(dtype)
    return y, aux.astype(jnp.float32)
