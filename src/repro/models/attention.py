"""Attention mixers: GQA (full/sliding-window) and MLA, with blockwise
(FlashAttention-style online-softmax) training/prefill and 1-token decode
against full or ring-buffer KV caches.

Memory discipline: the (S, S) logit matrix is never materialized — the
blockwise path scans q-chunks × kv-chunks keeping (m, l, acc) running
statistics, so peak attention memory is O(B·H·qc·kc) regardless of S.
This is what lets prefill_32k compile inside 16 GB/chip.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.layers import (apply_rope, apply_w, dense_init, rms_norm,
                                 wload)

NEG_INF = -1e30


# ======================================================================
# GQA / sliding-window attention
# ======================================================================
def init_attn(key, cfg) -> dict:
    ks = jax.random.split(key, 4)
    d, q, kv = cfg.d_model, cfg.q_dim, cfg.kv_dim
    return {
        "wq": dense_init(ks[0], (d, q)),
        "wk": dense_init(ks[1], (d, kv)),
        "wv": dense_init(ks[2], (d, kv)),
        "wo": dense_init(ks[3], (q, d)),
    }


def attn_axes(cfg) -> dict:
    return {
        "wq": ("embed", "heads_flat"),
        "wk": ("embed", "kv_flat"),
        "wv": ("embed", "kv_flat"),
        "wo": ("heads_flat", "embed"),
    }


def read_layer_cache(cache: dict, layer_idx) -> dict:
    """Slice one layer's state out of a layer-stacked cache dict."""
    return {k: jax.lax.dynamic_index_in_dim(v, layer_idx, 0,
                                            keepdims=False)
            for k, v in cache.items()}


def write_layer_cache(cache: dict, new: dict, layer_idx) -> dict:
    """Write one layer's (full) state back into the stacked buffer."""
    out = {}
    zero = jnp.int32(0)
    for k, v in cache.items():
        idx = (layer_idx,) + (zero,) * (v.ndim - 1)
        out[k] = jax.lax.dynamic_update_slice(
            v, new[k][None].astype(v.dtype), idx)
    return out


def _mask(q_pos, k_pos, window: int):
    """Causal (+ sliding-window) mask: True = attend."""
    ok = k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        ok &= k_pos[None, :] > q_pos[:, None] - window
    return ok


def blockwise_attention(q, k, v, q_positions, k_positions, *,
                        window: int = 0, q_chunk: int = 1024,
                        kv_chunk: int = 1024, scale: float | None = None,
                        fused: bool = False):
    """Online-softmax attention.

    q: (B, Sq, H, D); k, v: (B, Sk, KV, D); positions: (Sq,), (Sk,).
    Returns (B, Sq, H, D). Causal by construction of the position mask.

    ``fused=True`` tags the computation as the fused flash-attention
    Pallas kernel (kernels/flash_attention — same math, VMEM-resident
    tiles) for the dry-run's fused-kernel byte accounting.
    """
    if fused:
        with jax.named_scope("fused_flash_attention"):
            return blockwise_attention(
                q, k, v, q_positions, k_positions, window=window,
                q_chunk=q_chunk, kv_chunk=kv_chunk, scale=scale,
                fused=False)
    b, sq, h, d = q.shape
    sk, kv = k.shape[1], k.shape[2]
    dv = v.shape[-1]                      # may differ from d (MLA)
    g = h // kv
    scale = scale if scale is not None else 1.0 / np.sqrt(d)
    qc = min(q_chunk, sq)
    kc = min(kv_chunk, sk)
    assert sq % qc == 0 and sk % kc == 0, (sq, qc, sk, kc)
    nq, nk = sq // qc, sk // kc

    # scan axes lead: (nq, B, qc, ...) / (nk, B, kc, ...)
    qr = jnp.moveaxis(q.reshape(b, nq, qc, kv, g, d), 1, 0)
    kr = jnp.moveaxis(k.reshape(b, nk, kc, kv, d), 1, 0)
    vr = jnp.moveaxis(v.reshape(b, nk, kc, kv, dv), 1, 0)
    qp = q_positions.reshape(nq, qc)
    kp = k_positions.reshape(nk, kc)

    def q_chunk_body(_, qi):
        q_i, qp_i = qi                       # (B,qc,KV,G,D), (qc,)
        m0 = jnp.full((b, kv, g, qc), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, qc), jnp.float32)
        a0 = jnp.zeros((b, kv, g, qc, dv), jnp.float32)

        def kv_chunk_body(carry, kj):
            m, l, acc = carry
            k_j, v_j, kp_j = kj
            s = jnp.einsum("bqkgd,bckd->bkgqc", q_i, k_j,
                           preferred_element_type=jnp.float32) * scale
            mask = _mask(qp_i, kp_j, window)              # (qc, kc)
            s = jnp.where(mask[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, jnp.max(s, axis=-1))
            p = jnp.exp(s - m_new[..., None])
            alpha = jnp.exp(m - m_new)
            l_new = l * alpha + jnp.sum(p, axis=-1)
            pv = jnp.einsum("bkgqc,bckd->bkgqd", p.astype(v_j.dtype), v_j,
                            preferred_element_type=jnp.float32)
            acc_new = acc * alpha[..., None] + pv
            return (m_new, l_new, acc_new), None

        (m, l, acc), _ = jax.lax.scan(
            kv_chunk_body, (m0, l0, a0), (kr, vr, kp))
        out = acc / jnp.maximum(l[..., None], 1e-30)       # (B,KV,G,qc,D)
        return None, out.astype(q.dtype)

    _, outs = jax.lax.scan(q_chunk_body, None, (qr, qp))   # (nq,B,KV,G,qc,Dv)
    out = jnp.moveaxis(outs, 0, 1)                          # (B,nq,KV,G,qc,Dv)
    out = jnp.moveaxis(out, 4, 2)                           # (B,nq,qc,KV,G,Dv)
    return out.reshape(b, sq, h, dv)


def attn_forward(params, x, cfg, spec, positions, return_cache=False):
    """Full-sequence attention (train / prefill). x: (B, S, d_model)."""
    b, s, _ = x.shape
    dt = x.dtype
    q = apply_w(x, params["wq"], dt).reshape(
        b, s, cfg.n_heads, cfg.head_dim)
    k = apply_w(x, params["wk"], dt).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim)
    v = apply_w(x, params["wv"], dt).reshape(
        b, s, cfg.n_kv_heads, cfg.head_dim)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    q = constrain(q, ("batch", "seq", "heads", None))
    k = constrain(k, ("batch", "seq", "kv_heads", None))
    out = blockwise_attention(
        q, k, v, positions, positions, window=spec.window,
        q_chunk=cfg.attn_chunk_q, kv_chunk=cfg.attn_chunk_kv,
        fused=cfg.fused_attention)
    out = constrain(out, ("batch", "seq", "heads", None))
    y = apply_w(out.reshape(b, s, cfg.q_dim), params["wo"], dt)
    if not return_cache:
        return y
    w = spec.window
    if w > 0 and s > w:  # ring-buffer layers keep the last window
        k, v = k[:, -w:], v[:, -w:]
    return y, {"k": k, "v": v}


# ----------------------------------------------------------------------
# Decode path (1 new token against a KV cache)
# ----------------------------------------------------------------------
def init_attn_cache(cfg, spec, batch: int, max_len: int, dtype) -> dict:
    """Full cache for global layers; ring buffer for windowed layers."""
    length = min(spec.window, max_len) if spec.window > 0 else max_len
    shape = (batch, length, cfg.n_kv_heads, cfg.head_dim)
    return {"k": jnp.zeros(shape, dtype), "v": jnp.zeros(shape, dtype)}


def attn_decode(params, x, cache, pos, cfg, spec, layer_idx=None):
    """x: (B, 1, d_model); pos: 0-based index of the new token — scalar
    int32 (whole batch in lockstep) or (B,) int32 (per-slot positions,
    continuous batching: every slot writes its own ring slot and masks
    its own validity range).

    ``layer_idx`` set ⇒ cache leaves are layer-stacked (L, B, len, KV, D)
    and this layer's update is a single token-sized dynamic-update-slice
    into the shared (donated) buffer — decode writes O(token), never
    O(cache). With layer_idx=None (unrolled stages) the per-layer cache
    is updated functionally as before.
    """
    b = x.shape[0]
    dt = x.dtype
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1
    q = apply_w(x, params["wq"], dt).reshape(
        b, 1, cfg.n_heads, cfg.head_dim)
    k = apply_w(x, params["wk"], dt).reshape(
        b, 1, cfg.n_kv_heads, cfg.head_dim)
    v = apply_w(x, params["wv"], dt).reshape(
        b, 1, cfg.n_kv_heads, cfg.head_dim)
    # (1,) broadcasts over batch; (B, 1) gives each slot its own angle
    pos_arr = pos[:, None] if per_slot else jnp.reshape(pos, (1,))
    q = apply_rope(q, pos_arr, cfg.rope_theta)
    k = apply_rope(k, pos_arr, cfg.rope_theta)

    stacked = layer_idx is not None
    k_buf, v_buf = cache["k"], cache["v"]
    length = k_buf.shape[2] if stacked else k_buf.shape[1]
    slot = jnp.where(spec.window > 0, pos % length,
                     jnp.minimum(pos, length - 1)).astype(jnp.int32)
    if per_slot:
        rows = jnp.arange(b)
        if stacked:
            k_buf = k_buf.at[layer_idx, rows, slot].set(
                k[:, 0].astype(k_buf.dtype))
            v_buf = v_buf.at[layer_idx, rows, slot].set(
                v[:, 0].astype(v_buf.dtype))
            with jax.named_scope("fused_flash_attention"
                                 if cfg.fused_attention else "cache_read"):
                k_cache = jax.lax.dynamic_index_in_dim(
                    k_buf, layer_idx, 0, keepdims=False)
                v_cache = jax.lax.dynamic_index_in_dim(
                    v_buf, layer_idx, 0, keepdims=False)
        else:
            k_cache = k_buf.at[rows, slot].set(k[:, 0].astype(k_buf.dtype))
            v_cache = v_buf.at[rows, slot].set(v[:, 0].astype(v_buf.dtype))
            k_buf, v_buf = k_cache, v_cache
    elif stacked:
        zero = jnp.int32(0)
        k_buf = jax.lax.dynamic_update_slice(
            k_buf, k[None].astype(k_buf.dtype),
            (layer_idx, zero, slot, zero, zero))
        v_buf = jax.lax.dynamic_update_slice(
            v_buf, v[None].astype(v_buf.dtype),
            (layer_idx, zero, slot, zero, zero))
        # the layer-cache read is part of the flash-decoding kernel's
        # streaming loop; keep it inside the fused scope
        with jax.named_scope("fused_flash_attention"
                             if cfg.fused_attention else "cache_read"):
            k_cache = jax.lax.dynamic_index_in_dim(
                k_buf, layer_idx, 0, keepdims=False)
            v_cache = jax.lax.dynamic_index_in_dim(
                v_buf, layer_idx, 0, keepdims=False)
    else:
        k_cache = jax.lax.dynamic_update_slice_in_dim(
            k_buf, k.astype(k_buf.dtype), slot, axis=1)
        v_cache = jax.lax.dynamic_update_slice_in_dim(
            v_buf, v.astype(v_buf.dtype), slot, axis=1)
        k_buf, v_buf = k_cache, v_cache

    kv, g = cfg.n_kv_heads, cfg.n_heads // cfg.n_kv_heads
    qh = q.reshape(b, kv, g, cfg.head_dim)

    def _core(qh, k_cache, v_cache):
        s = jnp.einsum("bkgd,bskd->bkgs", qh, k_cache,
                       preferred_element_type=jnp.float32)
        s = s / np.sqrt(cfg.head_dim)
        n_valid = jnp.minimum(pos + 1, length)      # () or (B,)
        n_valid = n_valid[:, None, None, None] if per_slot else n_valid
        valid = jnp.arange(length)[None, None, None, :] < n_valid
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(dt)
        return jnp.einsum("bkgs,bskd->bkgd", p, v_cache,
                          preferred_element_type=jnp.float32).astype(dt)

    if cfg.fused_attention:  # flash-decoding kernel accounting
        with jax.named_scope("fused_flash_attention"):
            out = _core(qh, k_cache, v_cache)
    else:
        out = _core(qh, k_cache, v_cache)
    out = out.reshape(b, 1, cfg.q_dim)
    return apply_w(out, params["wo"], dt), {"k": k_buf, "v": v_buf}


# ======================================================================
# Multi-head Latent Attention (MiniCPM3 / DeepSeek-V2 style)
# ======================================================================
def init_mla(key, cfg) -> dict:
    m = cfg.mla
    ks = jax.random.split(key, 5)
    h = cfg.n_heads
    qk_dim = m.qk_nope_dim + m.qk_rope_dim
    return {
        "wdq": dense_init(ks[0], (cfg.d_model, m.q_lora_rank)),
        "q_norm": jnp.zeros((m.q_lora_rank,), jnp.float32),
        "wuq": dense_init(ks[1], (m.q_lora_rank, h * qk_dim)),
        "wdkv": dense_init(ks[2], (cfg.d_model,
                                   m.kv_lora_rank + m.qk_rope_dim)),
        "kv_norm": jnp.zeros((m.kv_lora_rank,), jnp.float32),
        "wukv": dense_init(ks[3], (m.kv_lora_rank,
                                   h * (m.qk_nope_dim + m.v_head_dim))),
        "wo": dense_init(ks[4], (h * m.v_head_dim, cfg.d_model)),
    }


def mla_axes(cfg) -> dict:
    return {
        "wdq": ("embed", "lora"),
        "q_norm": ("lora",),
        "wuq": ("lora", "heads_flat"),
        "wdkv": ("embed", "lora"),
        "kv_norm": ("lora",),
        "wukv": ("lora", "heads_flat"),
        "wo": ("heads_flat", "embed"),
    }


def _mla_qkv(params, x, cfg, positions):
    """Shared q/k/v construction for the full-sequence MLA path."""
    m = cfg.mla
    b, s, _ = x.shape
    dt = x.dtype
    h = cfg.n_heads
    cq = rms_norm(apply_w(x, params["wdq"], dt), params["q_norm"],
                  cfg.norm_eps)
    q = apply_w(cq, params["wuq"], dt).reshape(
        b, s, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)

    ckv_full = apply_w(x, params["wdkv"], dt)
    ckv, k_rope = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    k_rope = apply_rope(k_rope[:, :, None, :], positions, cfg.rope_theta)
    ckv_n = rms_norm(ckv, params["kv_norm"], cfg.norm_eps)
    kv = (ckv_n @ wload(params["wukv"], dt)).reshape(
        b, s, h, m.qk_nope_dim + m.v_head_dim)
    k_nope, v = jnp.split(kv, [m.qk_nope_dim], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, h, m.qk_rope_dim))], -1)
    q = jnp.concatenate([q_nope, q_rope], -1)
    return q, k, v, ckv_n, k_rope


def mla_forward(params, x, cfg, spec, positions, return_cache=False):
    m = cfg.mla
    b, s, _ = x.shape
    dt = x.dtype
    q, k, v, ckv_n, k_rope = _mla_qkv(params, x, cfg, positions)
    scale = 1.0 / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
    out = blockwise_attention(
        q, k, v, positions, positions, window=spec.window,
        q_chunk=cfg.attn_chunk_q, kv_chunk=cfg.attn_chunk_kv, scale=scale,
        fused=cfg.fused_attention)
    out = out.reshape(b, s, cfg.n_heads * m.v_head_dim)
    y = apply_w(out, params["wo"], dt)
    if not return_cache:
        return y
    return y, {"ckv": ckv_n, "k_rope": k_rope[:, :, 0, :]}


def init_mla_cache(cfg, spec, batch: int, max_len: int, dtype) -> dict:
    m = cfg.mla
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dtype),
        "k_rope": jnp.zeros((batch, max_len, m.qk_rope_dim), dtype),
    }


def mla_decode(params, x, cache, pos, cfg, spec, layer_idx=None):
    """Absorbed-matrix MLA decode: attention runs in the latent space, so
    per-step work is O(S·(kv_lora+rope)) instead of O(S·H·qk_dim)."""
    m = cfg.mla
    b = x.shape[0]
    dt = x.dtype
    h = cfg.n_heads
    pos = jnp.asarray(pos, jnp.int32)
    per_slot = pos.ndim == 1            # (B,) continuous-batching positions
    pos_arr = pos[:, None] if per_slot else jnp.reshape(pos, (1,))
    stacked = layer_idx is not None

    cq = rms_norm(apply_w(x, params["wdq"], dt), params["q_norm"],
                  cfg.norm_eps)
    q = apply_w(cq, params["wuq"], dt).reshape(
        b, h, m.qk_nope_dim + m.qk_rope_dim)
    q_nope, q_rope = jnp.split(q, [m.qk_nope_dim], axis=-1)
    q_rope = apply_rope(q_rope[:, None], pos_arr,
                        cfg.rope_theta)[:, 0]            # (B,H,rope)

    ckv_full = apply_w(x, params["wdkv"], dt)[:, 0]      # (B, lora+rope)
    ckv_new, k_rope_new = jnp.split(ckv_full, [m.kv_lora_rank], axis=-1)
    ckv_new = rms_norm(ckv_new, params["kv_norm"], cfg.norm_eps)
    k_rope_new = apply_rope(k_rope_new[:, None, None, :], pos_arr,
                            cfg.rope_theta)[:, 0, 0]

    if per_slot:
        rows = jnp.arange(b)
        if stacked:
            ckv_buf = cache["ckv"].at[layer_idx, rows, pos].set(
                ckv_new.astype(cache["ckv"].dtype))
            kr_buf = cache["k_rope"].at[layer_idx, rows, pos].set(
                k_rope_new.astype(cache["k_rope"].dtype))
            with jax.named_scope("fused_flash_attention"
                                 if cfg.fused_attention else "cache_read"):
                ckv = jax.lax.dynamic_index_in_dim(ckv_buf, layer_idx, 0,
                                                   keepdims=False)
                k_rope = jax.lax.dynamic_index_in_dim(kr_buf, layer_idx, 0,
                                                      keepdims=False)
        else:
            ckv = cache["ckv"].at[rows, pos].set(
                ckv_new.astype(cache["ckv"].dtype))
            k_rope = cache["k_rope"].at[rows, pos].set(
                k_rope_new.astype(cache["k_rope"].dtype))
            ckv_buf, kr_buf = ckv, k_rope
    elif stacked:
        zero = jnp.int32(0)
        ckv_buf = jax.lax.dynamic_update_slice(
            cache["ckv"], ckv_new[None, :, None].astype(
                cache["ckv"].dtype), (layer_idx, zero, pos, zero))
        kr_buf = jax.lax.dynamic_update_slice(
            cache["k_rope"], k_rope_new[None, :, None].astype(
                cache["k_rope"].dtype), (layer_idx, zero, pos, zero))
        with jax.named_scope("fused_flash_attention"
                             if cfg.fused_attention else "cache_read"):
            ckv = jax.lax.dynamic_index_in_dim(ckv_buf, layer_idx, 0,
                                               keepdims=False)
            k_rope = jax.lax.dynamic_index_in_dim(kr_buf, layer_idx, 0,
                                                  keepdims=False)
    else:
        ckv = jax.lax.dynamic_update_slice_in_dim(
            cache["ckv"], ckv_new[:, None].astype(cache["ckv"].dtype),
            pos, axis=1)
        k_rope = jax.lax.dynamic_update_slice_in_dim(
            cache["k_rope"],
            k_rope_new[:, None].astype(cache["k_rope"].dtype),
            pos, axis=1)
        ckv_buf, kr_buf = ckv, k_rope

    # absorb W_uk into q: q_abs (B,H,lora)
    wukv = wload(params["wukv"], dt).reshape(
        m.kv_lora_rank, h, m.qk_nope_dim + m.v_head_dim)
    w_uk = wukv[..., :m.qk_nope_dim]                     # (lora,H,nope)
    w_uv = wukv[..., m.qk_nope_dim:]                     # (lora,H,v)
    q_abs = jnp.einsum("bhn,lhn->bhl", q_nope, w_uk)

    def _core(q_abs, q_rope, ckv, k_rope):
        s = (jnp.einsum("bhl,bsl->bhs", q_abs, ckv,
                        preferred_element_type=jnp.float32)
             + jnp.einsum("bhr,bsr->bhs", q_rope, k_rope,
                          preferred_element_type=jnp.float32))
        s = s / np.sqrt(m.qk_nope_dim + m.qk_rope_dim)
        p_cmp = pos[:, None, None] if per_slot else pos
        valid = jnp.arange(ckv.shape[1])[None, None, :] <= p_cmp
        s = jnp.where(valid, s, NEG_INF)
        p = jax.nn.softmax(s, axis=-1).astype(dt)
        return jnp.einsum("bhs,bsl->bhl", p, ckv)        # (B,H,lora)

    if cfg.fused_attention:
        with jax.named_scope("fused_flash_attention"):
            o_latent = _core(q_abs, q_rope, ckv, k_rope)
    else:
        o_latent = _core(q_abs, q_rope, ckv, k_rope)
    out = jnp.einsum("bhl,lhv->bhv", o_latent, w_uv)     # (B,H,v)
    out = out.reshape(b, 1, h * m.v_head_dim)
    return apply_w(out, params["wo"], dt), {"ckv": ckv_buf,
                                            "k_rope": kr_buf}
