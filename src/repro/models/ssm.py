"""Recurrent mixers: Mamba-1 (Jamba) and xLSTM (mLSTM + sLSTM).

Training paths are chunked so memory stays O(B·chunk·inner·state):
* Mamba: outer `lax.scan` over sequence chunks, inner associative scan,
  checkpointed per chunk.
* mLSTM: chunkwise-parallel form — intra-chunk quadratic (c×c) gate
  matrix + inter-chunk (C, n, m) running state with max-stabilization
  (the flash-attention-style combine of the xLSTM paper's appendix).
* sLSTM: inherently sequential (block-diagonal recurrence) — `lax.scan`
  over time, as the paper itself prescribes.

Decode paths are single-step recurrent updates; state size is
independent of context length (this is why xlstm/jamba run long_500k).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import constrain
from repro.models.attention import read_layer_cache, write_layer_cache
from repro.models.layers import apply_w, dense_init, rms_norm


# ======================================================================
# Mamba-1
# ======================================================================
def _mamba_dims(cfg):
    m = cfg.mamba
    d_inner = m.expand * cfg.d_model
    dt_rank = m.dt_rank or int(np.ceil(cfg.d_model / 16))
    return d_inner, dt_rank


def init_mamba(key, cfg) -> dict:
    m = cfg.mamba
    di, dtr = _mamba_dims(cfg)
    ks = jax.random.split(key, 6)
    a = jnp.tile(jnp.arange(1, m.d_state + 1, dtype=jnp.float32), (di, 1))
    return {
        "in_proj": dense_init(ks[0], (cfg.d_model, 2 * di)),
        "conv_w": dense_init(ks[1], (m.d_conv, di)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "x_proj": dense_init(ks[2], (di, dtr + 2 * m.d_state)),
        "dt_proj": dense_init(ks[3], (dtr, di)),
        "dt_bias": jnp.log(jnp.expm1(  # softplus⁻¹ of U(1e-3, 1e-1) mean
            jnp.full((di,), 0.01, jnp.float32))),
        "A_log": jnp.log(a),
        "D": jnp.ones((di,), jnp.float32),
        "out_proj": dense_init(ks[5], (di, cfg.d_model)),
    }


def mamba_axes(cfg) -> dict:
    return {
        "in_proj": ("embed", "inner"),
        "conv_w": ("conv", "inner"),
        "conv_b": ("inner",),
        "x_proj": ("inner", "lora"),
        "dt_proj": ("lora", "inner"),
        "dt_bias": ("inner",),
        "A_log": ("inner", "state"),
        "D": ("inner",),
        "out_proj": ("inner", "embed"),
    }


def _causal_conv(x, w, b, state=None):
    """Depthwise causal conv over seq. x: (B,S,C), w: (K,C).

    ``state``: (B, K-1, C) trailing inputs from the previous step (decode);
    returns (y, new_state).
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((x.shape[0], k - 1, x.shape[2]), x.dtype)
    else:
        pad = state.astype(x.dtype)
    xp = jnp.concatenate([pad, x], axis=1)          # (B, S+K-1, C)
    y = sum(xp[:, i:i + x.shape[1], :] * w[i][None, None, :]
            for i in range(k))
    new_state = xp[:, -(k - 1):, :]
    return y + b[None, None, :], new_state


def _selective_scan_chunk(h0, dA, dBx):
    """Associative scan within a chunk. dA, dBx: (B, c, di, ds)."""
    def combine(a, b):
        a1, b1 = a
        a2, b2 = b
        return a1 * a2, b1 * a2 + b2

    aa, bb = jax.lax.associative_scan(combine, (dA, dBx), axis=1)
    h = aa * h0[:, None] + bb                        # (B, c, di, ds)
    return h, h[:, -1]


def mamba_forward(params, x, cfg, spec, positions, chunk: int = 128,
                  return_cache=False):
    """x: (B, S, d_model) → (B, S, d_model)."""
    m = cfg.mamba
    di, dtr = _mamba_dims(cfg)
    b, s, _ = x.shape
    dt_ = x.dtype

    xz = apply_w(x, params["in_proj"], dt_)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi = constrain(xi, ("batch", "seq", "inner"))
    xi, conv_tail = _causal_conv(xi, params["conv_w"].astype(dt_),
                                 params["conv_b"].astype(dt_))
    xi = jax.nn.silu(xi)

    xdbl = apply_w(xi, params["x_proj"], dt_)
    dt_raw, b_ssm, c_ssm = jnp.split(xdbl, [dtr, dtr + m.d_state], axis=-1)
    dt = jax.nn.softplus(
        apply_w(dt_raw, params["dt_proj"], dt_)
        + params["dt_bias"].astype(dt_))             # (B,S,di)
    a = -jnp.exp(params["A_log"])                    # (di, ds) f32

    c = min(chunk, s)
    assert s % c == 0
    nc = s // c
    xi_c = xi.reshape(b, nc, c, di)
    dt_c = dt.reshape(b, nc, c, di).astype(jnp.float32)
    b_c = b_ssm.reshape(b, nc, c, m.d_state).astype(jnp.float32)
    c_c = c_ssm.reshape(b, nc, c, m.d_state)

    @jax.checkpoint
    def chunk_body(h, inp):
        xi_j, dt_j, b_j, c_j = inp                    # (B,c,·)
        da = jnp.exp(dt_j[..., None] * a[None, None])        # (B,c,di,ds)
        dbx = (dt_j * xi_j.astype(jnp.float32))[..., None] \
            * b_j[..., None, :]                              # (B,c,di,ds)
        hs, h_last = _selective_scan_chunk(h, da, dbx)
        y = jnp.einsum("bcds,bcs->bcd", hs, c_j.astype(jnp.float32))
        return h_last, y.astype(dt_)

    h0 = jnp.zeros((b, di, m.d_state), jnp.float32)
    h_last, ys = jax.lax.scan(
        chunk_body, h0,
        (jnp.moveaxis(xi_c, 1, 0), jnp.moveaxis(dt_c, 1, 0),
         jnp.moveaxis(b_c, 1, 0), jnp.moveaxis(c_c, 1, 0)))
    y = jnp.moveaxis(ys, 0, 1).reshape(b, s, di)
    y = y + xi * params["D"].astype(dt_)[None, None]
    y = y * jax.nn.silu(z)
    out = apply_w(y, params["out_proj"], dt_)
    if not return_cache:
        return out
    return out, {"conv": conv_tail, "ssm": h_last}


def init_mamba_cache(cfg, spec, batch: int, max_len: int, dtype) -> dict:
    m = cfg.mamba
    di, _ = _mamba_dims(cfg)
    return {
        "conv": jnp.zeros((batch, m.d_conv - 1, di), dtype),
        "ssm": jnp.zeros((batch, di, m.d_state), jnp.float32),
    }


def mamba_decode(params, x, cache, pos, cfg, spec, layer_idx=None):
    if layer_idx is not None:  # layer-stacked cache (scanned decode)
        local = read_layer_cache(cache, layer_idx)
        out, new_local = mamba_decode(params, x, local, pos, cfg, spec)
        return out, write_layer_cache(cache, new_local, layer_idx)
    """x: (B, 1, d_model) single-step recurrence."""
    m = cfg.mamba
    di, dtr = _mamba_dims(cfg)
    b = x.shape[0]
    dt_ = x.dtype

    xz = apply_w(x, params["in_proj"], dt_)
    xi, z = jnp.split(xz, 2, axis=-1)
    xi, conv_state = _causal_conv(
        xi, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_),
        state=cache["conv"])
    xi = jax.nn.silu(xi)[:, 0]                       # (B, di)

    xdbl = apply_w(xi, params["x_proj"], dt_)
    dt_raw, b_ssm, c_ssm = jnp.split(xdbl, [dtr, dtr + m.d_state], axis=-1)
    dt = jax.nn.softplus(
        apply_w(dt_raw, params["dt_proj"], dt_)
        + params["dt_bias"].astype(dt_)).astype(jnp.float32)  # (B,di)
    a = -jnp.exp(params["A_log"])
    da = jnp.exp(dt[..., None] * a[None])            # (B,di,ds)
    dbx = (dt * xi.astype(jnp.float32))[..., None] \
        * b_ssm.astype(jnp.float32)[:, None, :]
    h = cache["ssm"] * da + dbx
    y = jnp.einsum("bds,bs->bd", h, c_ssm.astype(jnp.float32)).astype(dt_)
    y = y + xi * params["D"].astype(dt_)[None]
    y = y * jax.nn.silu(z[:, 0])
    out = apply_w(y, params["out_proj"], dt_)[:, None]
    return out, {"conv": conv_state.astype(cache["conv"].dtype), "ssm": h}


# ======================================================================
# xLSTM — mLSTM (chunkwise-parallel) and sLSTM (sequential scan)
# ======================================================================
def _mlstm_dims(cfg):
    di = int(cfg.xlstm.proj_factor_m * cfg.d_model)
    dh = di // cfg.n_heads
    return di, dh


def init_mlstm(key, cfg) -> dict:
    di, _ = _mlstm_dims(cfg)
    x = cfg.xlstm
    ks = jax.random.split(key, 8)
    return {
        "up_proj": dense_init(ks[0], (cfg.d_model, 2 * di)),
        "conv_w": dense_init(ks[1], (x.conv_kernel, di)),
        "conv_b": jnp.zeros((di,), jnp.float32),
        "wq": dense_init(ks[2], (di, di)),
        "wk": dense_init(ks[3], (di, di)),
        "wv": dense_init(ks[4], (di, di)),
        "wi": dense_init(ks[5], (di, cfg.n_heads)),
        "wf": dense_init(ks[6], (di, cfg.n_heads)),
        "bi": jnp.zeros((cfg.n_heads,), jnp.float32),
        "bf": jnp.full((cfg.n_heads,), 3.0, jnp.float32),  # open f at init
        "out_norm": jnp.zeros((di,), jnp.float32),
        "down_proj": dense_init(ks[7], (di, cfg.d_model)),
    }


def mlstm_axes(cfg) -> dict:
    return {
        "up_proj": ("embed", "inner"), "conv_w": ("conv", "inner"),
        "conv_b": ("inner",), "wq": ("inner", "inner"),
        "wk": ("inner", "inner"), "wv": ("inner", "inner"),
        "wi": ("inner", "gates"), "wf": ("inner", "gates"),
        "bi": ("gates",), "bf": ("gates",), "out_norm": ("inner",),
        "down_proj": ("inner", "embed"),
    }


def _mlstm_gates(params, xc, b, s, h):
    li = apply_w(xc, params["wi"], xc.dtype).astype(jnp.float32) \
        + params["bi"]                                 # (B,S,H) log-i
    lf = jax.nn.log_sigmoid(
        apply_w(xc, params["wf"], xc.dtype).astype(jnp.float32)
        + params["bf"])                                # (B,S,H) log-f
    return li, lf


def mlstm_forward(params, x, cfg, spec, positions, return_cache=False):
    """Chunkwise-parallel mLSTM. x: (B,S,d) → (B,S,d)."""
    di, dh = _mlstm_dims(cfg)
    hn = cfg.n_heads
    b, s, _ = x.shape
    dt_ = x.dtype
    c = min(cfg.xlstm.chunk, s)
    assert s % c == 0
    nc = s // c

    xz = apply_w(x, params["up_proj"], dt_)
    xm, z = jnp.split(xz, 2, axis=-1)
    xc, conv_tail = _causal_conv(xm, params["conv_w"].astype(dt_),
                                 params["conv_b"].astype(dt_))
    xc = jax.nn.silu(xc)
    q = apply_w(xc, params["wq"], dt_).reshape(b, s, hn, dh)
    k = apply_w(xc, params["wk"], dt_).reshape(b, s, hn, dh) / np.sqrt(dh)
    v = apply_w(xm, params["wv"], dt_).reshape(b, s, hn, dh)
    li, lf = _mlstm_gates(params, xc, b, s, hn)

    # chunk views: (B, nc, c, ...) → scan over nc
    qc = jnp.moveaxis(q.reshape(b, nc, c, hn, dh), 1, 0)
    kc = jnp.moveaxis(k.reshape(b, nc, c, hn, dh), 1, 0)
    vc = jnp.moveaxis(v.reshape(b, nc, c, hn, dh), 1, 0)
    lic = jnp.moveaxis(li.reshape(b, nc, c, hn), 1, 0)
    lfc = jnp.moveaxis(lf.reshape(b, nc, c, hn), 1, 0)

    @jax.checkpoint
    def chunk_body(carry, inp):
        cbar, nbar, mbar = carry       # (B,H,dh,dh), (B,H,dh), (B,H)
        q_j, k_j, v_j, li_j, lf_j = inp
        # gate math in fp32 end-to-end (also: XLA:CPU lacks some
        # bf16×bf16→f32 dot shapes these einsums would hit)
        q_j = q_j.astype(jnp.float32)
        k_j = k_j.astype(jnp.float32)
        v_j = v_j.astype(jnp.float32)
        # cumulative log-f within chunk, inclusive: F_t = Σ_{s≤t} lf_s
        f_cum = jnp.cumsum(lf_j, axis=1)                     # (B,c,H)
        # intra-chunk scores: a[t,s] = F_t − F_s + li_s (s ≤ t)
        a_mat = f_cum[:, :, None, :] - f_cum[:, None, :, :] \
            + li_j[:, None, :, :]                            # (B,c,c,H)
        tri = jnp.tril(jnp.ones((c, c), bool))
        a_mat = jnp.where(tri[None, :, :, None], a_mat, -jnp.inf)
        m_intra = jnp.max(a_mat, axis=2)                     # (B,c,H)
        # inter-chunk (state) branch log-weight: F_t + m̄
        m_state = f_cum + mbar[:, None, :]                   # (B,c,H)
        m_tot = jnp.maximum(m_intra, m_state)
        m_tot = jnp.maximum(m_tot, -30.0)  # keeps exp(-m) sane when gates≈0
        d_mat = jnp.exp(a_mat - m_tot[:, :, None, :])        # (B,c,c,H)
        state_w = jnp.exp(m_state - m_tot)                   # (B,c,H)

        s_mat = jnp.einsum("bthd,bshd->btsh", q_j, k_j)
        cw = s_mat * d_mat                                   # (B,c,c,H)
        num_intra = jnp.einsum("btsh,bshd->bthd", cw, v_j)
        num_state = jnp.einsum("bthd,bhde->bthe", q_j, cbar) \
            * state_w[..., None]
        den_intra = jnp.sum(cw, axis=2)                      # (B,c,H)
        den_state = jnp.einsum("bthd,bhd->bth", q_j, nbar) * state_w
        den = jnp.maximum(jnp.abs(den_intra + den_state),
                          jnp.exp(-m_tot)) + 1e-6
        h_out = (num_intra + num_state) / den[..., None]     # (B,c,H,dh)

        # ---- state update to end of chunk ----
        f_tot = f_cum[:, -1, :]                              # (B,H)
        bmat = f_tot[:, None, :] - f_cum + li_j              # (B,c,H)
        m_new = jnp.maximum(f_tot + mbar, jnp.max(bmat, axis=1))
        m_new = jnp.maximum(m_new, -30.0)
        w_s = jnp.exp(bmat - m_new[:, None, :])              # (B,c,H)
        carry_scale = jnp.exp(f_tot + mbar - m_new)          # (B,H)
        kv = jnp.einsum("bshd,bshe->bhde", k_j * w_s[..., None], v_j)
        c_new = cbar * carry_scale[..., None, None] + kv
        n_new = nbar * carry_scale[..., None] \
            + jnp.sum(k_j * w_s[..., None], axis=1)
        return (c_new, n_new, m_new), h_out.astype(dt_)

    c0 = jnp.zeros((b, hn, dh, dh), jnp.float32)
    n0 = jnp.zeros((b, hn, dh), jnp.float32)
    m0 = jnp.full((b, hn), -30.0, jnp.float32)
    (c_f, n_f, m_f), hs = jax.lax.scan(chunk_body, (c0, n0, m0),
                                       (qc, kc, vc, lic, lfc))
    hseq = jnp.moveaxis(hs, 0, 1).reshape(b, s, di)
    hseq = rms_norm(hseq, params["out_norm"], cfg.norm_eps)
    out = hseq * jax.nn.silu(z)
    y = apply_w(out, params["down_proj"], dt_)
    if not return_cache:
        return y
    return y, {"conv": conv_tail, "C": c_f, "n": n_f, "m": m_f}


def init_mlstm_cache(cfg, spec, batch: int, max_len: int, dtype) -> dict:
    di, dh = _mlstm_dims(cfg)
    x = cfg.xlstm
    return {
        "conv": jnp.zeros((batch, x.conv_kernel - 1, di), dtype),
        "C": jnp.zeros((batch, cfg.n_heads, dh, dh), jnp.float32),
        "n": jnp.zeros((batch, cfg.n_heads, dh), jnp.float32),
        "m": jnp.full((batch, cfg.n_heads), -30.0, jnp.float32),
    }


def mlstm_decode(params, x, cache, pos, cfg, spec, layer_idx=None):
    if layer_idx is not None:  # layer-stacked cache (scanned decode)
        local = read_layer_cache(cache, layer_idx)
        out, new_local = mlstm_decode(params, x, local, pos, cfg, spec)
        return out, write_layer_cache(cache, new_local, layer_idx)
    di, dh = _mlstm_dims(cfg)
    hn = cfg.n_heads
    b = x.shape[0]
    dt_ = x.dtype

    xz = apply_w(x, params["up_proj"], dt_)
    xm, z = jnp.split(xz, 2, axis=-1)
    xc, conv_state = _causal_conv(
        xm, params["conv_w"].astype(dt_), params["conv_b"].astype(dt_),
        state=cache["conv"])
    xc = jax.nn.silu(xc)[:, 0]
    xm = xm[:, 0]
    q = apply_w(xc, params["wq"], dt_).reshape(b, hn, dh)
    k = apply_w(xc, params["wk"], dt_).reshape(b, hn, dh) / np.sqrt(dh)
    v = apply_w(xm, params["wv"], dt_).reshape(b, hn, dh)
    li = apply_w(xc, params["wi"], dt_).astype(jnp.float32) + params["bi"]
    lf = jax.nn.log_sigmoid(
        apply_w(xc, params["wf"], dt_).astype(jnp.float32) + params["bf"])

    q, k, v = (t.astype(jnp.float32) for t in (q, k, v))
    m_new = jnp.maximum(lf + cache["m"], li)
    m_new = jnp.maximum(m_new, -30.0)
    fp = jnp.exp(lf + cache["m"] - m_new)[..., None]          # (B,H,1)
    ip = jnp.exp(li - m_new)[..., None]
    c_new = cache["C"] * fp[..., None] \
        + ip[..., None] * (k[..., :, None] * v[..., None, :])
    n_new = cache["n"] * fp + ip * k
    num = jnp.einsum("bhd,bhde->bhe", q, c_new)
    den = jnp.maximum(
        jnp.abs(jnp.einsum("bhd,bhd->bh", q, n_new)),
        jnp.exp(-m_new)) + 1e-6
    hvec = (num / den[..., None]).reshape(b, di).astype(dt_)
    hvec = rms_norm(hvec, params["out_norm"], cfg.norm_eps)
    out = apply_w(hvec * jax.nn.silu(z[:, 0]), params["down_proj"], dt_)
    return out[:, None], {
        "conv": conv_state.astype(cache["conv"].dtype),
        "C": c_new, "n": n_new, "m": m_new}


# ----------------------------------------------------------------------
# sLSTM
# ----------------------------------------------------------------------
def _slstm_dims(cfg):
    di = cfg.d_model                      # no up-projection in the core
    dh = di // cfg.n_heads
    ff = int(cfg.xlstm.proj_factor_s * cfg.d_model)
    ff = (ff + 63) // 64 * 64
    return di, dh, ff


def init_slstm(key, cfg) -> dict:
    di, dh, ff = _slstm_dims(cfg)
    ks = jax.random.split(key, 4)
    r = jax.random.normal(ks[1], (4, cfg.n_heads, dh, dh),
                          jnp.float32) / np.sqrt(dh)
    b = jnp.zeros((4 * di,), jnp.float32)
    b = b.at[di:2 * di].set(3.0)          # forget-gate bias (order i,f,z,o)
    return {
        "w": dense_init(ks[0], (cfg.d_model, 4 * di)),
        "r": r,
        "b": b,
        "out_norm": jnp.zeros((di,), jnp.float32),
        "up_proj": dense_init(ks[2], (di, 2 * ff)),
        "down_proj": dense_init(ks[3], (ff, cfg.d_model)),
    }


def slstm_axes(cfg) -> dict:
    return {
        # r stays replicated: sharding the (4, H, dh, dh) recurrent
        # matrices over "model" costs a psum per TIME STEP inside the
        # sequential scan (measured: xlstm train_4k went collective-bound
        # purely from this) — the matrices are tiny, replicate them
        "w": ("embed", "inner"), "r": ("stack", None, None, None),
        "b": ("inner",), "out_norm": ("inner",),
        "up_proj": ("inner", "mlp"), "down_proj": ("mlp", "embed"),
    }


def _slstm_cell(params, wx_t, state, cfg):
    """One sLSTM step. wx_t: (B, 4*di) precomputed input contribution."""
    di, dh, _ = _slstm_dims(cfg)
    hn = cfg.n_heads
    c, n, hprev, m = state
    hh = hprev.reshape(-1, hn, dh)
    rec = jnp.einsum("bhd,ghde->bghe", hh, params["r"])   # (B,4,H,dh)
    pre = wx_t.reshape(-1, 4, di) + rec.reshape(-1, 4, di) \
        + params["b"].reshape(4, di)[None]
    it, ft, zt, ot = pre[:, 0], pre[:, 1], pre[:, 2], pre[:, 3]
    lf = jax.nn.log_sigmoid(ft)
    m_new = jnp.maximum(lf + m, it)
    i_s = jnp.exp(it - m_new)
    f_s = jnp.exp(lf + m - m_new)
    c_new = f_s * c + i_s * jnp.tanh(zt)
    n_new = f_s * n + i_s
    h_new = jax.nn.sigmoid(ot) * c_new / jnp.maximum(n_new, 1e-6)
    return (c_new, n_new, h_new, m_new), h_new


def slstm_forward(params, x, cfg, spec, positions, return_cache=False):
    di, dh, ff = _slstm_dims(cfg)
    b, s, _ = x.shape
    dt_ = x.dtype
    wx = apply_w(x, params["w"], dt_).astype(jnp.float32)   # (B,S,4di)

    def step(state, wx_t):
        return _slstm_cell(params, wx_t, state, cfg)

    z = jnp.zeros((b, di), jnp.float32)
    st0 = (z, z, z, jnp.full((b, di), -30.0, jnp.float32))
    (c_f, n_f, h_f, m_f), hs = jax.lax.scan(step, st0,
                                            jnp.moveaxis(wx, 1, 0))
    h = jnp.moveaxis(hs, 0, 1).astype(dt_)                  # (B,S,di)
    h = rms_norm(h, params["out_norm"], cfg.norm_eps)
    u, g = jnp.split(apply_w(h, params["up_proj"], dt_), 2, axis=-1)
    y = apply_w(u * jax.nn.silu(g), params["down_proj"], dt_)
    if not return_cache:
        return y
    return y, {"c": c_f, "n": n_f, "h": h_f, "m": m_f}


def init_slstm_cache(cfg, spec, batch: int, max_len: int, dtype) -> dict:
    di, _, _ = _slstm_dims(cfg)
    z = jnp.zeros((batch, di), jnp.float32)
    return {"c": z, "n": z, "h": z,
            "m": jnp.full((batch, di), -30.0, jnp.float32)}


def slstm_decode(params, x, cache, pos, cfg, spec, layer_idx=None):
    if layer_idx is not None:  # layer-stacked cache (scanned decode)
        local = read_layer_cache(cache, layer_idx)
        out, new_local = slstm_decode(params, x, local, pos, cfg, spec)
        return out, write_layer_cache(cache, new_local, layer_idx)
    dt_ = x.dtype
    wx = apply_w(x[:, 0], params["w"], dt_).astype(jnp.float32)
    st = (cache["c"], cache["n"], cache["h"], cache["m"])
    (c, n, h, m), _ = _slstm_cell(params, wx, st, cfg)
    hn = rms_norm(h.astype(dt_), params["out_norm"], cfg.norm_eps)
    u, g = jnp.split(apply_w(hn, params["up_proj"], dt_), 2, axis=-1)
    out = apply_w(u * jax.nn.silu(g), params["down_proj"], dt_)[:, None]
    return out, {"c": c, "n": n, "h": h, "m": m}
