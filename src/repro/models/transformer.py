"""Composable decoder stack: pattern-scanned blocks + embeddings + head.

A model = embedding → [stages] → final norm → unembed. Each stage is
either a `lax.scan` over ``reps`` repetitions of a layer *pattern* (one
set of block params per pattern position, stacked over reps — compile
time O(|pattern|)) or an unrolled tail. Blocks are pre-norm residual:
mixer (attention/MLA/Mamba/mLSTM/sLSTM) then FFN (dense/MoE/none).

The full-sequence path returns *hidden states*, not logits — the loss is
computed with a sequence-chunked cross-entropy (`chunked_ce_loss`) so the
(B, S, vocab) logit tensor is never materialized (vocab=262k × S=32k
would not fit any HBM).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.distributed.sharding import active_mesh, constrain
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm
from repro.models.layers import (
    cdtype, cross_entropy, dense_init, dense_ffn, embed, init_dense_ffn,
    init_embed, rms_norm, unembed)

# mixer registry: init, axes, forward, decode, cache-init
MIXERS = {
    "attn": (attn.init_attn, attn.attn_axes, attn.attn_forward,
             attn.attn_decode, attn.init_attn_cache),
    "mla": (attn.init_mla, attn.mla_axes, attn.mla_forward,
            attn.mla_decode, attn.init_mla_cache),
    "mamba": (ssm.init_mamba, ssm.mamba_axes, ssm.mamba_forward,
              ssm.mamba_decode, ssm.init_mamba_cache),
    "mlstm": (ssm.init_mlstm, ssm.mlstm_axes, ssm.mlstm_forward,
              ssm.mlstm_decode, ssm.init_mlstm_cache),
    "slstm": (ssm.init_slstm, ssm.slstm_axes, ssm.slstm_forward,
              ssm.slstm_decode, ssm.init_slstm_cache),
}


# ----------------------------------------------------------------------
# Stage planning
# ----------------------------------------------------------------------
def plan_stages(cfg) -> list[dict]:
    stages = []
    if cfg.lead:
        stages.append({"kind": "unroll", "specs": list(cfg.lead),
                       "reps": 1})
    if cfg.pattern_reps > 1:
        stages.append({"kind": "scan", "specs": list(cfg.pattern),
                       "reps": cfg.pattern_reps})
    elif cfg.pattern_reps == 1:
        stages.append({"kind": "unroll", "specs": list(cfg.pattern),
                       "reps": 1})
    if cfg.tail:
        stages.append({"kind": "unroll", "specs": list(cfg.tail),
                       "reps": 1})
    return stages


# ----------------------------------------------------------------------
# Block init / axes
# ----------------------------------------------------------------------
def init_block(key, spec, cfg) -> dict:
    k1, k2 = jax.random.split(key)
    p = {
        "mixer_norm": jnp.zeros((cfg.d_model,), jnp.float32),
        "mixer": MIXERS[spec.mixer][0](k1, cfg),
    }
    if spec.ffn == "dense":
        p["ffn_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["ffn"] = init_dense_ffn(k2, cfg.d_model, cfg.d_ff)
    elif spec.ffn == "moe":
        p["ffn_norm"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["ffn"] = moe_mod.init_moe(k2, cfg)
    return p


def block_axes(spec, cfg) -> dict:
    ax = {
        "mixer_norm": ("embed",),
        "mixer": MIXERS[spec.mixer][1](cfg),
    }
    if spec.ffn == "dense":
        ax["ffn_norm"] = ("embed",)
        ax["ffn"] = {"w_gate": ("embed", "mlp"), "w_up": ("embed", "mlp"),
                     "w_down": ("mlp", "embed")}
    elif spec.ffn == "moe":
        ax["ffn_norm"] = ("embed",)
        ax["ffn"] = moe_mod.moe_axes(cfg)
    return ax


def init_params(key, cfg) -> dict:
    stages = plan_stages(cfg)
    ke, kh, *kst = jax.random.split(key, 2 + len(stages))
    params = {"embed": init_embed(ke, cfg),
              "final_norm": jnp.zeros((cfg.d_model,), jnp.float32)}
    st_params = {}
    for si, (st, k) in enumerate(zip(stages, kst)):
        sp = {}
        for pi, spec in enumerate(st["specs"]):
            kk = jax.random.fold_in(k, pi)
            if st["kind"] == "scan":
                keys = jax.random.split(kk, st["reps"])
                sp[f"pos{pi}"] = jax.vmap(
                    lambda kx: init_block(kx, spec, cfg))(keys)
            else:
                sp[f"pos{pi}"] = init_block(kk, spec, cfg)
        st_params[f"s{si}"] = sp
    params["stages"] = st_params
    return params


def param_axes(cfg) -> dict:
    stages = plan_stages(cfg)
    if cfg.input_mode == "tokens":
        emb = {"tokens": ("vocab", "embed")}
    else:
        emb = {"proj": ("embed", None)}
    if not cfg.tie_embeddings or cfg.input_mode != "tokens":
        emb["unembed"] = ("embed", "vocab")
    axes = {"embed": emb, "final_norm": ("embed",)}
    st_axes = {}
    for si, st in enumerate(stages):
        sp = {}
        for pi, spec in enumerate(st["specs"]):
            bx = block_axes(spec, cfg)
            if st["kind"] == "scan":
                bx = jax.tree_util.tree_map(
                    lambda t: ("layers", *t), bx,
                    is_leaf=lambda x: isinstance(x, tuple))
            sp[f"pos{pi}"] = bx
        st_axes[f"s{si}"] = sp
    axes["stages"] = st_axes
    return axes


# ----------------------------------------------------------------------
# Forward (train / prefill)
# ----------------------------------------------------------------------
def _apply_block_full(spec, bp, x, cfg, positions, want_cache=False):
    mesh = active_mesh()
    h = rms_norm(x, bp["mixer_norm"], cfg.norm_eps)
    cache = None
    if want_cache:
        h, cache = MIXERS[spec.mixer][2](bp["mixer"], h, cfg, spec,
                                         positions, return_cache=True)
    else:
        h = MIXERS[spec.mixer][2](bp["mixer"], h, cfg, spec, positions)
    x = constrain(x + h, ("batch", "seq", None))
    aux = jnp.float32(0.0)
    if spec.ffn == "dense":
        h = rms_norm(x, bp["ffn_norm"], cfg.norm_eps)
        x = x + dense_ffn(bp["ffn"], h, cfg)
    elif spec.ffn == "moe":
        h = rms_norm(x, bp["ffn_norm"], cfg.norm_eps)
        y, aux = moe_mod.moe_ffn(bp["ffn"], h, cfg, mesh)
        x = x + y
    return constrain(x, ("batch", "seq", None)), aux, cache


def forward_hidden(params, inputs, cfg, return_caches: bool = False):
    """inputs: (B, S) int tokens or (B, S, d_input) embeddings.

    Returns (hidden (B, S, d_model), aux_loss scalar) — and, with
    ``return_caches=True`` (prefill), a decode-ready cache pytree whose
    layout matches ``init_cache`` (seq-sized; the server pads to max_len).
    """
    x = embed(params["embed"], inputs, cfg)
    x = constrain(x, ("batch", "seq", None))
    s = x.shape[1]
    positions = jnp.arange(s, dtype=jnp.int32)
    aux_total = jnp.float32(0.0)
    caches = {}

    for si, st in enumerate(plan_stages(cfg)):
        sp = params["stages"][f"s{si}"]
        stage_cache = {}
        if st["kind"] == "scan":
            def body(carry, rep_params):
                xx = carry
                aux = jnp.float32(0.0)
                cc = {}
                for pi, spec in enumerate(st["specs"]):
                    xx, a, c1 = _apply_block_full(
                        spec, rep_params[f"pos{pi}"], xx, cfg, positions,
                        want_cache=return_caches)
                    aux = aux + a
                    if return_caches:
                        cc[f"pos{pi}"] = c1
                return xx, (aux, cc)

            body_fn = jax.checkpoint(body) if cfg.remat else body
            x, (auxs, stage_cache) = jax.lax.scan(body_fn, x, sp)
            aux_total = aux_total + jnp.sum(auxs)
        else:
            for pi, spec in enumerate(st["specs"]):
                def blk(xx, _spec=spec, _bp=sp[f"pos{pi}"]):
                    return _apply_block_full(_spec, _bp, xx, cfg,
                                             positions,
                                             want_cache=return_caches)
                if cfg.remat:
                    blk = jax.checkpoint(blk)
                x, a, c1 = blk(x)
                aux_total = aux_total + a
                if return_caches:
                    stage_cache[f"pos{pi}"] = c1
        if return_caches:
            caches[f"s{si}"] = stage_cache
    hidden = rms_norm(x, params["final_norm"], cfg.norm_eps)
    if return_caches:
        return hidden, aux_total, caches
    return hidden, aux_total


def chunked_ce_loss(params, hidden, labels, cfg, chunk: int = 512,
                    mask=None):
    """Sequence-chunked cross-entropy: never materializes (B,S,V)."""
    b, s, d = hidden.shape
    c = min(chunk, s)
    assert s % c == 0
    nc = s // c
    hc = jnp.moveaxis(hidden.reshape(b, nc, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, c), 1, 0)
    if mask is None:
        mk = jnp.ones((nc, b, c), jnp.float32)
    else:
        mk = jnp.moveaxis(mask.reshape(b, nc, c), 1, 0).astype(jnp.float32)

    @jax.checkpoint
    def body(carry, inp):
        h, l, m = inp
        logits = unembed(params["embed"], h, cfg)
        logits = constrain(logits, ("batch", "seq", "vocab"))
        lse = jax.nn.logsumexp(logits.astype(jnp.float32), axis=-1)
        gold = jnp.take_along_axis(
            logits.astype(jnp.float32), l[..., None], axis=-1)[..., 0]
        nll = jnp.sum((lse - gold) * m)
        return (carry[0] + nll, carry[1] + jnp.sum(m)), None

    (total, count), _ = jax.lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), (hc, lc, mk))
    return total / jnp.maximum(count, 1.0)


def loss_fn(params, batch, cfg):
    """batch: {"inputs": ..., "labels": (B,S)} → (loss, metrics)."""
    hidden, aux = forward_hidden(params, batch["inputs"], cfg)
    ce = chunked_ce_loss(params, hidden, batch["labels"], cfg,
                         mask=batch.get("mask"))
    loss = ce
    if cfg.moe is not None:
        loss = loss + cfg.moe.router_aux_weight * aux
    return loss, {"ce": ce, "aux": aux}


# ----------------------------------------------------------------------
# Decode
# ----------------------------------------------------------------------
def init_cache(cfg, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or cdtype(cfg)
    cache = {}
    for si, st in enumerate(plan_stages(cfg)):
        sc = {}
        for pi, spec in enumerate(st["specs"]):
            c1 = MIXERS[spec.mixer][4](cfg, spec, batch, max_len, dtype)
            if st["kind"] == "scan":
                c1 = jax.tree_util.tree_map(
                    lambda a: jnp.zeros((st["reps"],) + a.shape, a.dtype),
                    c1)
            sc[f"pos{pi}"] = c1
        cache[f"s{si}"] = sc
    return cache


def cache_axes(cfg) -> dict:
    """Logical axes for cache leaves (for sharding)."""
    names = {
        "attn": {"k": ("batch", "kv_seq", "kv_heads", None),
                 "v": ("batch", "kv_seq", "kv_heads", None)},
        "mla": {"ckv": ("batch", "kv_seq", None),
                "k_rope": ("batch", "kv_seq", None)},
        "mamba": {"conv": ("batch", None, "inner"),
                  "ssm": ("batch", "inner", "state")},
        "mlstm": {"conv": ("batch", None, "inner"),
                  "C": ("batch", "heads", None, None),
                  "n": ("batch", "heads", None),
                  "m": ("batch", "heads")},
        "slstm": {"c": ("batch", "inner"), "n": ("batch", "inner"),
                  "h": ("batch", "inner"), "m": ("batch", "inner")},
    }
    axes = {}
    for si, st in enumerate(plan_stages(cfg)):
        sc = {}
        for pi, spec in enumerate(st["specs"]):
            ax = names[spec.mixer]
            if st["kind"] == "scan":
                ax = jax.tree_util.tree_map(
                    lambda t: ("layers", *t), ax,
                    is_leaf=lambda x: isinstance(x, tuple))
            sc[f"pos{pi}"] = ax
        axes[f"s{si}"] = sc
    return axes


def _apply_block_decode(spec, bp, x, cache, pos, cfg, layer_idx=None):
    h = rms_norm(x, bp["mixer_norm"], cfg.norm_eps)
    h, new_cache = MIXERS[spec.mixer][3](bp["mixer"], h, cache, pos,
                                         cfg, spec, layer_idx=layer_idx)
    x = x + h
    if spec.ffn == "dense":
        h = rms_norm(x, bp["ffn_norm"], cfg.norm_eps)
        x = x + dense_ffn(bp["ffn"], h, cfg)
    elif spec.ffn == "moe":
        h = rms_norm(x, bp["ffn_norm"], cfg.norm_eps)
        y, _ = moe_mod.moe_ffn(bp["ffn"], h, cfg, active_mesh())
        x = x + y
    return x, new_cache


def decode_step(params, cache, inputs, pos, cfg):
    """One token for every sequence in the batch.

    inputs: (B, 1) tokens or (B, 1, d_input); pos: scalar int32.
    Returns (logits (B, 1, vocab), new_cache).
    """
    x = embed(params["embed"], inputs, cfg)
    new_cache = {}
    for si, st in enumerate(plan_stages(cfg)):
        sp = params["stages"][f"s{si}"]
        sc = cache[f"s{si}"]
        nc_stage = {}
        if st["kind"] == "scan":
            # the stacked cache rides in the scan CARRY: each layer's
            # update is a token-sized dynamic-update-slice into the
            # shared (donated) buffer — O(token) writes, never O(cache)
            def body(carry, rep_params):
                xx, cc, li = carry
                ncc = dict(cc)
                for pi, spec in enumerate(st["specs"]):
                    xx, ncc[f"pos{pi}"] = _apply_block_decode(
                        spec, rep_params[f"pos{pi}"], xx,
                        ncc[f"pos{pi}"], pos, cfg, layer_idx=li)
                return (xx, ncc, li + 1), None

            (x, nc_stage, _), _ = jax.lax.scan(
                body, (x, sc, jnp.int32(0)), sp)
        else:
            for pi, spec in enumerate(st["specs"]):
                x, nc1 = _apply_block_decode(
                    spec, sp[f"pos{pi}"], x, sc[f"pos{pi}"], pos, cfg)
                nc_stage[f"pos{pi}"] = nc1
        new_cache[f"s{si}"] = nc_stage
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = unembed(params["embed"], x, cfg)
    return constrain(logits, ("batch", None, "vocab")), new_cache


def prefill(params, inputs, cfg, max_len: int | None = None):
    """Run the full-sequence path, then return last-token logits plus a
    cache built by replaying decode steps is wasteful — instead the
    serving runtime uses chunked prefill via decode for recurrent mixers
    and direct cache writes for attention. For the dry-run and tests we
    expose the simple semantic version: hidden → last logits."""
    hidden, _ = forward_hidden(params, inputs, cfg)
    return unembed(params["embed"], hidden[:, -1:], cfg)


# ----------------------------------------------------------------------
# Analytic parameter counts (for roofline MODEL_FLOPS and docs)
# ----------------------------------------------------------------------
def count_params(cfg, active_only: bool = False) -> int:
    d, ff, v = cfg.d_model, cfg.d_ff, cfg.vocab_size
    total = v * d if cfg.input_mode == "tokens" else cfg.d_input * d
    if not cfg.tie_embeddings or cfg.input_mode != "tokens":
        total += d * v

    def mixer_count(spec):
        if spec.mixer == "attn":
            return d * cfg.q_dim * 2 + d * cfg.kv_dim * 2
        if spec.mixer == "mla":
            m = cfg.mla
            qk = m.qk_nope_dim + m.qk_rope_dim
            return (d * m.q_lora_rank
                    + m.q_lora_rank * cfg.n_heads * qk
                    + d * (m.kv_lora_rank + m.qk_rope_dim)
                    + m.kv_lora_rank * cfg.n_heads
                    * (m.qk_nope_dim + m.v_head_dim)
                    + cfg.n_heads * m.v_head_dim * d)
        if spec.mixer == "mamba":
            di, dtr = ssm._mamba_dims(cfg)
            ds = cfg.mamba.d_state
            return (d * 2 * di + cfg.mamba.d_conv * di
                    + di * (dtr + 2 * ds) + dtr * di + di * ds
                    + 3 * di + di * d)  # conv_b, dt_bias, D
        if spec.mixer == "mlstm":
            di, _ = ssm._mlstm_dims(cfg)
            return (d * 2 * di + cfg.xlstm.conv_kernel * di + 3 * di * di
                    + 2 * di * cfg.n_heads + 2 * cfg.n_heads  # bi, bf
                    + 2 * di + di * d)
        if spec.mixer == "slstm":
            di, dh, ffs = ssm._slstm_dims(cfg)
            return (d * 4 * di + 4 * cfg.n_heads * dh * dh + 4 * di
                    + di  # out_norm
                    + di * 2 * ffs + ffs * d)
        raise ValueError(spec.mixer)

    def ffn_count(spec):
        if spec.ffn == "dense":
            return 3 * d * ff
        if spec.ffn == "moe":
            m = cfg.moe
            routed = m.n_experts * 3 * d * m.d_expert
            if active_only:
                routed = m.top_k * 3 * d * m.d_expert
            shared = m.n_shared * 3 * d * m.d_expert
            return d * m.n_experts + routed + shared
        return 0

    for spec in cfg.all_layer_specs():
        norms = d if spec.ffn == "none" else 2 * d
        total += mixer_count(spec) + ffn_count(spec) + norms
    total += d  # final norm
    return int(total)
