"""The LC quadratic penalty used inside the compiled L step.

    P(w; a, λ, μ) = Σ_leaves  μ/2 · ‖w − a − λ/μ‖²,   a = Δ(Θ)

Gradient wrt w is μ(w − a) − λ. Because ``a`` and ``λ`` are per-leaf and
share the leaf's sharding, this term adds zero collectives to the L step.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.tasks import get_path


def lc_penalty(params, lc_state, tasks) -> jnp.ndarray:
    """Total penalty over all compression tasks (f32 scalar)."""
    mu = lc_state["mu"]
    total = jnp.float32(0.0)
    for t in tasks:
        ts = lc_state["tasks"][t.name]
        for p in t.paths:
            w = get_path(params, p).astype(jnp.float32)
            d = w - ts["a"][p] - ts["lam"][p] / mu
            total = total + 0.5 * mu * jnp.sum(d * d)
    return total


def lc_penalty_grad_refs(lc_state, tasks):
    """(a, λ) pytrees keyed by param path — convenience for custom L steps."""
    refs = {}
    for t in tasks:
        ts = lc_state["tasks"][t.name]
        for p in t.paths:
            refs[p] = (ts["a"][p], ts["lam"][p])
    return refs
