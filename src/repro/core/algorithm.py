"""The LC algorithm driver (paper Fig. 2).

    w ← argmin_w L(w)                                  (pretrained model)
    Θ ← Π(w̄)                                           (direct compression)
    λ ← 0
    for μ = μ0 < μ1 < … :
        w ← argmin_w L(w) + μ/2‖w − Δ(Θ) − λ/μ‖²       (L step — user fn)
        Θ ← argmin_Θ ‖w − λ/μ − Δ(Θ)‖²                 (C step — schemes)
        λ ← λ − μ(w − Δ(Θ))                            (multipliers)
        stop when ‖w − Δ(Θ)‖ small

The L step is handed to the user as a *compiled step function + step
count* (not an opaque Python loop) so the trainer can pjit it, checkpoint
mid-L-step, and apply fault-tolerance policies. The C step is jitted and
sharding-preserving; per-task C steps are independent and are dispatched
together (JAX's async dispatch overlaps them — the paper's "C steps can be
run in parallel" note).
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.core import state as lcstate
from repro.core.penalty import lc_penalty
from repro.core.tasks import CompressionTask, check_disjoint, get_path
from repro.core.views import AsVector


def exponential_mu_schedule(mu0: float, a: float, n_steps: int):
    """μ_k = μ0·a^k (paper §7: a ∈ [1.1, 1.4])."""
    return [mu0 * a**k for k in range(n_steps)]


@dataclass
class LCMetrics:
    step: int
    mu: float
    distortion: dict[str, float]      # per task: ‖w − Δ(Θ)‖²
    penalty: float
    compression_ratio: float


class LCAlgorithm:
    """Orchestrates L/C/multiplier steps over a params pytree."""

    def __init__(self, tasks: Sequence[CompressionTask],
                 mu_schedule: Sequence[float],
                 l_step: Callable | None = None,
                 eval_fn: Callable | None = None,
                 jit_c_step: bool = True):
        self.tasks = list(tasks)
        self.mu_schedule = list(mu_schedule)
        self.l_step = l_step
        self.eval_fn = eval_fn
        self._c_step = jax.jit(self._c_step_impl) if jit_c_step \
            else self._c_step_impl
        self._resolved = False

    # ------------------------------------------------------------------
    def resolve(self, params):
        if not self._resolved:
            resolved = []
            for t in self.tasks:
                t = t.resolve(params)
                if len(t.paths) > 1 and not isinstance(t.view, AsVector):
                    # single-array views (AsIs/AsMatrix/AsStacked) over a
                    # multi-leaf selector = one independent task per leaf
                    # (paper semantics: per-layer compression)
                    for i, p in enumerate(t.paths):
                        resolved.append(CompressionTask(
                            f"{t.name}[{i}]", t.pattern, t.view,
                            t.scheme, [p]))
                else:
                    resolved.append(t)
            self.tasks = resolved
            check_disjoint(self.tasks)
            self._resolved = True
        return self

    def init(self, params) -> dict:
        """Θ ← Π(w̄), λ ← 0 (direct compression)."""
        self.resolve(params)
        tasks_state = {}
        for t in self.tasks:
            leaves = t.leaves(params)
            x = t.view.to_compressible(leaves)
            theta = t.scheme_init(x)
            a_arr = t.scheme_decompress(theta)
            a_leaves = t.view.from_compressible(a_arr, leaves)
            a = {p: l.astype(jnp.float32)
                 for p, l in zip(t.paths, a_leaves)}
            lam = lcstate.zeros_like_leaves(t.paths, leaves)
            tasks_state[t.name] = lcstate.task_state(theta, lam, a)
        return lcstate.lc_state(tasks_state, self.mu_schedule[0], k=0)

    # ------------------------------------------------------------------
    def _c_step_impl(self, params, lc):
        mu = lc["mu"]
        new_tasks = {}
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            leaves = t.leaves(params)
            shifted = [get_path(params, p).astype(jnp.float32)
                       - ts["lam"][p] / mu for p in t.paths]
            x = t.view.to_compressible(
                [s.astype(l.dtype) for s, l in zip(shifted, leaves)])
            theta = t.scheme_compress(x, ts["theta"], mu)
            a_arr = t.scheme_decompress(theta)
            a_leaves = t.view.from_compressible(a_arr, leaves)
            a = {p: l.astype(jnp.float32)
                 for p, l in zip(t.paths, a_leaves)}
            new_tasks[t.name] = lcstate.task_state(theta, ts["lam"], a)
        return {"tasks": new_tasks, "mu": mu, "k": lc["k"]}

    def c_step(self, params, lc) -> dict:
        return self._c_step(params, lc)

    def multiplier_step(self, params, lc) -> dict:
        """λ ← λ − μ(w − Δ(Θ)) (augmented Lagrangian; skip for QP)."""
        mu = lc["mu"]
        new_tasks = {}
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            lam = {p: ts["lam"][p]
                   - mu * (get_path(params, p).astype(jnp.float32)
                           - ts["a"][p])
                   for p in t.paths}
            new_tasks[t.name] = lcstate.task_state(ts["theta"], lam, ts["a"])
        return {"tasks": new_tasks, "mu": mu, "k": lc["k"]}

    def set_mu(self, lc, mu: float, k: int) -> dict:
        return {"tasks": lc["tasks"], "mu": jnp.float32(mu),
                "k": jnp.int32(k)}

    # ------------------------------------------------------------------
    def penalty(self, params, lc) -> jnp.ndarray:
        return lc_penalty(params, lc, self.tasks)

    def distortion(self, params, lc) -> dict[str, jnp.ndarray]:
        """‖w − Δ(Θ)‖² per task — must decrease across C steps (§7)."""
        out = {}
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            d = jnp.float32(0.0)
            for p in t.paths:
                diff = get_path(params, p).astype(jnp.float32) - ts["a"][p]
                d = d + jnp.sum(diff * diff)
            out[t.name] = d
        return out

    def constraint_violation(self, params, lc) -> jnp.ndarray:
        """‖w − Δ(Θ)‖ over all tasks — the convergence monitor."""
        total = jnp.float32(0.0)
        for v in self.distortion(params, lc).values():
            total = total + v
        return jnp.sqrt(total)

    def compression_ratio(self, params, lc, float_bits: int = 32) -> float:
        """(uncompressed bits of compressed params) / (Θ bits)."""
        orig_bits = 0.0
        comp_bits = 0.0
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            for p in t.paths:
                orig_bits += get_path(params, p).size * float_bits
            theta = ts["theta"]
            if t.view.stacked:
                n = jax.tree_util.tree_leaves(theta)[0].shape[0]
                item = jax.tree_util.tree_map(lambda x: x[0], theta)
                comp_bits += n * float(t.scheme.bits(item, float_bits))
            else:
                comp_bits += float(t.scheme.bits(theta, float_bits))
        return orig_bits / max(comp_bits, 1.0)

    def apply_compression(self, params):
        """w ← Δ(Θ) applied into the params pytree — the final compressed
        model (call after the LC loop; uses the latest C step of w)."""
        lc = self._last_lc
        out = params
        from repro.core.tasks import set_path
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            for p in t.paths:
                leaf = get_path(params, p)
                out = set_path(out, p, ts["a"][p].astype(leaf.dtype))
        return out

    # ------------------------------------------------------------------
    def run(self, train_state, params_of: Callable, tol: float = 0.0,
            callbacks: Sequence[Callable] = ()):
        """Full LC loop (paper Fig. 2 / Listing 1).

        ``train_state`` is opaque to LC except through ``params_of``.
        ``self.l_step(train_state, lc, step) -> train_state`` runs one full
        L step (the user decides epochs/steps inside, as in the paper).
        """
        assert self.l_step is not None, "provide l_step to run()"
        params = params_of(train_state)
        lc = self.init(params)
        self._last_lc = lc
        history = []
        for k, mu in enumerate(self.mu_schedule):
            lc = self.set_mu(lc, mu, k)
            train_state = self.l_step(train_state, lc, k)
            params = params_of(train_state)
            lc = self.c_step(params, lc)
            lc = self.multiplier_step(params, lc)
            self._last_lc = lc
            m = LCMetrics(
                step=k, mu=float(mu),
                distortion={n: float(v) for n, v in
                            self.distortion(params, lc).items()},
                penalty=float(self.penalty(params, lc)),
                compression_ratio=float(
                    self.compression_ratio(params, lc)),
            )
            history.append(m)
            for cb in callbacks:
                cb(train_state, lc, m)
            if tol > 0 and float(
                    self.constraint_violation(params, lc)) < tol:
                break
        return train_state, lc, history
