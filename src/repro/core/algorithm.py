"""The LC algorithm driver (paper Fig. 2).

    w ← argmin_w L(w)                                  (pretrained model)
    Θ ← Π(w̄)                                           (direct compression)
    λ ← 0
    for μ = μ0 < μ1 < … :
        w ← argmin_w L(w) + μ/2‖w − Δ(Θ) − λ/μ‖²       (L step — user fn)
        Θ ← argmin_Θ ‖w − λ/μ − Δ(Θ)‖²                 (C step — schemes)
        λ ← λ − μ(w − Δ(Θ))                            (multipliers)
        stop when ‖w − Δ(Θ)‖ small

The L step is handed to the user as a *compiled step function + step
count* (not an opaque Python loop) so the trainer can pjit it, checkpoint
mid-L-step, and apply fault-tolerance policies.

The C step is ONE jitted call. With ``group_tasks=True`` (default) the
independent per-task projections are not merely traced side by side: tasks
with equal ``scheme.group_key()`` and item shape are stacked along a
leading axis and solved by a single vmapped scheme program per group
(``core.grouping``) — the paper's "C steps can be run in parallel" note,
realized as batched compute instead of N copies of the same HLO. The LC
state buffers are donated to the C/multiplier steps on accelerators, so
Θ/λ/a update in place. ``group_tasks=False`` keeps the legacy per-task
trace for schemes that cannot be vmapped.
"""
from __future__ import annotations

from dataclasses import dataclass
from functools import partial
from typing import Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import state as lcstate
from repro.core.grouping import (
    describe_groups, grouped_compress, grouped_init, solve_task)
from repro.core.penalty import lc_penalty
from repro.core.tasks import CompressionTask, check_disjoint, get_path
from repro.core.views import AsVector


def exponential_mu_schedule(mu0: float, a: float, n_steps: int):
    """μ_k = μ0·a^k (paper §7: a ∈ [1.1, 1.4])."""
    return [mu0 * a**k for k in range(n_steps)]


@dataclass
class LCMetrics:
    step: int
    mu: float
    distortion: dict[str, float]      # per task: ‖w − Δ(Θ)‖²
    penalty: float
    compression_ratio: float


class LCAlgorithm:
    """Orchestrates L/C/multiplier steps over a params pytree."""

    def __init__(self, tasks: Sequence[CompressionTask],
                 mu_schedule: Sequence[float],
                 l_step: Callable | None = None,
                 eval_fn: Callable | None = None,
                 jit_c_step: bool = True,
                 group_tasks: bool = True,
                 donate: bool | str = "auto",
                 mesh=None,
                 sharding_rules: dict | None = None,
                 cstep_backend: str = "auto",
                 planner: str | None = "on"):
        self.tasks = list(tasks)
        self.mu_schedule = list(mu_schedule)
        self.l_step = l_step
        self.eval_fn = eval_fn
        self.group_tasks = bool(group_tasks)
        self.mesh = mesh
        self.sharding_rules = sharding_rules
        # kernel dispatch backend for opted-in scheme solvers
        # ("auto" | "jnp" | "interpret" | "pallas" | "off"); resolved
        # per group by repro.kernels.dispatch — see docs/architecture.md
        self.cstep_backend = self._check_backend(cstep_backend)
        # roofline-guided group planner ("on" | "off" | None≡"off"):
        # picks backend/tile/chunking per group at trace time and
        # memoizes the decision — see repro.analysis.cost
        self.planner = self._check_planner(planner)
        if donate == "auto":
            # donation is a no-op (with a warning) on CPU; only ask for
            # in-place Θ/λ/a updates where XLA implements aliasing.
            donate = jax.default_backend() in ("tpu", "gpu", "cuda", "rocm")
        self._jit_c_step = bool(jit_c_step)
        self._donate = bool(donate)
        self._build_steps()
        self._resolved = False

    def _build_steps(self):
        """(Re)wrap the step impls in jit; called again by set_mesh so a
        late-bound mesh invalidates any already-compiled executables."""
        dargs = (1,) if self._donate else ()
        if self._jit_c_step:
            self._c_step = jax.jit(self._c_step_impl, donate_argnums=dargs)
            self._mult_step = jax.jit(self._multiplier_step_impl,
                                      donate_argnums=dargs)
            self._distortion = jax.jit(self._distortion_impl)
            self._shifted_distortion = jax.jit(
                self._shifted_distortion_impl)
        else:
            self._c_step = self._c_step_impl
            self._mult_step = self._multiplier_step_impl
            self._distortion = self._distortion_impl
            self._shifted_distortion = self._shifted_distortion_impl
        # Async (overlap-safe) variants: NEVER donate. While an
        # overlapped L step is in flight it still reads the previous
        # Θ/λ/a buffers through its penalty refs, so donating them to
        # the concurrent C/multiplier step would let XLA overwrite
        # memory another executable is reading. When donation is off
        # anyway, the sync and async entry points share one executable.
        if self._donate and self._jit_c_step:
            self._c_step_async = jax.jit(self._c_step_impl)
            self._mult_step_async = jax.jit(self._multiplier_step_impl)
        else:
            self._c_step_async = self._c_step
            self._mult_step_async = self._mult_step
        # grouped Θ^DC cold start: one jitted program, one scheme trace
        # per group (never donates — params are the caller's)
        self._init_grouped = (jax.jit(self._init_grouped_impl)
                              if self._jit_c_step
                              else self._init_grouped_impl)

    def set_mesh(self, mesh, rules: dict | None = None) -> "LCAlgorithm":
        """Bind the device mesh the grouped C step shards over.

        The mesh is static trace-time state (it picks the sharding
        constraints baked into the C-step HLO), so the jitted steps are
        rebuilt — safe to call any time, typically right after
        construction by the trainer that owns the mesh.
        """
        self.mesh = mesh
        if rules is not None:
            self.sharding_rules = rules
        self._build_steps()
        return self

    @staticmethod
    def _check_backend(backend):
        """Fail fast on a typo'd backend: the first consumer would
        otherwise be dispatch.resolve_backend inside the first C-step
        trace, minutes into a run and wrapped in a jit traceback."""
        valid = (None, "auto", "jnp", "interpret", "pallas", "off")
        if backend not in valid:
            raise ValueError(
                f"cstep_backend must be one of {valid[1:]}, "
                f"got {backend!r}")
        return backend

    @staticmethod
    def _check_planner(planner):
        valid = (None, "on", "off")
        if planner not in valid:
            raise ValueError(
                f"planner must be one of {valid}, got {planner!r}")
        return planner

    def set_planner(self, planner: str | None) -> "LCAlgorithm":
        """Toggle the roofline group planner. Trace-time state like
        :meth:`set_backend` (it decides which solver impl / tiling /
        chunking the C-step HLO bakes in), so the steps are rebuilt."""
        self.planner = self._check_planner(planner)
        self._build_steps()
        return self

    def set_backend(self, backend: str) -> "LCAlgorithm":
        """Select the kernel dispatch backend for the C step.

        Like :meth:`set_mesh` this is trace-time state (it decides
        which solver implementations the C-step HLO bakes in), so the
        jitted steps are rebuilt.
        """
        self.cstep_backend = self._check_backend(backend)
        self._build_steps()
        return self

    # ------------------------------------------------------------------
    def resolve(self, params):
        if not self._resolved:
            resolved = []
            for t in self.tasks:
                t = t.resolve(params)
                if len(t.paths) > 1 and not isinstance(t.view, AsVector):
                    # single-array views (AsIs/AsMatrix/AsStacked) over a
                    # multi-leaf selector = one independent task per leaf
                    # (paper semantics: per-layer compression)
                    for i, p in enumerate(t.paths):
                        resolved.append(CompressionTask(
                            f"{t.name}[{i}]", t.pattern, t.view,
                            t.scheme, [p]))
                else:
                    resolved.append(t)
            self.tasks = resolved
            check_disjoint(self.tasks)
            self._resolved = True
        return self

    def init(self, params) -> dict:
        """Θ ← Π(w̄), λ ← 0 (direct compression).

        With ``group_tasks=True`` (default) the Θ^DC solves run through
        :func:`grouped_init` inside one jitted program — one scheme
        trace per (scheme, item shape) group, so cold-start compile
        cost is O(groups) like the C step's (and the packed item axes
        shard over a bound mesh). ``group_tasks=False`` keeps the
        legacy eager per-task loop; both produce identical state.
        """
        self.resolve(params)
        if self.group_tasks:
            return self._init_grouped(params)
        tasks_state = {}
        for t in self.tasks:
            theta = t.scheme_init(t.compressible(params))
            a = t.scatter_decompressed(t.scheme_decompress(theta), params)
            lam = lcstate.zeros_like_leaves(t.paths, t.leaves(params))
            tasks_state[t.name] = lcstate.task_state(theta, lam, a)
        return lcstate.lc_state(tasks_state, self.mu_schedule[0], k=0)

    def _init_grouped_impl(self, params):
        xs = {t.name: t.compressible(params) for t in self.tasks}
        results = grouped_init(self.tasks, xs, mesh=self.mesh,
                               rules=self.sharding_rules)
        tasks_state = {}
        for t in self.tasks:
            theta, a_arr = results[t.name]
            a = t.scatter_decompressed(a_arr, params)
            lam = lcstate.zeros_like_leaves(t.paths, t.leaves(params))
            tasks_state[t.name] = lcstate.task_state(theta, lam, a)
        return lcstate.lc_state(tasks_state, self.mu_schedule[0], k=0)

    # ------------------------------------------------------------------
    def _c_step_impl(self, params, lc):
        if self.group_tasks:
            return self._c_step_grouped(params, lc)
        return self._c_step_pertask(params, lc)

    def _c_step_pertask(self, params, lc):
        """Per-task path: one scheme trace per task (`group_tasks=False`).

        Kernel dispatch still applies — each opted-in task's solve runs
        through its named batched solver on a 1-task item stack — so
        the kernel path is exercised on both dispatch modes."""
        mu = lc["mu"]
        new_tasks = {}
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            x = t.shifted_compressible(params, ts, mu)
            theta = solve_task(t, x, ts["theta"], mu,
                               backend=self.cstep_backend)
            a = t.scatter_decompressed(t.scheme_decompress(theta), params)
            new_tasks[t.name] = lcstate.task_state(theta, ts["lam"], a)
        return lcstate.with_tasks(lc, new_tasks)

    def _c_step_grouped(self, params, lc):
        """Grouped path: one vmapped scheme trace per (scheme, shape)
        group — see ``core.grouping``. With ``self.mesh`` set, each
        group's packed item axis is sharded over the mesh's data axis.
        Bitwise-equivalent to the per-task path and to ``mesh=None``
        (enforced by tests/test_grouped_cstep.py and
        tests/test_sharded_cstep.py)."""
        mu = lc["mu"]
        xs = {t.name: t.shifted_compressible(params, lc["tasks"][t.name],
                                             mu)
              for t in self.tasks}
        thetas = {t.name: lc["tasks"][t.name]["theta"]
                  for t in self.tasks}
        results = grouped_compress(self.tasks, xs, thetas, mu,
                                   mesh=self.mesh,
                                   rules=self.sharding_rules,
                                   backend=self.cstep_backend,
                                   planner=self.planner)
        new_tasks = {}
        for t in self.tasks:
            theta, a_arr = results[t.name]
            a = t.scatter_decompressed(a_arr, params)
            new_tasks[t.name] = lcstate.task_state(
                theta, lc["tasks"][t.name]["lam"], a)
        return lcstate.with_tasks(lc, new_tasks)

    def c_step(self, params, lc) -> dict:
        return self._c_step(params, lc)

    def c_step_async(self, params, lc) -> dict:
        """C step for the overlapped trainer pipeline: dispatches the
        jitted grouped solve and returns the *unblocked* state (every
        leaf a future). Unlike :meth:`c_step` it never donates its
        inputs — the caller is by construction still holding the
        previous Θ/λ/a alive inside an in-flight L step."""
        return self._c_step_async(params, lc)

    def multiplier_step_async(self, params, lc) -> dict:
        """Non-donating, non-blocking :meth:`multiplier_step` (the λ
        buffers it consumes are still referenced by the in-flight L
        step's penalty refs during overlap)."""
        return self._mult_step_async(params, lc)

    def group_summary(self, params) -> list[dict]:
        """The grouping the C step will use, from shapes only (no compute)."""
        self.resolve(params)
        xs = {t.name: jax.eval_shape(t.view.to_compressible,
                                     t.leaves(params))
              for t in self.tasks}
        # group_tasks=False runs the unsharded per-task path, so don't
        # report a layout that will never be applied (kernel dispatch
        # does apply there — solver/backend stay honest either way)
        return describe_groups(self.tasks, xs,
                               mesh=self.mesh if self.group_tasks
                               else None,
                               rules=self.sharding_rules,
                               backend=self.cstep_backend,
                               planner=self.planner)

    def _multiplier_step_impl(self, params, lc):
        mu = lc["mu"]
        new_tasks = {}
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            lam = {p: ts["lam"][p]
                   - mu * (get_path(params, p).astype(jnp.float32)
                           - ts["a"][p])
                   for p in t.paths}
            new_tasks[t.name] = lcstate.task_state(ts["theta"], lam, ts["a"])
        return lcstate.with_tasks(lc, new_tasks)

    def multiplier_step(self, params, lc) -> dict:
        """λ ← λ − μ(w − Δ(Θ)) (augmented Lagrangian; skip for QP)."""
        return self._mult_step(params, lc)

    def set_mu(self, lc, mu: float, k: int) -> dict:
        return {"tasks": lc["tasks"], "mu": jnp.float32(mu),
                "k": jnp.int32(k)}

    # ------------------------------------------------------------------
    def penalty(self, params, lc) -> jnp.ndarray:
        return lc_penalty(params, lc, self.tasks)

    def _distortion_impl(self, params, lc) -> dict[str, jnp.ndarray]:
        out = {}
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            d = jnp.float32(0.0)
            for p in t.paths:
                diff = get_path(params, p).astype(jnp.float32) - ts["a"][p]
                d = d + jnp.sum(diff * diff)
            out[t.name] = d
        return out

    def distortion(self, params, lc) -> dict[str, jnp.ndarray]:
        """‖w − Δ(Θ)‖² per task — must decrease across C steps (§7)."""
        return self._distortion(params, lc)

    def _shifted_distortion_impl(self, params, lc) -> dict[str, jnp.ndarray]:
        out = {}
        mu = lc["mu"]
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            x = t.shifted_compressible(params, ts, mu).astype(jnp.float32)
            a = t.view.to_compressible(
                [ts["a"][p] for p in t.paths]).astype(jnp.float32)
            out[t.name] = jnp.sum((x - a) ** 2)
        return out

    def shifted_distortion(self, params, lc) -> dict[str, jnp.ndarray]:
        """‖(w − λ/μ) − Δ(Θ)‖² per task — the exact C-step objective.

        Unlike :meth:`distortion`, a warm-started C step is *guaranteed*
        not to increase this at fixed (w, λ, μ) — the paper §7 monitor
        the trainer checks around every C step.
        """
        return self._shifted_distortion(params, lc)

    def constraint_violation(self, params, lc) -> jnp.ndarray:
        """‖w − Δ(Θ)‖ over all tasks — the convergence monitor."""
        total = jnp.float32(0.0)
        for v in self.distortion(params, lc).values():
            total = total + v
        return jnp.sqrt(total)

    def compression_ratio(self, params, lc, float_bits: int = 32) -> float:
        """(uncompressed bits of compressed params) / (Θ bits)."""
        orig_bits = 0.0
        comp_bits = 0.0
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            for p in t.paths:
                orig_bits += get_path(params, p).size * float_bits
            theta = ts["theta"]
            if t.view.stacked:
                # bits() can be item-dependent (RankSelection stores a
                # different rank per item), so sum per item rather than
                # extrapolating item 0 across the stack; one host
                # transfer per leaf, then index on host (no per-item
                # device round trips)
                host = jax.tree_util.tree_map(np.asarray, theta)
                n = jax.tree_util.tree_leaves(host)[0].shape[0]
                for i in range(n):
                    item = jax.tree_util.tree_map(
                        lambda x, i=i: x[i], host)
                    comp_bits += float(t.scheme.bits(item, float_bits))
            else:
                comp_bits += float(t.scheme.bits(theta, float_bits))
        return orig_bits / max(comp_bits, 1.0)

    def apply_compression(self, params):
        """w ← Δ(Θ) applied into the params pytree — the final compressed
        model (call after the LC loop; uses the latest C step of w)."""
        lc = self._last_lc
        out = params
        from repro.core.tasks import set_path
        for t in self.tasks:
            ts = lc["tasks"][t.name]
            for p in t.paths:
                leaf = get_path(params, p)
                out = set_path(out, p, ts["a"][p].astype(leaf.dtype))
        return out

    # ------------------------------------------------------------------
    def run(self, train_state, params_of: Callable, tol: float = 0.0,
            callbacks: Sequence[Callable] = ()):
        """Full LC loop (paper Fig. 2 / Listing 1).

        ``train_state`` is opaque to LC except through ``params_of``.
        ``self.l_step(train_state, lc, step) -> train_state`` runs one full
        L step (the user decides epochs/steps inside, as in the paper).
        """
        assert self.l_step is not None, "provide l_step to run()"
        params = params_of(train_state)
        lc = self.init(params)
        self._last_lc = lc
        history = []
        for k, mu in enumerate(self.mu_schedule):
            lc = self.set_mu(lc, mu, k)
            train_state = self.l_step(train_state, lc, k)
            params = params_of(train_state)
            lc = self.c_step(params, lc)
            lc = self.multiplier_step(params, lc)
            self._last_lc = lc
            m = LCMetrics(
                step=k, mu=float(mu),
                distortion={n: float(v) for n, v in
                            self.distortion(params, lc).items()},
                penalty=float(self.penalty(params, lc)),
                compression_ratio=float(
                    self.compression_ratio(params, lc)),
            )
            history.append(m)
            for cb in callbacks:
                cb(train_state, lc, m)
            if tol > 0 and float(
                    self.constraint_violation(params, lc)) < tol:
                break
        return train_state, lc, history
