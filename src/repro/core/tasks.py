"""Compression tasks: (parameter selector) → (view, scheme).

The paper's ``compression_tasks`` dict maps ``Param(...)`` objects to
``(view, compression)`` pairs. Here parameters live in a nested-dict
pytree, so the selector is a regex over slash-joined paths — this survives
scanned layer stacks (a stacked param is one leaf, compressed per-item via
``AsStacked``) and works identically on sharded arrays.
"""
from __future__ import annotations

import re
import zlib
from dataclasses import dataclass, field
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.schemes.base import CompressionScheme
from repro.core.views import View


def flatten_params(params) -> dict[str, Any]:
    """Nested dict pytree → {'a/b/c': leaf} with deterministic order."""
    flat = {}

    def rec(node, prefix):
        if isinstance(node, dict):
            for k in sorted(node.keys()):
                rec(node[k], f"{prefix}/{k}" if prefix else str(k))
        else:
            flat[prefix] = node

    rec(params, "")
    return flat


def set_path(params, path: str, value):
    """Functionally set a slash path in a nested dict pytree."""
    keys = path.split("/")
    node = dict(params)
    cursor = node
    for k in keys[:-1]:
        cursor[k] = dict(cursor[k])
        cursor = cursor[k]
    cursor[keys[-1]] = value
    return node


def get_path(params, path: str):
    node = params
    for k in path.split("/"):
        node = node[k]
    return node


@dataclass
class CompressionTask:
    """One entry of the compression-tasks structure."""

    name: str
    pattern: str                      # regex matched with re.search on paths
    view: View
    scheme: CompressionScheme
    # resolved lazily against a concrete params pytree:
    paths: list[str] = field(default_factory=list)

    def resolve(self, params) -> "CompressionTask":
        flat = flatten_params(params)
        rx = re.compile(self.pattern)
        paths = [p for p in flat if rx.search(p)]
        if not paths:
            raise ValueError(
                f"task {self.name!r}: pattern {self.pattern!r} matched no "
                f"parameters; available: {sorted(flat)[:20]}...")
        return CompressionTask(self.name, self.pattern, self.view,
                               self.scheme, paths)

    def leaves(self, params) -> list:
        return [get_path(params, p) for p in self.paths]

    def compressible(self, params):
        """x = view(w) — the array the scheme projects."""
        return self.view.to_compressible(self.leaves(params))

    def shifted_compressible(self, params, task_state, mu):
        """x = view(w − λ/μ) — the C-step input (paper Fig. 2)."""
        leaves = self.leaves(params)
        shifted = [get_path(params, p).astype(jnp.float32)
                   - task_state["lam"][p] / mu for p in self.paths]
        return self.view.to_compressible(
            [s.astype(l.dtype) for s, l in zip(shifted, leaves)])

    def scatter_decompressed(self, a_arr, params) -> dict:
        """Δ(Θ) in compressible shape → {path: f32 leaf} (the ``a`` refs)."""
        a_leaves = self.view.from_compressible(a_arr, self.leaves(params))
        return {p: l.astype(jnp.float32)
                for p, l in zip(self.paths, a_leaves)}

    def group_signature(self, x, batched: bool = False) -> tuple | None:
        """Hashable grouping signature, or None when not groupable.

        ``x`` may be a concrete array, a tracer, or a ShapeDtypeStruct —
        only ``.shape``/``.dtype`` are read. Two tasks with equal
        signatures are solved by one vmapped scheme call (see
        ``core.grouping``).

        With ``batched=True`` (kernel dispatch active) a scheme that is
        :meth:`CompressionScheme.kernel_dispatch_ready` groups by its
        ``batch_key()`` instead — hyperparameters the batched solver
        takes as per-item operands (κ) drop out of the identity, so
        e.g. mixed-κ pruning tasks land in one group/kernel launch.
        """
        if batched and self.scheme.kernel_dispatch_ready():
            key = ("batched", self.scheme.solver, self.scheme.batch_key())
        else:
            key = self.scheme.group_key()
        if key is None:
            return None
        # the scheme class is part of the identity: a subclass overriding
        # compress() but inheriting group_key() must not merge with its
        # parent (the group runs ONE scheme instance for all members)
        return (type(self.scheme).__qualname__, key,
                self.view.item_shape(x), str(x.dtype))

    # ---- per-item PRNG keys (stochastic C steps) -----------------------
    def item_keys(self, n_items: int) -> jnp.ndarray:
        """(n_items, 2) uint32 PRNG keys for schemes with ``wants_key``.

        Derived from the *task name* (not the packed group offset) and
        the within-task item index, so the keys are identical on the
        grouped and per-task dispatch paths, deterministic across
        reruns, and distinct for every item of a packed group — no two
        items ever share a randomized-SVD sketch.
        """
        seed = zlib.crc32(self.name.encode("utf-8")) & 0x7FFFFFFF
        base = jax.random.PRNGKey(seed)
        return jax.vmap(lambda j: jax.random.fold_in(base, j))(
            jnp.arange(n_items))

    # ---- scheme application, vmapped when the view is stacked ----------
    def scheme_init(self, x):
        if self.scheme.wants_key:
            keys = self.item_keys(self.view.item_count(x))
            if self.view.stacked:
                return jax.vmap(
                    lambda xi, ki: self.scheme.init(xi, key=ki))(x, keys)
            return self.scheme.init(x, key=keys[0])
        if self.view.stacked:
            return jax.vmap(lambda xi: self.scheme.init(xi))(x)
        return self.scheme.init(x)

    def scheme_compress(self, x, theta, mu):
        if self.scheme.wants_key:
            keys = self.item_keys(self.view.item_count(x))
            if self.view.stacked:
                return jax.vmap(
                    lambda xi, ti, ki: self.scheme.compress(
                        xi, ti, mu=mu, key=ki))(x, theta, keys)
            return self.scheme.compress(x, theta, mu=mu, key=keys[0])
        if self.view.stacked:
            return jax.vmap(
                lambda xi, ti: self.scheme.compress(xi, ti, mu=mu))(x, theta)
        return self.scheme.compress(x, theta, mu=mu)

    def scheme_decompress(self, theta):
        if self.view.stacked:
            return jax.vmap(self.scheme.decompress)(theta)
        return self.scheme.decompress(theta)


def check_disjoint(tasks: list[CompressionTask]):
    """Each parameter may belong to at most one task (paper semantics:
    additive multi-scheme compression of the same params is expressed as a
    single AdditiveCombination task, not two overlapping tasks)."""
    seen: dict[str, str] = {}
    for t in tasks:
        for p in t.paths:
            if p in seen:
                raise ValueError(
                    f"parameter {p} claimed by tasks {seen[p]!r} and "
                    f"{t.name!r}; use AdditiveCombination for multi-scheme")
            seen[p] = t.name
    return True
