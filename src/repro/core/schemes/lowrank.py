"""Low-rank C steps (paper §4.3).

``LowRank(r)`` — truncated SVD to a fixed target rank.
``RankSelection(alpha, cost=...)`` — automatic per-matrix rank (Idelbayev &
Carreira-Perpiñán, CVPR'20 [17]): the C step minimizes
    λ·α·C(r) + μ/2·Σ_{i>r} σ_i²   over r ∈ {0..R},
with C(r) = r·(m+n) (storage floats) or C(r) = r·(m+n) MAC-scaled (FLOPs).
Because the selected rank changes across C steps, Θ keeps fixed shapes
(U: (m,R), V: (n,R)) plus an integer rank; columns ≥ r are masked to zero —
this keeps every C step jit-compatible on TPU.

Under kernel dispatch both schemes route through the **matmul-only
batched solvers** in ``kernels/lowrank`` (``lowrank_rsvd`` /
``rank_select``): Gaussian sketch per item, power iteration with
Jacobi-based orthogonalization, small Gram finisher — no LAPACK custom
call, so packed groups shard under plain GSPMD (``gspmd_safe``) and
mixed-rank / mixed-α tasks pack into ONE launch (rank and α ride as
traced per-item operands; factors pad to the group ``R_max``).
``LowRank(randomized=False)`` demands the exact LAPACK SVD and opts out
of dispatch; ``RankSelection`` joins the batched path only when
``max_rank`` bounds the sketch (unbounded selection keeps the exact
spectrum).

For large matrices the legacy per-task path also uses a randomized range
finder (Halko et al.); its sketch key is threaded per item by the C-step
engine (``wants_key`` / ``CompressionTask.item_keys``), so grouped items
never share a sketch and reruns are reproducible.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.schemes.base import CompressionScheme


def randomized_svd(w: jnp.ndarray, r: int, key: jax.Array,
                   oversample: int = 8, power_iters: int = 2):
    """Rank-r randomized SVD. Returns (U (m,r), s (r,), V (n,r))."""
    m, n = w.shape
    k = min(r + oversample, min(m, n))
    omega = jax.random.normal(key, (n, k), dtype=jnp.float32)
    y = w.astype(jnp.float32) @ omega
    for _ in range(power_iters):
        y, _ = jnp.linalg.qr(y)
        y = w.astype(jnp.float32) @ (w.astype(jnp.float32).T @ y)
    q, _ = jnp.linalg.qr(y)                      # (m, k)
    b = q.T @ w.astype(jnp.float32)              # (k, n)
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub
    return u[:, :r], s[:r], vt[:r, :].T


def exact_svd(w: jnp.ndarray):
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    return u, s, vt.T


#: base seed for sketch keys when a scheme is used outside the C-step
#: engine (direct compress() calls); inside it, per-item fold_in keys
#: arrive via the key= kwarg / the engine-appended operand.
_SKETCH_SEED = 0x1C


class LowRank(CompressionScheme):
    """W ≈ U Vᵀ with fixed target rank (Θ = (U√s, V√s))."""

    domain = "matrix"
    # batched matmul-only randomized SVD in the dispatch registry; rank
    # is NOT in batch_key() — it rides as a traced per-item operand, so
    # tasks differing only in target rank pack into ONE group/launch
    # with factors padded to the group R_max (pack_thetas_padded).
    solver = "lowrank_rsvd"
    solver_operands = ("rank",)
    wants_key = True       # per-item sketch keys from the C-step engine
    gspmd_safe = True      # no LAPACK custom call in the batched solver

    def __init__(self, target_rank: int, randomized: str = "auto"):
        assert target_rank >= 1
        self.rank = int(target_rank)
        self.randomized = randomized

    @classmethod
    def contract_examples(cls):
        return (cls(target_rank=2),)

    def group_key(self):
        # `randomized="auto"` resolves per item shape, but grouped items
        # share a shape, so the key stays static within any group.
        return ("lowrank", self.rank, self.randomized)

    def batch_key(self):
        # randomized=False is an explicit demand for the exact LAPACK
        # SVD: opt out of the (always-randomized) batched solver.
        if self.randomized is False:
            return None
        return ("lowrank-rsvd",)

    def batch_operands(self, n_items: int):
        return (jnp.full((n_items,), self.rank, jnp.int32),)

    def compress_batched(self, solve, w, theta, operands, mu=None):
        """One solver call factorizes the whole packed group. ``theta``
        arrives padded to the group R_max (its trailing dim is the
        static factor width the solver needs); ``operands`` is
        (per-item ranks, per-item sketch keys). The previous U factor
        warm-starts the range finder (``u0=``) — at late μ, where Θ
        barely moves between C steps, the solver then spends fewer
        power iterations for the same ≤1e-4 distortion budget."""
        rank, keys = operands
        r_max = theta["u"].shape[-1]
        u, v = solve(w, rank, keys, r_max=r_max, u0=theta["u"])
        return {"u": u, "v": v}

    def _use_rsvd(self, shape):
        # legacy-path policy only: with kernel dispatch OFF, "auto"
        # keeps the exact SVD below the 2048 threshold. Under dispatch,
        # "auto" means the batched randomized solver regardless of
        # shape (the documented ≤1e-4 relative-distortion budget) —
        # pass randomized=False to demand exactness everywhere.
        if self.randomized == "auto":
            return min(shape) > 2048
        return bool(self.randomized)

    def _svd(self, w, key=None):
        if self._use_rsvd(w.shape):
            if key is None:
                # direct scheme use outside the C-step engine: a fixed
                # deterministic seed (never the old shape-derived one —
                # equal-shaped matrices must not be forced to share a
                # sketch when the engine supplies real per-item keys)
                key = jax.random.PRNGKey(_SKETCH_SEED)
            return randomized_svd(w, self.rank, key)
        u, s, v = exact_svd(w)
        return u[:, :self.rank], s[:self.rank], v[:, :self.rank]

    def init(self, w, key=None):
        return self.compress(w, None, key=key)

    def compress(self, w, theta, mu=None, key=None):
        u, s, v = self._svd(w, key)
        rs = jnp.sqrt(s)
        return {"u": u * rs[None, :], "v": v * rs[None, :]}

    def decompress(self, theta):
        return theta["u"] @ theta["v"].T

    def bits(self, theta, float_bits: int = 32):
        return (theta["u"].size + theta["v"].size) * float_bits

    def flops(self, theta, orig_shape):
        m, n = orig_shape[-2], orig_shape[-1]
        return 2.0 * self.rank * (m + n)


class RankSelection(CompressionScheme):
    """Automatic rank selection per matrix (λ-weighted cost vs distortion).

    ``alpha`` is the paper's λ·α_l product for this matrix: the price (in
    distortion units, scaled by 2/μ internally) of one unit of C(r).
    """

    domain = "matrix"
    # batched matmul-only spectrum solver; α rides as a traced per-item
    # operand so tasks differing only in α pack into ONE group/launch.
    # Engages only when max_rank bounds the sketch (see batch_key).
    solver = "rank_select"
    solver_operands = ("alpha",)
    wants_key = True
    gspmd_safe = True

    @classmethod
    def contract_examples(cls):
        # max_rank bounds the sketch so the batched solver engages; the
        # unbounded variant covers the exact-spectrum vmap path
        return (cls(alpha=1e-3, max_rank=3), cls(alpha=1e-3))

    def __init__(self, alpha: float, cost: str = "storage",
                 max_rank: int | None = None):
        assert cost in ("storage", "flops")
        self.alpha = float(alpha)
        self.cost = cost
        self.max_rank = max_rank

    def group_key(self):
        return ("rank-selection", self.alpha, self.cost, self.max_rank)

    def batch_key(self):
        # unbounded selection (max_rank=None) needs the full spectrum —
        # keep the exact LAPACK path; a bounded max_rank gives the
        # batched solver its static sketch width. α drops out (operand).
        if self.max_rank is None:
            return None
        return ("rank-select", self.cost, self.max_rank)

    def batch_operands(self, n_items: int):
        return (jnp.full((n_items,), self.alpha, jnp.float32),)

    def compress_batched(self, solve, w, theta, operands, mu=None):
        assert mu is not None, "rank selection needs μ"
        alpha, keys = operands
        r_max = theta["u"].shape[-1]
        u, v, rank = solve(w, alpha, keys, mu, r_max=r_max,
                           cost=self.cost, u0=theta["u"])
        return {"u": u, "v": v, "rank": rank}

    def _rmax(self, shape):
        r = min(shape)
        return min(self.max_rank, r) if self.max_rank else r

    def _unit_cost(self, shape):
        m, n = shape
        if self.cost == "storage":
            return float(m + n)          # floats per unit rank
        return 2.0 * float(m + n)        # MACs per unit rank per example

    def init(self, w, key=None):
        return self.compress(w, None, mu=1e-6, key=key)

    def compress(self, w, theta, mu=None, key=None):
        assert mu is not None, "rank selection needs μ"
        m, n = w.shape
        rmax = self._rmax((m, n))
        u, s, v = exact_svd(w)
        u, s, v = u[:, :rmax], s[:rmax], v[:, :rmax]
        # tail energy: E(r) = Σ_{i>r} σ_i², r = 0..rmax
        s2 = s.astype(jnp.float32) ** 2
        tail = jnp.concatenate([jnp.cumsum(s2[::-1])[::-1],
                                jnp.zeros((1,), jnp.float32)])  # (rmax+1,)
        ranks = jnp.arange(rmax + 1, dtype=jnp.float32)
        total = self.alpha * self._unit_cost((m, n)) * ranks \
            + 0.5 * mu * tail
        r_star = jnp.argmin(total).astype(jnp.int32)
        mask = (jnp.arange(rmax) < r_star).astype(jnp.float32)
        rs = jnp.sqrt(s * mask)
        return {"u": u * rs[None, :], "v": v * rs[None, :], "rank": r_star}

    def decompress(self, theta):
        return theta["u"] @ theta["v"].T

    def bits(self, theta, float_bits: int = 32):
        """Storage at the *selected* rank: r·(m+n) floats for the live
        columns of U/V, plus ⌈log2(R+1)⌉ bits to store which r ∈ {0..R}
        was selected (the masked columns are zero and never stored).

        No ``float()`` host pull on ``theta["rank"]`` — it is a traced
        device scalar inside jitted reporting paths (and a host numpy
        scalar in ``compression_ratio``'s per-item loop); plain
        arithmetic works for both and jit callers get a 0-d array.
        """
        m = theta["u"].shape[0]
        n = theta["v"].shape[0]
        r_max = theta["u"].shape[1]
        rank_index_bits = math.ceil(math.log2(r_max + 1))
        return theta["rank"] * float((m + n) * float_bits) \
            + rank_index_bits

    def rank(self, theta) -> jnp.ndarray:
        return theta["rank"]

    def flops(self, theta, orig_shape):
        """Inference FLOPs at the selected rank — traced-safe like
        :meth:`bits` (no ``float()`` on the device scalar)."""
        m, n = orig_shape[-2], orig_shape[-1]
        return theta["rank"] * (2.0 * (m + n))
