"""Low-rank C steps (paper §4.3).

``LowRank(r)`` — truncated SVD to a fixed target rank.
``RankSelection(alpha, cost=...)`` — automatic per-matrix rank (Idelbayev &
Carreira-Perpiñán, CVPR'20 [17]): the C step minimizes
    λ·α·C(r) + μ/2·Σ_{i>r} σ_i²   over r ∈ {0..R},
with C(r) = r·(m+n) (storage floats) or C(r) = r·(m+n) MAC-scaled (FLOPs).
Because the selected rank changes across C steps, Θ keeps fixed shapes
(U: (m,R), V: (n,R)) plus an integer rank; columns ≥ r are masked to zero —
this keeps every C step jit-compatible on TPU.

For large matrices a randomized range finder (Halko et al.) replaces the
exact SVD: the only O(m·n·R) work is two tall matmuls, which GSPMD shards.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.schemes.base import CompressionScheme


def randomized_svd(w: jnp.ndarray, r: int, key: jax.Array,
                   oversample: int = 8, power_iters: int = 2):
    """Rank-r randomized SVD. Returns (U (m,r), s (r,), V (n,r))."""
    m, n = w.shape
    k = min(r + oversample, min(m, n))
    omega = jax.random.normal(key, (n, k), dtype=jnp.float32)
    y = w.astype(jnp.float32) @ omega
    for _ in range(power_iters):
        y, _ = jnp.linalg.qr(y)
        y = w.astype(jnp.float32) @ (w.astype(jnp.float32).T @ y)
    q, _ = jnp.linalg.qr(y)                      # (m, k)
    b = q.T @ w.astype(jnp.float32)              # (k, n)
    ub, s, vt = jnp.linalg.svd(b, full_matrices=False)
    u = q @ ub
    return u[:, :r], s[:r], vt[:r, :].T


def exact_svd(w: jnp.ndarray):
    u, s, vt = jnp.linalg.svd(w.astype(jnp.float32), full_matrices=False)
    return u, s, vt.T


class LowRank(CompressionScheme):
    """W ≈ U Vᵀ with fixed target rank (Θ = (U√s, V√s))."""

    domain = "matrix"

    def __init__(self, target_rank: int, randomized: str = "auto"):
        assert target_rank >= 1
        self.rank = int(target_rank)
        self.randomized = randomized

    def group_key(self):
        # `randomized="auto"` resolves per item shape, but grouped items
        # share a shape, so the key stays static within any group.
        return ("lowrank", self.rank, self.randomized)

    def _use_rsvd(self, shape):
        if self.randomized == "auto":
            return min(shape) > 2048
        return bool(self.randomized)

    def _svd(self, w):
        if self._use_rsvd(w.shape):
            key = jax.random.PRNGKey(w.shape[0] * 7919 + w.shape[1])
            return randomized_svd(w, self.rank, key)
        u, s, v = exact_svd(w)
        return u[:, :self.rank], s[:self.rank], v[:, :self.rank]

    def init(self, w, key=None):
        return self.compress(w, None)

    def compress(self, w, theta, mu=None):
        u, s, v = self._svd(w)
        rs = jnp.sqrt(s)
        return {"u": u * rs[None, :], "v": v * rs[None, :]}

    def decompress(self, theta):
        return theta["u"] @ theta["v"].T

    def bits(self, theta, float_bits: int = 32):
        return (theta["u"].size + theta["v"].size) * float_bits

    def flops(self, theta, orig_shape):
        m, n = orig_shape[-2], orig_shape[-1]
        return 2.0 * self.rank * (m + n)


class RankSelection(CompressionScheme):
    """Automatic rank selection per matrix (λ-weighted cost vs distortion).

    ``alpha`` is the paper's λ·α_l product for this matrix: the price (in
    distortion units, scaled by 2/μ internally) of one unit of C(r).
    """

    domain = "matrix"

    def __init__(self, alpha: float, cost: str = "storage",
                 max_rank: int | None = None):
        assert cost in ("storage", "flops")
        self.alpha = float(alpha)
        self.cost = cost
        self.max_rank = max_rank

    def group_key(self):
        return ("rank-selection", self.alpha, self.cost, self.max_rank)

    def _rmax(self, shape):
        r = min(shape)
        return min(self.max_rank, r) if self.max_rank else r

    def _unit_cost(self, shape):
        m, n = shape
        if self.cost == "storage":
            return float(m + n)          # floats per unit rank
        return 2.0 * float(m + n)        # MACs per unit rank per example

    def init(self, w, key=None):
        return self.compress(w, None, mu=1e-6)

    def compress(self, w, theta, mu=None):
        assert mu is not None, "rank selection needs μ"
        m, n = w.shape
        rmax = self._rmax((m, n))
        u, s, v = exact_svd(w)
        u, s, v = u[:, :rmax], s[:rmax], v[:, :rmax]
        # tail energy: E(r) = Σ_{i>r} σ_i², r = 0..rmax
        s2 = s.astype(jnp.float32) ** 2
        tail = jnp.concatenate([jnp.cumsum(s2[::-1])[::-1],
                                jnp.zeros((1,), jnp.float32)])  # (rmax+1,)
        ranks = jnp.arange(rmax + 1, dtype=jnp.float32)
        total = self.alpha * self._unit_cost((m, n)) * ranks \
            + 0.5 * mu * tail
        r_star = jnp.argmin(total).astype(jnp.int32)
        mask = (jnp.arange(rmax) < r_star).astype(jnp.float32)
        rs = jnp.sqrt(s * mask)
        return {"u": u * rs[None, :], "v": v * rs[None, :], "rank": r_star}

    def decompress(self, theta):
        return theta["u"] @ theta["v"].T

    def bits(self, theta, float_bits: int = 32):
        """Storage at the *selected* rank: r·(m+n) floats for the live
        columns of U/V, plus ⌈log2(R+1)⌉ bits to store which r ∈ {0..R}
        was selected (the masked columns are zero and never stored)."""
        m = theta["u"].shape[0]
        n = theta["v"].shape[0]
        r_max = theta["u"].shape[1]
        rank_index_bits = math.ceil(math.log2(r_max + 1))
        return float(theta["rank"]) * (m + n) * float_bits \
            + rank_index_bits

    def rank(self, theta) -> jnp.ndarray:
        return theta["rank"]

    def flops(self, theta, orig_shape):
        m, n = orig_shape[-2], orig_shape[-1]
        return 2.0 * float(theta["rank"]) * (m + n)
