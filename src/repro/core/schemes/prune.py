"""Pruning C steps (paper §4.2).

Constraint forms (ℓ0: keep top-κ by magnitude; ℓ1: project onto the ℓ1
ball) and penalty forms (ℓ0: hard threshold at √(2α/μ); ℓ1: soft threshold
at α/μ). Penalty forms depend on the current μ, which the LC driver passes
into ``compress``.

Θ is the dense projected vector θ (same shape as w; zeros encode the
pruned support). ``bits`` accounts for sparse storage: κ·(value + index)
bits.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.core.schemes.base import CompressionScheme


def topk_magnitude_mask(w: jnp.ndarray, kappa: int) -> jnp.ndarray:
    """Boolean mask keeping *exactly* min(κ, w.size) largest |w|.

    Ties at the κ-th magnitude are broken toward the lower index
    (``lax.top_k`` order). A threshold mask (``|w| >= kth``) keeps the
    whole tied class — on tie-heavy leaves (e.g. mamba ``A_log``, whose
    init repeats each value per row) that makes θ infeasible
    (‖θ‖₀ ≫ κ), under-reports the C-step distortion, and falsifies the
    κ-nonzero ``bits()`` accounting; the §7 monitor then flags a
    distortion *increase* on the first C step after the ties break.
    """
    a = jnp.abs(w.ravel())
    idx = jax.lax.top_k(a, min(int(kappa), a.size))[1]
    mask = jnp.zeros(a.shape, bool).at[idx].set(True)
    return mask.reshape(w.shape)


def project_l1_ball(w: jnp.ndarray, radius: float) -> jnp.ndarray:
    """Euclidean projection of w onto {θ : ‖θ‖₁ ≤ radius} (Duchi et al.)."""
    a = jnp.abs(w.ravel()).astype(jnp.float32)
    total = jnp.sum(a)

    def _project(_):
        u = jnp.sort(a)[::-1]
        cs = jnp.cumsum(u)
        r = jnp.arange(1, a.size + 1, dtype=jnp.float32)
        cond = u * r > (cs - radius)
        rho = jnp.max(jnp.where(cond, r, 0.0))
        cs_rho = jnp.sum(jnp.where(r <= rho, u, 0.0))
        tau = (cs_rho - radius) / jnp.maximum(rho, 1.0)
        return jnp.sign(w) * jnp.maximum(jnp.abs(w) - tau, 0.0)

    return jax.lax.cond(total <= radius, lambda _: w, _project, None)


class ConstraintL0Pruning(CompressionScheme):
    """s.t. ‖θ‖₀ ≤ κ — keep the κ largest-magnitude weights (eq. 4)."""

    domain = "vector"
    # batched top-κ solver (threshold bisection on TPU) in the kernel
    # dispatch registry. κ is deliberately NOT in batch_key(): it rides
    # along as a traced per-item operand, so tasks that differ only in
    # κ pack into ONE kernel launch (mixed-κ grouping) — under the
    # vmap path they can't group at all, κ being baked into the trace.
    solver = "topk_mask"
    solver_operands = ("kappa",)

    def __init__(self, kappa: int):
        assert kappa >= 1
        self.kappa = int(kappa)

    @classmethod
    def contract_examples(cls):
        return (cls(kappa=4),)

    def group_key(self):
        return ("prune-l0", self.kappa)

    def batch_key(self):
        return ("prune-l0",)

    def batch_operands(self, n_items: int):
        return (jnp.full((n_items,), self.kappa, jnp.int32),)

    def init(self, w, key=None):
        return self.compress(w, None)

    def compress(self, w, theta, mu=None):
        mask = topk_magnitude_mask(w, self.kappa)
        return {"theta": jnp.where(mask, w, 0.0)}

    def compress_batched(self, solve, w, theta, operands, mu=None):
        (kappa,) = operands
        return {"theta": solve(w, kappa)}

    def decompress(self, theta):
        return theta["theta"]

    def bits(self, theta, float_bits: int = 32):
        p = theta["theta"].size
        return self.kappa * (float_bits + math.ceil(math.log2(max(p, 2))))


class ConstraintL1Pruning(CompressionScheme):
    """s.t. ‖θ‖₁ ≤ κ — projection onto the ℓ1 ball."""

    domain = "vector"
    # batched sort+cumsum projection in the dispatch registry (ROADMAP
    # "Solver coverage"); the ball radius κ rides as a traced per-item
    # operand, so tasks differing only in κ share one launch.
    solver = "project_l1_ball"
    solver_operands = ("radius",)

    def __init__(self, kappa: float):
        self.kappa = float(kappa)

    @classmethod
    def contract_examples(cls):
        return (cls(kappa=1.0),)

    def group_key(self):
        return ("prune-l1", self.kappa)

    def batch_key(self):
        return ("prune-l1",)

    def batch_operands(self, n_items: int):
        return (jnp.full((n_items,), self.kappa, jnp.float32),)

    def init(self, w, key=None):
        return self.compress(w, None)

    def compress(self, w, theta, mu=None):
        return {"theta": project_l1_ball(w, self.kappa)}

    def compress_batched(self, solve, w, theta, operands, mu=None):
        (radius,) = operands
        return {"theta": solve(w, radius)}

    def decompress(self, theta):
        return theta["theta"]

    def bits(self, theta, float_bits: int = 32):
        p = theta["theta"].size
        nnz = int(p)  # upper bound; exact nnz is data-dependent
        return nnz * float_bits

    def nnz(self, theta) -> jnp.ndarray:
        return jnp.sum(theta["theta"] != 0)


class PenaltyL0Pruning(CompressionScheme):
    """min L(w) + α‖w‖₀ — C step hard-thresholds at √(2α/μ)."""

    domain = "vector"

    def __init__(self, alpha: float):
        self.alpha = float(alpha)

    @classmethod
    def contract_examples(cls):
        return (cls(alpha=1e-3),)

    def group_key(self):
        return ("prune-penalty-l0", self.alpha)

    def init(self, w, key=None):
        # At init μ→0⁺ would prune everything; use the direct projection
        # with μ = μ0 supplied later — start from w itself (no pruning).
        return {"theta": w}

    def compress(self, w, theta, mu=None):
        assert mu is not None, "penalty pruning needs μ"
        t = jnp.sqrt(2.0 * self.alpha / mu)
        return {"theta": jnp.where(jnp.abs(w) > t, w, 0.0)}

    def decompress(self, theta):
        return theta["theta"]

    def bits(self, theta, float_bits: int = 32):
        p = theta["theta"].size
        return p * float_bits  # data-dependent; report via nnz()

    def nnz(self, theta) -> jnp.ndarray:
        return jnp.sum(theta["theta"] != 0)


class PenaltyL1Pruning(CompressionScheme):
    """min L(w) + α‖w‖₁ — C step soft-thresholds at α/μ."""

    domain = "vector"
    # batched prox in the dispatch registry; α rides as a traced
    # per-item operand, so mixed-α penalty tasks share one launch.
    solver = "soft_threshold"
    solver_operands = ("alpha",)

    def __init__(self, alpha: float):
        self.alpha = float(alpha)

    @classmethod
    def contract_examples(cls):
        return (cls(alpha=1e-3),)

    def group_key(self):
        return ("prune-penalty-l1", self.alpha)

    def batch_key(self):
        return ("prune-penalty-l1",)

    def batch_operands(self, n_items: int):
        return (jnp.full((n_items,), self.alpha, jnp.float32),)

    def init(self, w, key=None):
        return {"theta": w}

    def compress(self, w, theta, mu=None):
        assert mu is not None, "penalty pruning needs μ"
        t = self.alpha / mu
        return {"theta": jnp.sign(w) * jnp.maximum(jnp.abs(w) - t, 0.0)}

    def compress_batched(self, solve, w, theta, operands, mu=None):
        assert mu is not None, "penalty pruning needs μ"
        (alpha,) = operands
        return {"theta": solve(w, alpha, mu)}

    def decompress(self, theta):
        return theta["theta"]

    def bits(self, theta, float_bits: int = 32):
        return theta["theta"].size * float_bits

    def nnz(self, theta) -> jnp.ndarray:
        return jnp.sum(theta["theta"] != 0)
