"""Additive combinations of compressions (paper §4, Table 1 bottom).

Δ(Θ₁,…,Θ_S) = Σ_s Δ_s(Θ_s); the C step
    min ‖w − Σ_s Δ_s(Θ_s)‖²
is solved by alternating projections: each sub-scheme projects the current
residual, which monotonically decreases the joint distortion (each inner
step is an exact partial minimization).

Sub-schemes may live in different domains: vector-domain sub-schemes see
the flattened residual, matrix-domain ones see it reshaped — the view
passes the original (matrix) shape when any sub-scheme needs it.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core.schemes.base import CompressionScheme


class AdditiveCombination(CompressionScheme):
    def __init__(self, schemes: list[CompressionScheme], iters: int = 3):
        assert len(schemes) >= 2
        self.schemes = list(schemes)
        self.iters = int(iters)
        # domain: "matrix" if any sub-scheme needs matrices, else "vector"
        self.domain = ("matrix" if any(s.domain == "matrix" for s in schemes)
                       else "vector")

    @classmethod
    def contract_examples(cls):
        # imports live here, not at module top: base-class machinery
        # must not pull sibling scheme modules into an import cycle
        from repro.core.schemes.prune import ConstraintL0Pruning
        from repro.core.schemes.quantize import AdaptiveQuantization
        return (cls([AdaptiveQuantization(k=2, iters=2),
                     ConstraintL0Pruning(kappa=4)], iters=2),)

    def group_key(self):
        subs = tuple(s.group_key() for s in self.schemes)
        if any(k is None for k in subs):
            return None
        return ("additive", self.iters, subs)

    def init_key(self):
        # compose sub-scheme init identities: a sub-scheme whose init
        # differs (DP warm start) must split the additive init group too
        subs = tuple(s.init_key() for s in self.schemes)
        if any(k is None for k in subs):
            return None
        return ("additive-init", self.iters, subs)

    def _to_domain(self, x, scheme):
        if scheme.domain == "vector" and x.ndim != 1:
            return x.ravel()
        return x

    def _from_domain(self, x, shape):
        return x.reshape(shape)

    def init(self, w, key=None):
        thetas = []
        resid = w
        for s in self.schemes:
            th = s.init(self._to_domain(resid, s), key=key)
            thetas.append(th)
            resid = resid - self._from_domain(
                s.decompress(th), w.shape)
        return {"parts": thetas}

    def compress(self, w, theta, mu=None):
        thetas = list(theta["parts"])
        for _ in range(self.iters):
            for i, s in enumerate(self.schemes):
                others = sum(
                    (self._from_domain(self.schemes[j].decompress(thetas[j]),
                                       w.shape)
                     for j in range(len(self.schemes)) if j != i),
                    jnp.zeros_like(w))
                resid = w - others
                try:
                    thetas[i] = s.compress(self._to_domain(resid, s),
                                           thetas[i], mu=mu)
                except TypeError:
                    thetas[i] = s.compress(self._to_domain(resid, s),
                                           thetas[i])
        return {"parts": thetas}

    def decompress(self, theta):
        parts = theta["parts"]
        out = None
        shape = None
        # decompress in matrix domain if available, else vector
        for s, th in zip(self.schemes, parts):
            d = s.decompress(th)
            if d.ndim > 1:
                shape = d.shape
        for s, th in zip(self.schemes, parts):
            d = s.decompress(th)
            if shape is not None:
                d = d.reshape(shape)
            out = d if out is None else out + d
        return out

    def bits(self, theta, float_bits: int = 32):
        return sum(s.bits(th, float_bits)
                   for s, th in zip(self.schemes, theta["parts"]))
