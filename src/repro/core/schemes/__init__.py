from repro.core.schemes.base import (
    CompressionScheme, add_leading_axis, drop_leading_axis, pack_thetas,
    pack_thetas_padded, slice_theta_like, unpack_thetas)
from repro.core.schemes.quantize import (
    AdaptiveQuantization, Binarize, Ternarize, kmeans_1d, quantile_init,
    optimal_codebook_dp)
from repro.core.schemes.prune import (
    ConstraintL0Pruning, ConstraintL1Pruning, PenaltyL0Pruning,
    PenaltyL1Pruning, topk_magnitude_mask, project_l1_ball)
from repro.core.schemes.lowrank import (
    LowRank, RankSelection, randomized_svd, exact_svd)
from repro.core.schemes.additive import AdditiveCombination

__all__ = [
    "CompressionScheme", "add_leading_axis", "drop_leading_axis",
    "pack_thetas", "pack_thetas_padded", "slice_theta_like",
    "unpack_thetas",
    "AdaptiveQuantization", "Binarize", "Ternarize",
    "kmeans_1d", "quantile_init", "optimal_codebook_dp",
    "ConstraintL0Pruning", "ConstraintL1Pruning", "PenaltyL0Pruning",
    "PenaltyL1Pruning", "topk_magnitude_mask", "project_l1_ball",
    "LowRank", "RankSelection", "randomized_svd", "exact_svd",
    "AdditiveCombination",
]
