"""Base class for C-step compression schemes.

A scheme operates on a *compressible array* produced by a view
(`core.views`): either a 1-D vector, a single 2-D matrix, or a stack of
matrices ``(L, m, n)`` / vectors ``(L, p)`` (the scheme is vmapped over the
leading axis by the view machinery when ``per_item=True``).

Every method is jit-compatible and sharding-preserving: schemes receive
jnp arrays (possibly sharded), return pytrees of jnp arrays, and use only
``jnp`` / ``lax`` ops so GSPMD can partition the C step.

The key contract (paper §3):
    decompress(compress(w, theta_prev)) is the L2 projection of ``w`` onto
    the scheme's feasible set — distortion ``‖w − Δ(Θ)‖²`` must never
    increase across C steps (paper §7 "practical advice" monitors this; our
    tests enforce it).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Theta = Any  # scheme-specific pytree


class CompressionScheme:
    """Abstract C step: Π(w) = argmin_Θ ‖w − Δ(Θ)‖²."""

    #: "vector" | "matrix" — what the view must produce.
    domain: str = "vector"

    #: name of a batched solver in the kernel dispatch registry
    #: (``repro.kernels.dispatch``), or None — the scheme then always
    #: runs as a vmapped per-item program. Declaring a name is a claim
    #: that :meth:`compress_batched` reproduces :meth:`compress` for
    #: every item of a packed stack.
    solver: str | None = None

    #: whether the C-step engine threads a *per-item PRNG key* into the
    #: scheme's solves (stochastic C steps: randomized-SVD sketches).
    #: When True, :meth:`compress` and :meth:`init` must accept a
    #: ``key=`` kwarg, and the grouped engine appends a packed
    #: ``(n_items, 2)`` uint32 key array as the LAST entry of the
    #: ``operands`` tuple handed to :meth:`compress_batched`. Keys are
    #: derived per (task name, within-task item index) —
    #: ``CompressionTask.item_keys`` — so they are identical on the
    #: grouped and per-task dispatch paths, stable across reruns, and
    #: never shared between packed items.
    wants_key: bool = False

    #: whether this scheme's *batched solver* lowers to ops with SPMD
    #: partitioning rules only (matmuls/elementwise — no LAPACK custom
    #: call). Under a mesh, such a group's packed item axis shards with
    #: plain GSPMD constraints instead of the shard_map custom-call
    #: workaround (docs/architecture.md). Only consulted on the kernel
    #: dispatch path; the vmap fallback always keeps the workaround.
    gspmd_safe: bool = False

    #: machine-readable half of the solver calling convention: the
    #: parameter names, in order, that this scheme's
    #: :meth:`batch_operands` arrays bind to in the registered solver's
    #: signature (``repro.kernels.dispatch.solver_signature``). The
    #: engine never reads this — it exists so the lint contract layer
    #: can verify the declaration against the registry without running
    #: anything (``wants_key`` adds an implicit trailing ``"keys"``).
    #: A scheme with a ``solver`` must name one entry per operand.
    solver_operands: tuple[str, ...] = ()

    def init(self, w: jnp.ndarray, key=None) -> Theta:
        """Direct compression Θ^DC = Π(w) used to initialize the LC loop."""
        raise NotImplementedError

    def compress(self, w: jnp.ndarray, theta: Theta, mu=None) -> Theta:
        """One C step, warm-started at the previous Θ.

        ``mu`` is the current penalty parameter — only penalty-form schemes
        (ℓ0/ℓ1 penalties, rank selection) use it; projection-form schemes
        ignore it.
        """
        raise NotImplementedError

    def decompress(self, theta: Theta) -> jnp.ndarray:
        """Δ(Θ) → dense array with the view's compressible shape."""
        raise NotImplementedError

    def bits(self, theta: Theta, float_bits: int = 32) -> float:
        """Storage cost of Θ in bits (for compression-ratio accounting)."""
        raise NotImplementedError

    def flops(self, theta: Theta, orig_shape: tuple[int, ...]) -> float:
        """Inference FLOPs of a matmul against the compressed form.

        Defaults to the dense cost; low-rank/pruning override.
        ``orig_shape`` is the (m, n) of the uncompressed matrix.
        """
        m, n = orig_shape[-2], orig_shape[-1]
        return 2.0 * m * n

    # ------------------------------------------------------------------
    def group_key(self) -> tuple | None:
        """Static identity for grouped C-step dispatch (`core.grouping`).

        Tasks whose schemes return equal, hashable keys — and whose views
        produce items of the same shape/dtype — are stacked along a
        leading axis and solved by ONE vmapped ``compress`` call inside
        the single jitted C step. The key must therefore capture every
        hyperparameter that changes the traced computation (κ, K, rank,
        α, iteration counts, …).

        Return ``None`` (the default) to opt out: the task then runs on
        the per-task path even when grouping is enabled — the escape
        hatch for exotic schemes whose compress is not vmappable.
        """
        return None

    def init_key(self) -> tuple | None:
        """Static identity for grouped *init* dispatch (`grouped_init`).

        Defaults to :meth:`group_key`, which only has to cover
        ``compress``-changing hyperparameters. A scheme whose ``init``
        depends on extra hyperparameters (e.g. a DP warm start that
        ``compress`` never reads) must extend this key with them, or
        ``grouped_init`` would solve the group with ``group[0]``'s
        init settings. ``None`` keeps init on the per-task path.
        """
        return self.group_key()

    # ------------------------------------------------------------------
    # Batched kernel dispatch (see ``repro.kernels.dispatch`` and
    # ``core/grouping.py``). A scheme opts in by setting ``solver`` and
    # implementing ``compress_batched``; everything else has working
    # defaults.
    # ------------------------------------------------------------------
    def batch_key(self) -> tuple | None:
        """Static identity for *kernel-dispatched* grouping.

        Defaults to :meth:`group_key`. A scheme that moves a
        hyperparameter out of the trace and into a per-item operand
        (:meth:`batch_operands`) overrides this to drop it from the
        key — e.g. ℓ0 pruning drops κ, so tasks differing only in κ
        pack into one kernel launch (mixed-κ grouping). Must still
        capture every hyperparameter that *does* change the batched
        program (K, iteration counts, …).
        """
        return self.group_key()

    def batch_operands(self, n_items: int) -> tuple:
        """Per-item operand arrays (leading axis ``n_items``) passed to
        :meth:`compress_batched` — the packed form of hyperparameters
        dropped from :meth:`batch_key`. Default: none."""
        return ()

    def compress_batched(self, solve, w: jnp.ndarray, theta: Theta,
                         operands: tuple, mu=None) -> Theta:
        """Whole-group C step: one call solves a packed item stack.

        ``solve`` is the resolved implementation of :attr:`solver` for
        the active backend; ``w`` is ``(n_items, *item_shape)``;
        ``theta`` carries the same leading axis; ``operands`` is the
        group-concatenated result of :meth:`batch_operands`. Must be
        numerically equivalent to vmapping :meth:`compress` — bit-equal
        on the jnp backend, documented tolerance on kernel backends —
        unless the scheme documents a deliberate algorithm switch and
        an opt-out (``LowRank``'s batched solver is the randomized SVD
        at a stated 1e-4 relative-distortion budget;
        ``randomized=False`` keeps the exact path and disables
        dispatch).
        """
        raise NotImplementedError

    def kernel_dispatch_ready(self) -> bool:
        """Whether the dispatch layer may replace ``vmap(compress)``
        with :meth:`compress_batched` for this scheme instance.

        Requires an opted-in solver and a groupable :meth:`batch_key`.
        Two safety rails: ``group_key() is None`` (the documented
        "fully custom scheme" escape hatch) opts out of kernel dispatch
        too, even when a parent class declares a batched ``batch_key``;
        and the class providing the active ``compress`` must also stand
        behind ``compress_batched`` — a subclass that overrides
        ``compress`` but inherits ``compress_batched`` would silently
        run the parent's math, so it falls back to the vmap path
        instead.
        """
        if (self.solver is None or self.group_key() is None
                or self.batch_key() is None):
            return False

        def provider(name):
            for c in type(self).__mro__:
                if name in c.__dict__:
                    return c
            return None

        cp, cbp = provider("compress"), provider("compress_batched")
        return (cbp is not None and cbp is not CompressionScheme
                and cp is not None and issubclass(cbp, cp))

    # ------------------------------------------------------------------
    @classmethod
    def contract_examples(cls) -> tuple["CompressionScheme", ...]:
        """Representative *instances* for static tooling.

        The lint contract layer (``repro.analysis.lint``) instantiates
        each scheme class to read its declared contract
        (``group_key``/``batch_key``/``batch_operands``/``init_key``)
        and to lower its grouped C step on toy shapes — without a real
        model. Subclasses with required constructor arguments override
        this with one or more cheap instances (small hyperparameters:
        lowering cost, not fidelity, is what matters); the default
        covers no-arg constructors and returns ``()`` when the class
        cannot be built bare (such a class is skipped, and the linter
        reports it as uncovered).
        """
        try:
            return (cls(),)
        except TypeError:
            return ()

    # ------------------------------------------------------------------
    def distortion(self, w: jnp.ndarray, theta: Theta) -> jnp.ndarray:
        """‖w − Δ(Θ)‖² — the C-step objective, used by monitors/tests."""
        d = w - self.decompress(theta)
        return jnp.sum(d.astype(jnp.float32) ** 2)

    @property
    def name(self) -> str:
        return type(self).__name__


# ----------------------------------------------------------------------
# Stacked-Θ packing: grouped dispatch concatenates per-task Θ pytrees
# along a leading item axis, vmaps the scheme over it, and slices the
# result back. Works for any Θ pytree (dicts, NamedTuples, …).
# ----------------------------------------------------------------------
def add_leading_axis(theta: Theta) -> Theta:
    """Θ for a single item → Θ with a length-1 leading item axis."""
    return jax.tree_util.tree_map(lambda x: x[None], theta)


def drop_leading_axis(theta: Theta) -> Theta:
    """Inverse of :func:`add_leading_axis` (leading axis must be 1)."""
    return jax.tree_util.tree_map(lambda x: x[0], theta)


def pack_thetas(thetas: list[Theta]) -> Theta:
    """Concatenate Θ pytrees (each carrying a leading item axis) along
    axis 0 — the stacked Θ a grouped vmapped C step consumes."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *thetas)


def pack_thetas_padded(thetas: list[Theta]) -> Theta:
    """:func:`pack_thetas` with *trailing-dim padding*: each leaf is
    zero-padded up to the per-leaf max trailing shape before the
    leading-axis concatenate.

    This is what lets tasks whose Θ leaves differ in a trailing dim —
    ``LowRank`` factors of different target ranks (``(m, r_i)`` →
    ``(m, R_max)``), mixed-K codebooks (``(K_i,)`` → ``(K_max,)``) —
    pack into ONE batched solver launch. The solver contract is that
    each item's live entries stay in the leading slots of the padded
    dim (masked factor columns / +inf codebook tails), so the grouped
    engine can slice every task's Θ back to its own shapes afterwards.
    A group with uniform trailing shapes pads nothing and is exactly
    :func:`pack_thetas`.
    """
    def cat(*xs):
        trail = tuple(max(x.shape[1 + d] for x in xs)
                      for d in range(xs[0].ndim - 1))

        def pad(x):
            pads = [(0, 0)] + [(0, t - s)
                               for s, t in zip(x.shape[1:], trail)]
            return jnp.pad(x, pads) if any(p for _, p in pads) else x

        return jnp.concatenate([pad(x) for x in xs], axis=0)

    return jax.tree_util.tree_map(cat, *thetas)


def slice_theta_like(theta: Theta, like: Theta) -> Theta:
    """Undo :func:`pack_thetas_padded`'s trailing-dim padding for one
    task: slice every leaf of ``theta`` down to ``like``'s trailing
    shape (leading item axis untouched)."""
    return jax.tree_util.tree_map(
        lambda new, old: new[(slice(None),)
                             + tuple(slice(0, s) for s in old.shape[1:])],
        theta, like)


def unpack_thetas(packed: Theta, counts: list[int]) -> list[Theta]:
    """Split a stacked Θ back into per-task Θs of ``counts`` items."""
    out, off = [], 0
    for n in counts:
        out.append(jax.tree_util.tree_map(
            lambda x, o=off, n=n: x[o:o + n], packed))
        off += n
    return out
