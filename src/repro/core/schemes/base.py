"""Base class for C-step compression schemes.

A scheme operates on a *compressible array* produced by a view
(`core.views`): either a 1-D vector, a single 2-D matrix, or a stack of
matrices ``(L, m, n)`` / vectors ``(L, p)`` (the scheme is vmapped over the
leading axis by the view machinery when ``per_item=True``).

Every method is jit-compatible and sharding-preserving: schemes receive
jnp arrays (possibly sharded), return pytrees of jnp arrays, and use only
``jnp`` / ``lax`` ops so GSPMD can partition the C step.

The key contract (paper §3):
    decompress(compress(w, theta_prev)) is the L2 projection of ``w`` onto
    the scheme's feasible set — distortion ``‖w − Δ(Θ)‖²`` must never
    increase across C steps (paper §7 "practical advice" monitors this; our
    tests enforce it).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

Theta = Any  # scheme-specific pytree


class CompressionScheme:
    """Abstract C step: Π(w) = argmin_Θ ‖w − Δ(Θ)‖²."""

    #: "vector" | "matrix" — what the view must produce.
    domain: str = "vector"

    def init(self, w: jnp.ndarray, key=None) -> Theta:
        """Direct compression Θ^DC = Π(w) used to initialize the LC loop."""
        raise NotImplementedError

    def compress(self, w: jnp.ndarray, theta: Theta, mu=None) -> Theta:
        """One C step, warm-started at the previous Θ.

        ``mu`` is the current penalty parameter — only penalty-form schemes
        (ℓ0/ℓ1 penalties, rank selection) use it; projection-form schemes
        ignore it.
        """
        raise NotImplementedError

    def decompress(self, theta: Theta) -> jnp.ndarray:
        """Δ(Θ) → dense array with the view's compressible shape."""
        raise NotImplementedError

    def bits(self, theta: Theta, float_bits: int = 32) -> float:
        """Storage cost of Θ in bits (for compression-ratio accounting)."""
        raise NotImplementedError

    def flops(self, theta: Theta, orig_shape: tuple[int, ...]) -> float:
        """Inference FLOPs of a matmul against the compressed form.

        Defaults to the dense cost; low-rank/pruning override.
        ``orig_shape`` is the (m, n) of the uncompressed matrix.
        """
        m, n = orig_shape[-2], orig_shape[-1]
        return 2.0 * m * n

    # ------------------------------------------------------------------
    def group_key(self) -> tuple | None:
        """Static identity for grouped C-step dispatch (`core.grouping`).

        Tasks whose schemes return equal, hashable keys — and whose views
        produce items of the same shape/dtype — are stacked along a
        leading axis and solved by ONE vmapped ``compress`` call inside
        the single jitted C step. The key must therefore capture every
        hyperparameter that changes the traced computation (κ, K, rank,
        α, iteration counts, …).

        Return ``None`` (the default) to opt out: the task then runs on
        the per-task path even when grouping is enabled — the escape
        hatch for exotic schemes whose compress is not vmappable.
        """
        return None

    # ------------------------------------------------------------------
    def distortion(self, w: jnp.ndarray, theta: Theta) -> jnp.ndarray:
        """‖w − Δ(Θ)‖² — the C-step objective, used by monitors/tests."""
        d = w - self.decompress(theta)
        return jnp.sum(d.astype(jnp.float32) ** 2)

    @property
    def name(self) -> str:
        return type(self).__name__


# ----------------------------------------------------------------------
# Stacked-Θ packing: grouped dispatch concatenates per-task Θ pytrees
# along a leading item axis, vmaps the scheme over it, and slices the
# result back. Works for any Θ pytree (dicts, NamedTuples, …).
# ----------------------------------------------------------------------
def add_leading_axis(theta: Theta) -> Theta:
    """Θ for a single item → Θ with a length-1 leading item axis."""
    return jax.tree_util.tree_map(lambda x: x[None], theta)


def drop_leading_axis(theta: Theta) -> Theta:
    """Inverse of :func:`add_leading_axis` (leading axis must be 1)."""
    return jax.tree_util.tree_map(lambda x: x[0], theta)


def pack_thetas(thetas: list[Theta]) -> Theta:
    """Concatenate Θ pytrees (each carrying a leading item axis) along
    axis 0 — the stacked Θ a grouped vmapped C step consumes."""
    return jax.tree_util.tree_map(
        lambda *xs: jnp.concatenate(xs, axis=0), *thetas)


def unpack_thetas(packed: Theta, counts: list[int]) -> list[Theta]:
    """Split a stacked Θ back into per-task Θs of ``counts`` items."""
    out, off = [], 0
    for n in counts:
        out.append(jax.tree_util.tree_map(
            lambda x, o=off, n=n: x[o:o + n], packed))
        off += n
    return out
