"""Quantization C steps (paper §4.1).

Adaptive codebook quantization is the scalar k-means problem (eq. 2). Two
solvers are provided:

* ``AdaptiveQuantization`` — Lloyd iterations, warm-started across C steps.
  The nearest-centroid assignment counts codebook midpoints below each
  weight (bit-identical to ``searchsorted``, but a fused compare-reduce
  that stays fast under vmap for grouped C steps); cluster moments are
  masked reductions rather than scatter-adds. O(P·K) fused compute, O(P)
  memory — *no materialized* (P, K) distance matrix, which matters at
  P ~ 10⁹ and keeps the C step sharding-friendly (the only cross-shard
  traffic is the K-sized cluster-moment reductions).
* ``optimal_codebook_dp`` — globally optimal 1-D quantizer via dynamic
  programming on a B-bin histogram (exact on the binned distribution;
  replaces the O(K·P²) exact DP of Bruce/Wu, see DESIGN.md §8.3).

Fixed-form schemes: ``Binarize`` into {−1,1} or {−c,c} (optimal scale
c = mean|w|), ``Ternarize`` into {−c,0,c} with jointly optimal support and
scale (sort + cumsum argmax, per Carreira-Perpiñán & Idelbayev 2017 [4]).
"""
from __future__ import annotations

from functools import partial
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.schemes.base import CompressionScheme


class QuantTheta(NamedTuple):
    codebook: jnp.ndarray  # (K,) float32
    assign: jnp.ndarray    # (P,) int32 — index into codebook


def _assign_nearest(w: jnp.ndarray, codebook: jnp.ndarray) -> jnp.ndarray:
    """Nearest-centroid assignment for a *sorted* 1-D codebook.

    Counting midpoints below each w is bit-identical to
    ``searchsorted(midpoints, w, side='left')`` (ties included) but is a
    fused K-way compare-reduce: no serial binary-search chain, and it
    batches cleanly under vmap (grouped C steps) — searchsorted's gather
    loop degrades ~2× when the haystack is batched.
    """
    midpoints = (codebook[1:] + codebook[:-1]) * 0.5
    return jnp.sum((w[..., None] > midpoints).astype(jnp.int32), axis=-1)


def _cluster_moments(w, assign, k: int):
    """Per-cluster (Σw, count) via masked reductions.

    XLA fuses the broadcast-compare-select into the reduce — O(P) memory
    like segment_sum, but ~5× faster on CPU (scatter-adds serialize) and
    vmap-neutral for the grouped C step.
    """
    onehot = assign[..., None, :] == jnp.arange(k, dtype=jnp.int32)[:, None]
    sums = jnp.sum(jnp.where(onehot, w[..., None, :], 0.0), axis=-1)
    counts = jnp.sum(onehot.astype(jnp.float32), axis=-1)
    return sums, counts


def _lloyd_update(w, codebook):
    """One Lloyd step: assign to nearest centroid, recompute means."""
    k = codebook.shape[0]
    assign = _assign_nearest(w, codebook)
    sums, counts = _cluster_moments(w, assign, k)
    new = jnp.where(counts > 0, sums / jnp.maximum(counts, 1.0), codebook)
    return jnp.sort(new)


def kmeans_1d(w: jnp.ndarray, codebook0: jnp.ndarray, iters: int = 25):
    """Scalar k-means with warm start; returns (codebook, assignments).

    Small static ``iters`` unrolls instead of lowering to ``lax.while``:
    XLA keeps cross-iteration fusion and (on CPU) intra-op threading,
    which a while body forfeits — measurably faster both per-task and
    under the grouped C step's vmap. Large ``iters`` falls back to
    ``fori_loop`` to keep program size (and compile time) bounded.
    """
    w = w.astype(jnp.float32)
    codebook = jnp.sort(codebook0)
    if iters <= 32:
        for _ in range(iters):
            codebook = _lloyd_update(w, codebook)
    else:
        codebook = jax.lax.fori_loop(
            0, iters, lambda _, c: _lloyd_update(w, c), codebook)
    return codebook, _assign_nearest(w, codebook)


def quantile_init(w: jnp.ndarray, k: int) -> jnp.ndarray:
    """Deterministic k-means init: K equally-spaced quantiles of w."""
    qs = (jnp.arange(k, dtype=jnp.float32) + 0.5) / k
    return jnp.quantile(w.astype(jnp.float32), qs)


class AdaptiveQuantization(CompressionScheme):
    """Learned codebook of size K via scalar k-means (paper eq. 2)."""

    domain = "vector"
    # batched Lloyd solver in the kernel dispatch registry: on TPU the
    # grouped C step runs one items-grid Pallas launch per group instead
    # of vmapping kmeans_1d (see kernels/dispatch.py; the jnp backend is
    # bit-identical to the vmap path)
    solver = "kmeans_lloyd"
    solver_operands = ("kvalid",)

    def __init__(self, k: int = 2, iters: int = 25, use_dp_init: bool = False,
                 dp_bins: int = 2048):
        assert k >= 2
        self.k = int(k)
        self.iters = int(iters)
        self.use_dp_init = bool(use_dp_init)
        self.dp_bins = int(dp_bins)

    def group_key(self):
        return ("quant-kmeans", self.k, self.iters)

    def batch_key(self):
        # K shapes the codebook arrays, so it can't be a plain operand
        # like κ — instead codebooks pad to the group K_max
        # (pack_thetas_padded) and K rides as the traced per-item
        # *valid-entry count*: tasks differing only in K pack into one
        # group and one launch (mixed-K grouping). iters still shapes
        # the traced Lloyd loop and stays in the key.
        return ("quant-kmeans", self.iters)

    def batch_operands(self, n_items: int):
        return (jnp.full((n_items,), self.k, jnp.int32),)

    @classmethod
    def contract_examples(cls):
        # tiny iters: the lint HLO layer lowers this, it never runs it
        return (cls(k=2, iters=2),)

    def init_key(self):
        # the DP warm start only changes init(), not compress(): keep it
        # out of group_key (C-step groups merge across it) but in the
        # init grouping identity (Θ^DC differs)
        return (*self.group_key(), self.use_dp_init, self.dp_bins)

    def init(self, w, key=None):
        if self.use_dp_init:
            cb = optimal_codebook_dp(w, self.k, bins=self.dp_bins)
        else:
            cb = quantile_init(w, self.k)
        cb, assign = kmeans_1d(w, cb, self.iters)
        return QuantTheta(cb, assign)

    def compress(self, w, theta: QuantTheta, mu=None):
        cb, assign = kmeans_1d(w, theta.codebook, self.iters)
        return QuantTheta(cb, assign)

    def compress_batched(self, solve, w, theta: QuantTheta, operands,
                         mu=None):
        """One solver call warm-starts every item's codebook at once
        (w (I, P), theta.codebook (I, K_max) padded to the group max,
        operands = (per-item live-entry counts,)). Padded entries are
        pinned to +inf inside the solver, so each item's live codebook
        stays in the leading slots for the per-task slice-back."""
        (kvalid,) = operands
        cb, assign = solve(w, theta.codebook, kvalid, iters=self.iters)
        return QuantTheta(cb, assign)

    def decompress(self, theta: QuantTheta):
        return theta.codebook[theta.assign]

    def bits(self, theta: QuantTheta, float_bits: int = 32):
        p = theta.assign.size
        import math
        return p * math.ceil(math.log2(self.k)) + self.k * float_bits


class Binarize(CompressionScheme):
    """{−1,1} (``scaled=False``) or {−c,c} with optimal c = mean|w|."""

    domain = "vector"

    def __init__(self, scaled: bool = True):
        self.scaled = bool(scaled)

    def group_key(self):
        return ("quant-binarize", self.scaled)

    def init(self, w, key=None):
        return self.compress(w, None)

    def compress(self, w, theta, mu=None):
        w = w.astype(jnp.float32)
        sign = jnp.where(w >= 0, jnp.int8(1), jnp.int8(-1))
        scale = jnp.mean(jnp.abs(w)) if self.scaled else jnp.float32(1.0)
        return {"sign": sign, "scale": scale}

    def decompress(self, theta):
        return theta["sign"].astype(jnp.float32) * theta["scale"]

    def bits(self, theta, float_bits: int = 32):
        return theta["sign"].size + (float_bits if self.scaled else 0)


class Ternarize(CompressionScheme):
    """{−c,0,c} with jointly optimal support and scale.

    For support size s over the s largest |w|, the distortion reduction is
    (Σ_{top-s} |w|)² / s; we maximize it over s in one sort + cumsum pass.
    """

    domain = "vector"

    def group_key(self):
        return ("quant-ternarize",)

    def init(self, w, key=None):
        return self.compress(w, None)

    def compress(self, w, theta, mu=None):
        w = w.astype(jnp.float32)
        a = jnp.abs(w)
        a_sorted = jnp.sort(a)[::-1]
        csum = jnp.cumsum(a_sorted)
        s_range = jnp.arange(1, a.size + 1, dtype=jnp.float32)
        gain = csum**2 / s_range
        s_star = jnp.argmax(gain)
        c = csum[s_star] / (s_star + 1.0)
        thresh = a_sorted[s_star]  # keep |w| >= a_sorted[s*] (s*+1 items)
        sign = jnp.where(
            a >= thresh, jnp.where(w >= 0, jnp.int8(1), jnp.int8(-1)),
            jnp.int8(0))
        return {"sign": sign, "scale": c}

    def decompress(self, theta):
        return theta["sign"].astype(jnp.float32) * theta["scale"]

    def bits(self, theta, float_bits: int = 32):
        return theta["sign"].size * 1.585 + float_bits


# ----------------------------------------------------------------------
# Globally optimal 1-D quantizer on a histogram (DP).
# ----------------------------------------------------------------------
@partial(jax.jit, static_argnames=("k", "bins"))
def optimal_codebook_dp(w: jnp.ndarray, k: int, bins: int = 2048):
    """Exact K-level scalar quantizer on a B-bin histogram of w.

    Cost of covering bins [i..j] with one level is the weighted SSE around
    the weighted mean; DP over levels with full (B, B) interval-cost matrix.
    O(K·B²) time, O(B²) memory — independent of P.
    """
    w = w.astype(jnp.float32).ravel()
    lo, hi = jnp.min(w), jnp.max(w)
    width = jnp.maximum(hi - lo, 1e-12)
    centers = lo + (jnp.arange(bins, dtype=jnp.float32) + 0.5) / bins * width
    idx = jnp.clip(((w - lo) / width * bins).astype(jnp.int32), 0, bins - 1)
    h0 = jax.ops.segment_sum(jnp.ones_like(w), idx, num_segments=bins)
    h1 = h0 * centers
    h2 = h0 * centers**2

    # prefix sums with leading zero: S[j] - S[i] = bins i..j-1
    z = jnp.zeros((1,), jnp.float32)
    s0, s1, s2 = (jnp.concatenate([z, jnp.cumsum(h)]) for h in (h0, h1, h2))

    def interval_cost(i, j):  # bins [i, j) — i, j broadcastable int arrays
        n = s0[j] - s0[i]
        m1 = s1[j] - s1[i]
        m2 = s2[j] - s2[i]
        return jnp.where(n > 0, m2 - m1**2 / jnp.maximum(n, 1.0), 0.0)

    ii = jnp.arange(bins + 1)
    cost = interval_cost(ii[:, None], ii[None, :])          # (B+1, B+1)
    cost = jnp.where(ii[:, None] <= ii[None, :], cost, jnp.inf)

    # E[j] = best cost of covering bins [0, j) with the current # of levels
    e = cost[0]                                              # 1 level
    big = jnp.float32(jnp.inf)

    def level(e_prev, _):
        # E_new[j] = min_i E_prev[i] + cost[i, j]
        tot = e_prev[:, None] + cost                          # (B+1, B+1)
        e_new = jnp.min(tot, axis=0)
        arg = jnp.argmin(tot, axis=0)
        return e_new, arg

    e_final, args = jax.lax.scan(level, e, None, length=k - 1)
    del big

    # Backtrack split points: start at j = B, walk levels k-1 .. 1.
    def back(j, level_args):
        i = level_args[j]
        return i, j

    js = [jnp.int32(bins)]
    j = jnp.int32(bins)
    for lvl in range(k - 2, -1, -1):
        j = args[lvl][j]
        js.append(j)
    js = jnp.stack(js[::-1])  # (k,) right edges ascending, js[-1] = B
    lefts = jnp.concatenate([jnp.zeros((1,), jnp.int32), js[:-1]])

    n = s0[js] - s0[lefts]
    m1 = s1[js] - s1[lefts]
    cb = jnp.where(n > 0, m1 / jnp.maximum(n, 1.0), centers[jnp.clip(lefts, 0, bins - 1)])
    return jnp.sort(cb)
