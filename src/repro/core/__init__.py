"""The paper's contribution: the LC algorithm as a composable JAX module."""
from repro.core.algorithm import (
    LCAlgorithm, LCMetrics, exponential_mu_schedule)
from repro.core.tasks import (
    CompressionTask, flatten_params, get_path, set_path)
from repro.core.views import AsVector, AsIs, AsMatrix, AsStacked
from repro.core.penalty import lc_penalty, lc_penalty_grad_refs
from repro.core.grouping import build_groups, describe_groups
from repro.core import schemes

__all__ = [
    "LCAlgorithm", "LCMetrics", "exponential_mu_schedule",
    "CompressionTask", "flatten_params", "get_path", "set_path",
    "AsVector", "AsIs", "AsMatrix", "AsStacked",
    "lc_penalty", "lc_penalty_grad_refs", "schemes",
    "build_groups", "describe_groups",
]
