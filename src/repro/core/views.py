"""Compression views (paper §5, "compression tasks").

A view adapts a subset of model parameters to the array domain a scheme
expects, and scatters the decompressed result back:

* ``AsVector``  — flatten + concatenate all selected leaves into one 1-D
  vector (e.g. one codebook shared across several layers).
* ``AsIs``      — a single 2-D leaf used directly as a matrix.
* ``AsMatrix``  — a single leaf reshaped to 2-D (merge all but last dim).
* ``AsStacked`` — a single leaf with a leading stack axis (scanned layer
  stacks ``(L, ...)`` or expert stacks ``(E, ...)``); the scheme is vmapped
  over axis 0, giving per-layer/per-expert codebooks, ranks, or supports.
  ``domain`` controls whether each item is flattened ("vector") or
  reshaped to a matrix ("matrix").

Views are pure reshaping: ``from_compressible(to_compressible(x)) == x``.
"""
from __future__ import annotations

import numpy as np
import jax.numpy as jnp


class View:
    #: whether the compressible array carries a leading vmapped stack axis
    stacked: bool = False

    def to_compressible(self, leaves: list[jnp.ndarray]) -> jnp.ndarray:
        raise NotImplementedError

    def from_compressible(self, arr: jnp.ndarray,
                          templates: list) -> list[jnp.ndarray]:
        raise NotImplementedError

    # ---- item protocol (grouped C-step dispatch, `core.grouping`) ----
    # A compressible array is a stack of *items*: stacked views carry
    # their own leading item axis; single-array views are one item. The
    # grouped engine concatenates items from shape-compatible tasks and
    # vmaps the scheme once over the combined stack.
    def to_items(self, arr: jnp.ndarray) -> jnp.ndarray:
        """Compressible array → (n_items, *item_shape)."""
        return arr if self.stacked else arr[None]

    def from_items(self, items: jnp.ndarray) -> jnp.ndarray:
        """Inverse of :meth:`to_items`."""
        return items if self.stacked else items[0]

    def item_count(self, arr) -> int:
        return int(arr.shape[0]) if self.stacked else 1

    def item_shape(self, arr) -> tuple:
        return tuple(arr.shape[1:]) if self.stacked else tuple(arr.shape)


class AsVector(View):
    def to_compressible(self, leaves):
        return jnp.concatenate([l.ravel().astype(jnp.float32)
                                for l in leaves])

    def from_compressible(self, arr, templates):
        sizes = [int(np.prod(t.shape)) for t in templates]
        offs = np.cumsum([0] + sizes)
        return [arr[offs[i]:offs[i + 1]].reshape(templates[i].shape)
                .astype(templates[i].dtype)
                for i in range(len(templates))]


class AsIs(View):
    def to_compressible(self, leaves):
        assert len(leaves) == 1, "AsIs views exactly one parameter"
        (l,) = leaves
        assert l.ndim == 2, f"AsIs needs a 2-D matrix, got {l.shape}"
        return l.astype(jnp.float32)

    def from_compressible(self, arr, templates):
        return [arr.reshape(templates[0].shape).astype(templates[0].dtype)]


class AsMatrix(View):
    """Reshape one leaf to (prod(leading dims), last dim)."""

    def to_compressible(self, leaves):
        assert len(leaves) == 1, "AsMatrix views exactly one parameter"
        (l,) = leaves
        return l.reshape(-1, l.shape[-1]).astype(jnp.float32)

    def from_compressible(self, arr, templates):
        return [arr.reshape(templates[0].shape).astype(templates[0].dtype)]


class AsStacked(View):
    """Leading axis = stack (layers/experts); scheme is vmapped over it.

    ``stack_ndim`` merges that many leading axes into the stack: a scanned
    MoE leaf ``(L, E, m, n)`` with ``stack_ndim=2`` becomes ``L·E`` items —
    per-(layer, expert) codebooks/ranks/supports — instead of ``L`` items
    of flattened expert blocks. The default (1) is the historical behavior.
    """

    stacked = True

    def __init__(self, domain: str = "vector", stack_ndim: int = 1):
        assert domain in ("vector", "matrix")
        assert stack_ndim >= 1
        self.domain = domain
        self.stack_ndim = int(stack_ndim)

    def to_compressible(self, leaves):
        assert len(leaves) == 1, "AsStacked views exactly one parameter"
        (l,) = leaves
        k = self.stack_ndim
        assert l.ndim >= k + 1, \
            f"AsStacked(stack_ndim={k}) needs ndim>{k}, got {l.shape}"
        n = int(np.prod(l.shape[:k]))
        if self.domain == "vector":
            return l.reshape(n, -1).astype(jnp.float32)
        return l.reshape(n, -1, l.shape[-1]).astype(jnp.float32)

    def from_compressible(self, arr, templates):
        return [arr.reshape(templates[0].shape).astype(templates[0].dtype)]
