"""LC state pytrees.

``LCState`` travels with the train state through jit boundaries and
checkpoints:

    {"tasks": {task_name: {"theta": <scheme pytree>,
                           "lam":   {param_path: array},   # multipliers
                           "a":     {param_path: array}}}, # a = Δ(Θ) scattered
     "mu": f32 scalar,
     "k":  i32 LC-step counter}

``a`` (the decompressed target) and ``lam`` are stored *per original
parameter leaf* — because the L2 penalty separates over leaves, the L step
never materializes the concatenated view, and both arrays inherit the
parameter's sharding.
"""
from __future__ import annotations

import jax.numpy as jnp


def task_state(theta, lam: dict, a: dict) -> dict:
    return {"theta": theta, "lam": lam, "a": a}


def lc_state(tasks: dict, mu: float, k: int = 0) -> dict:
    return {"tasks": tasks, "mu": jnp.float32(mu), "k": jnp.int32(k)}


def with_tasks(lc: dict, new_tasks: dict) -> dict:
    """New LC state with ``tasks`` replaced, μ/k carried through — the
    one-liner every C/multiplier step ends with (keeps the pytree layout
    identical across the grouped and per-task paths, so checkpoints and
    the trainer's penalty refs never notice which engine produced it)."""
    return {"tasks": new_tasks, "mu": lc["mu"], "k": lc["k"]}


def zeros_like_leaves(paths: list[str], leaves: list) -> dict:
    return {p: jnp.zeros(l.shape, jnp.float32)
            for p, l in zip(paths, leaves)}
