"""LC state pytrees.

``LCState`` travels with the train state through jit boundaries and
checkpoints:

    {"tasks": {task_name: {"theta": <scheme pytree>,
                           "lam":   {param_path: array},   # multipliers
                           "a":     {param_path: array}}}, # a = Δ(Θ) scattered
     "mu": f32 scalar,
     "k":  i32 LC-step counter}

``a`` (the decompressed target) and ``lam`` are stored *per original
parameter leaf* — because the L2 penalty separates over leaves, the L step
never materializes the concatenated view, and both arrays inherit the
parameter's sharding.

Donation contract: ``LCAlgorithm``'s synchronous C/multiplier steps may
donate the incoming state's buffers (Θ/λ/a update in place on
accelerators). The *async* entry points used by the trainer's overlapped
pipeline never donate — during overlap the previous state's λ/a leaves
are still read by the in-flight L step, so both generations of buffers
must stay live until the trainer swaps its penalty refs
(:func:`ready_probe` is how it polls the in-flight generation).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def task_state(theta, lam: dict, a: dict) -> dict:
    return {"theta": theta, "lam": lam, "a": a}


def lc_state(tasks: dict, mu: float, k: int = 0) -> dict:
    return {"tasks": tasks, "mu": jnp.float32(mu), "k": jnp.int32(k)}


def with_tasks(lc: dict, new_tasks: dict) -> dict:
    """New LC state with ``tasks`` replaced, μ/k carried through — the
    one-liner every C/multiplier step ends with (keeps the pytree layout
    identical across the grouped and per-task paths, so checkpoints and
    the trainer's penalty refs never notice which engine produced it)."""
    return {"tasks": new_tasks, "mu": lc["mu"], "k": lc["k"]}


def zeros_like_leaves(paths: list[str], leaves: list) -> dict:
    return {p: jnp.zeros(l.shape, jnp.float32)
            for p, l in zip(paths, leaves)}


def ready_probe(lc: dict):
    """One representative leaf of an in-flight LC state, for non-blocking
    readiness polling (``probe.is_ready()``) in the overlapped trainer.

    The last task leaf in tree order is chosen: the multiplier step's λ
    updates are the final work dispatched at an LC boundary, so when this
    leaf lands the whole C+λ chain is (to within dispatch-order slack)
    done.
    """
    return jax.tree_util.tree_leaves(lc["tasks"])[-1]


def probe_is_ready(probe) -> bool:
    """``probe.is_ready()`` with a conservative fallback: jax < 0.4.10
    arrays have no ``is_ready`` — report not-ready and let the caller's
    deadline (swap_after / L-step end) force the block instead."""
    is_ready = getattr(probe, "is_ready", None)
    return bool(is_ready()) if is_ready is not None else False
