"""Grouped C-step dispatch (the paper's "C steps can be run in parallel").

The per-task C step traces one scheme program per task, so HLO size and
compile time grow linearly with the task count (a per-layer config on a
large model yields dozens of structurally identical k-means/top-κ
programs). Grouped dispatch instead:

1. partitions resolved tasks by ``CompressionTask.group_signature`` —
   (scheme ``group_key()``, view item shape, dtype);
2. concatenates each group's *items* (stacked views contribute their
   stack, single-array views contribute one item) along a leading axis;
3. packs the warm-start Θ pytrees the same way (`pack_thetas`);
4. runs ONE ``vmap``-ed ``scheme.compress`` (and ``decompress``) per
   group;
5. slices Θ and Δ(Θ) back out per task.

Everything here runs at trace time inside the single jitted ``c_step`` —
the Python loops cost nothing at runtime, and the resulting HLO contains
one scheme program per *group* instead of per *task*.

Tasks whose scheme opts out (``group_key() is None``) fall through to
the per-task path unchanged, so exotic schemes need no vmap support.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp

from repro.core.schemes.base import (
    add_leading_axis, drop_leading_axis, pack_thetas, unpack_thetas)
from repro.core.tasks import CompressionTask


def build_groups(tasks: Sequence[CompressionTask],
                 xs: dict) -> list[list[CompressionTask]]:
    """Partition tasks into groups of equal group signature.

    ``xs`` maps task name → compressible array (or ShapeDtypeStruct).
    Non-groupable tasks come back as singleton groups. Group order
    follows first appearance, so the output is deterministic.
    """
    groups: dict = {}
    order: list = []
    solos: list[list[CompressionTask]] = []
    for t in tasks:
        sig = t.group_signature(xs[t.name])
        if sig is None:
            solos.append([t])
            continue
        if sig not in groups:
            groups[sig] = []
            order.append(sig)
        groups[sig].append(t)
    return [groups[s] for s in order] + solos


def describe_groups(tasks: Sequence[CompressionTask],
                    xs: dict) -> list[dict]:
    """Human/bench-readable summary of the grouping a C step would use."""
    out = []
    for group in build_groups(tasks, xs):
        t0 = group[0]
        sig = t0.group_signature(xs[t0.name])
        out.append({
            "scheme": t0.scheme.name,
            "item_shape": t0.view.item_shape(xs[t0.name]),
            "tasks": [t.name for t in group],
            "items": sum(t.view.item_count(xs[t.name]) for t in group),
            # singleton groups run the per-task path even when groupable
            "grouped": sig is not None and len(group) > 1,
        })
    return out


def grouped_compress(tasks: Sequence[CompressionTask], xs: dict,
                     thetas: dict, mu) -> dict:
    """One C step over all tasks with grouped vmap dispatch.

    Returns ``{task_name: (new_theta, a_arr)}`` where ``a_arr`` is the
    decompressed Δ(Θ) in the task's compressible shape. Must be called
    under jit (it is trace-time machinery, not a runtime scheduler).
    """
    out = {}
    for group in build_groups(tasks, xs):
        if len(group) == 1:
            # singleton: per-task path (also the non-groupable fallback);
            # a 1-group vmap would only rewrite indexing for no benefit.
            t = group[0]
            theta = t.scheme_compress(xs[t.name], thetas[t.name], mu)
            out[t.name] = (theta, t.scheme_decompress(theta))
            continue

        scheme = group[0].scheme  # identical group_key ⇒ same static cfg
        items = jnp.concatenate(
            [t.view.to_items(xs[t.name]) for t in group], axis=0)
        packed = pack_thetas([
            thetas[t.name] if t.view.stacked
            else add_leading_axis(thetas[t.name]) for t in group])

        new_packed = jax.vmap(
            lambda xi, ti: scheme.compress(xi, ti, mu=mu))(items, packed)
        a_packed = jax.vmap(scheme.decompress)(new_packed)

        counts = [t.view.item_count(xs[t.name]) for t in group]
        theta_parts = unpack_thetas(new_packed, counts)
        off = 0
        for t, th, n in zip(group, theta_parts, counts):
            a_arr = t.view.from_items(a_packed[off:off + n])
            off += n
            out[t.name] = (th if t.view.stacked else drop_leading_axis(th),
                           a_arr)
    return out
