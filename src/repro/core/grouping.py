"""Grouped C-step dispatch (the paper's "C steps can be run in parallel").

The per-task C step traces one scheme program per task, so HLO size and
compile time grow linearly with the task count (a per-layer config on a
large model yields dozens of structurally identical k-means/top-κ
programs). Grouped dispatch instead:

1. partitions resolved tasks by ``CompressionTask.group_signature`` —
   (scheme ``group_key()``, view item shape, dtype);
2. concatenates each group's *items* (stacked views contribute their
   stack, single-array views contribute one item) along a leading axis;
3. packs the warm-start Θ pytrees the same way (`pack_thetas`);
4. runs ONE ``vmap``-ed ``scheme.compress`` (and ``decompress``) per
   group;
5. slices Θ and Δ(Θ) back out per task.

Everything here runs at trace time inside the single jitted ``c_step`` —
the Python loops cost nothing at runtime, and the resulting HLO contains
one scheme program per *group* instead of per *task*.

With a ``mesh``, the packed item axis is additionally annotated with the
``"items"`` logical sharding rule (``distributed/sharding.py``, default
candidates ``[("data",), ()]``): the stacked items are embarrassingly
parallel, so GSPMD splits the vmapped scheme program across the data
axis — a 64-layer group's C step runs data-parallel. Item counts that
don't divide the data axis are zero-padded up to the next multiple
(padded lanes are computed and discarded; vmap lanes are independent, so
the surviving slices are bit-identical to the unsharded result), and the
per-task Θ/Δ(Θ) slices are re-constrained with each task's own item
count so they land where the L step consumes them. ``mesh=None``
(default) is exactly the pre-mesh path.

Tasks whose scheme opts out (``group_key() is None``) fall through to
the per-task path unchanged, so exotic schemes need no vmap support.
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.schemes.base import (
    add_leading_axis, drop_leading_axis, pack_thetas, unpack_thetas)
from repro.core.tasks import CompressionTask
from repro.distributed.sharding import (
    items_partition, shard_map, stacked_sharding)


def build_groups(tasks: Sequence[CompressionTask],
                 xs: dict) -> list[list[CompressionTask]]:
    """Partition tasks into groups of equal group signature.

    ``xs`` maps task name → compressible array (or ShapeDtypeStruct).
    Non-groupable tasks come back as singleton groups. Group order
    follows first appearance, so the output is deterministic.
    """
    groups: dict = {}
    order: list = []
    solos: list[list[CompressionTask]] = []
    for t in tasks:
        sig = t.group_signature(xs[t.name])
        if sig is None:
            solos.append([t])
            continue
        if sig not in groups:
            groups[sig] = []
            order.append(sig)
        groups[sig].append(t)
    return [groups[s] for s in order] + solos


def describe_groups(tasks: Sequence[CompressionTask], xs: dict,
                    mesh: Mesh | None = None,
                    rules: dict | None = None) -> list[dict]:
    """Human/bench-readable summary of the grouping a C step would use.

    With a ``mesh``, each entry also reports how the packed item axis
    would be laid out: ``spec`` is the PartitionSpec of the stacked
    leading axis (``None`` whenever the axis is not sharded — no mesh,
    per-task path, or replication fallback) and ``padding`` is the
    number of zero items appended so the count divides the assigned
    mesh axes (0 when it already divides, or when not sharded).
    """
    out = []
    for group in build_groups(tasks, xs):
        t0 = group[0]
        sig = t0.group_signature(xs[t0.name])
        grouped = sig is not None and len(group) > 1
        n_items = sum(t.view.item_count(xs[t.name]) for t in group)
        spec, pad = None, 0
        if mesh is not None and grouped:
            entry, pad = items_partition(n_items, mesh, rules)
            spec = P(entry) if entry is not None else None
        out.append({
            "scheme": t0.scheme.name,
            "item_shape": t0.view.item_shape(xs[t0.name]),
            "tasks": [t.name for t in group],
            "items": n_items,
            # singleton groups run the per-task path even when groupable
            "grouped": grouped,
            "spec": spec,
            "padding": pad,
        })
    return out


def _pad_leading(x, pad: int):
    """Append ``pad`` zero items along axis 0 (the vmapped item axis)."""
    return jnp.concatenate(
        [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)


def _constrain_leading(tree, mesh, entry):
    """with_sharding_constraint splitting only the leading axis."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.with_sharding_constraint(
            x, stacked_sharding(mesh, entry, x.ndim)), tree)


def _constrain_replicated(tree, mesh):
    """with_sharding_constraint pinning every leaf fully replicated."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P())), tree)


def grouped_compress(tasks: Sequence[CompressionTask], xs: dict,
                     thetas: dict, mu, mesh: Mesh | None = None,
                     rules: dict | None = None) -> dict:
    """One C step over all tasks with grouped vmap dispatch.

    Returns ``{task_name: (new_theta, a_arr)}`` where ``a_arr`` is the
    decompressed Δ(Θ) in the task's compressible shape. Must be called
    under jit (it is trace-time machinery, not a runtime scheduler).
    With a ``mesh``, the packed item axis of every multi-task group is
    sharded per the ``"items"`` rule — see the module docstring; the
    numerics are unchanged.
    """
    out = {}
    for group in build_groups(tasks, xs):
        if len(group) == 1:
            # singleton: per-task path (also the non-groupable fallback);
            # a 1-group vmap would only rewrite indexing for no benefit.
            t = group[0]
            theta = t.scheme_compress(xs[t.name], thetas[t.name], mu)
            out[t.name] = (theta, t.scheme_decompress(theta))
            continue

        scheme = group[0].scheme  # identical group_key ⇒ same static cfg
        items = jnp.concatenate(
            [t.view.to_items(xs[t.name]) for t in group], axis=0)
        packed = pack_thetas([
            thetas[t.name] if t.view.stacked
            else add_leading_axis(thetas[t.name]) for t in group])

        counts = [t.view.item_count(xs[t.name]) for t in group]
        n_items = sum(counts)
        entry, pad = (None, 0)
        if mesh is not None:
            entry, pad = items_partition(n_items, mesh, rules)

        def _solve(xi, ti):
            nt = jax.vmap(
                lambda x, th: scheme.compress(x, th, mu=mu))(xi, ti)
            return nt, jax.vmap(scheme.decompress)(nt)

        if entry is not None:
            # padded lanes are independent vmap lanes computed and
            # discarded, so the surviving slices match mesh=None exactly
            if pad:
                items = _pad_leading(items, pad)
                packed = jax.tree_util.tree_map(
                    lambda x: _pad_leading(x, pad), packed)
            # enter the shard_map boundary from an explicit replicated
            # layout: on jax 0.4.x GSPMD's reshard-into-manual from a
            # dim-sharded concatenate miscompiles (the output comes back
            # psummed over the unmentioned mesh axes), while
            # replicated → manual slices correctly.
            items = _constrain_replicated(items, mesh)
            packed = _constrain_replicated(packed, mesh)
            # shard_map, not bare GSPMD: each device vmaps the scheme
            # over its local items, so schemes built on custom calls
            # (LAPACK svd/qr) partition correctly — the SPMD partitioner
            # has no rule for those and miscompiles sliced uses.
            spec = P(entry)
            new_packed, a_packed = shard_map(
                _solve, mesh, in_specs=(spec, spec),
                out_specs=(spec, spec))(items, packed)
        else:
            new_packed, a_packed = _solve(items, packed)

        if pad:
            new_packed = jax.tree_util.tree_map(
                lambda x: x[:n_items], new_packed)
            a_packed = a_packed[:n_items]

        theta_parts = unpack_thetas(new_packed, counts)
        off = 0
        for t, th, n in zip(group, theta_parts, counts):
            a_arr = t.view.from_items(a_packed[off:off + n])
            off += n
            if not t.view.stacked:
                th = drop_leading_axis(th)
            elif mesh is not None:
                # land the sliced stack where the L step consumes it:
                # the task's own item count decides its spec (exact
                # divisibility only — slices can't be padded)
                t_entry, _ = items_partition(n, mesh, rules,
                                             allow_pad=False)
                if t_entry is not None:
                    th = _constrain_leading(th, mesh, t_entry)
                    a_arr = _constrain_leading(a_arr, mesh, t_entry)
            out[t.name] = (th, a_arr)
    return out
