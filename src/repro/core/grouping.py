"""Grouped C-step dispatch (the paper's "C steps can be run in parallel").

The per-task C step traces one scheme program per task, so HLO size and
compile time grow linearly with the task count (a per-layer config on a
large model yields dozens of structurally identical k-means/top-κ
programs). Grouped dispatch instead:

1. partitions resolved tasks by ``CompressionTask.group_signature`` —
   (scheme ``group_key()``, view item shape, dtype);
2. concatenates each group's *items* (stacked views contribute their
   stack, single-array views contribute one item) along a leading axis;
3. packs the warm-start Θ pytrees the same way (`pack_thetas`);
4. solves each group with ONE program: a **named batched kernel
   solver** resolved through ``repro.kernels.dispatch`` when the
   scheme opts in (items-grid Pallas on TPU, interpret-mode Pallas or
   the bit-identical batched jnp solver on CPU), else one ``vmap``-ed
   ``scheme.compress``;
5. slices Θ and Δ(Θ) back out per task.

Everything here runs at trace time inside the single jitted ``c_step`` —
the Python loops cost nothing at runtime, and the resulting HLO contains
one scheme program per *group* instead of per *task*.

With a ``mesh``, the packed item axis is additionally annotated with the
``"items"`` logical sharding rule (``distributed/sharding.py``, default
candidates ``[("data",), ()]``): the stacked items are embarrassingly
parallel, so GSPMD splits the group program across the data axis — a
64-layer group's C step runs data-parallel. Item counts that don't
divide the data axis are zero-padded up to the next multiple (padded
lanes are computed and discarded; items are independent, so the
surviving slices are bit-identical to the unsharded result), and the
per-task Θ/Δ(Θ) slices are re-constrained with each task's own item
count so they land where the L step consumes them. ``mesh=None``
(default) is exactly the pre-mesh path.

Kernel dispatch (``backend=``) composes with all of it: under the
batched signature, schemes that move a hyperparameter into a per-item
operand (ℓ0 pruning's κ, low-rank's target rank, rank selection's α,
k-means' valid-K count) group across values of it — one launch for
mixed-hyperparameter tasks — and the per-item operands are
padded/sharded alongside the items. Θ leaves whose *shapes* differ
across members (mixed-rank factors, mixed-K codebooks) pack with
trailing-dim padding (``pack_thetas_padded``) and slice back to each
task's own shapes after the solve. Stochastic solvers
(``scheme.wants_key``) get engine-derived per-item PRNG keys — by task
name and within-task index, identical on the grouped and per-task
paths — appended as the last operand (kernel path) or threaded as a
``key=`` kwarg (vmap path). Batched solvers that are custom-call-free
(``scheme.gspmd_safe``: the matmul-only low-rank solvers) shard under
plain GSPMD instead of the shard_map workaround. Tasks whose scheme
opts out (``group_key() is None``) fall through to the per-task path
unchanged, so exotic schemes need no vmap support; a scheme whose
subclass overrides ``compress`` without standing behind
``compress_batched`` is likewise kept on the vmap path (see
``CompressionScheme.kernel_dispatch_ready``).
"""
from __future__ import annotations

from typing import Sequence

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core.schemes.base import (
    add_leading_axis, drop_leading_axis, pack_thetas, pack_thetas_padded,
    slice_theta_like, unpack_thetas)
from repro.core.tasks import CompressionTask
from repro.distributed.sharding import (
    items_partition, shard_map, stacked_sharding)


def _task_solver(scheme, backend):
    """(solver_fn, actual_backend) for a scheme under a requested
    backend, or (None, None) → vmap path."""
    if backend in (None, "off") or not scheme.kernel_dispatch_ready():
        return None, None
    # deferred import: `import repro.core` must not eagerly pull the
    # Pallas kernel modules (jax.experimental.pallas + registration)
    # for users who never turn kernel dispatch on
    from repro.kernels.dispatch import lookup as solver_lookup
    return solver_lookup(scheme.solver, backend)


def _abstract(tree):
    """Pytree → matching ShapeDtypeStructs (works on arrays, tracers
    and ShapeDtypeStructs alike — only shape/dtype are read)."""
    return jax.tree_util.tree_map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)


def _plan_multi_group(group, xs: dict, thetas: dict, counts: list[int],
                      solver_fn, mesh, rules, backend):
    """Plan one multi-task group through the roofline cost model.

    The planner never changes *grouping*: ``solver_fn`` (resolved by
    the static rule) already fixed whether the group packs for a named
    batched solver, and the plan only re-picks the backend among the
    registered implementations of that same solver, tunes its
    items-grid tile, and decides chunking/shard_mode. Trace-safe: only
    shapes/dtypes are consulted; the optional HLO refinement lowers on
    ``ShapeDtypeStruct``s (and is skipped under a mesh). Plans are
    cached in ``repro.analysis.cost`` keyed by the group signature, so
    repeated LC boundaries — and jit-cache rebuilds — replan nothing.
    """
    from repro.analysis import cost as _cost
    from repro.kernels.dispatch import registered_backends
    t0 = group[0]
    scheme = t0.scheme
    batched = solver_fn is not None
    sig = t0.group_signature(xs[t0.name], batched=batched)
    n_items = sum(counts)
    xs_a = {t.name: _abstract(xs[t.name]) for t in group}
    th_a = {t.name: _abstract(thetas[t.name]) for t in group}
    arrays = jax.eval_shape(
        lambda xs_, th_: _pack_group(group, xs_, th_, counts,
                                     solver_fn)[0], xs_a, th_a)
    item_shape = t0.view.item_shape(xs[t0.name])
    item_elems = 1
    for d in item_shape:
        item_elems *= int(d)
    # per-row VMEM beyond the weight tile itself (codebook / threshold
    # blocks); a coarse margin is enough to rank the tile candidates
    extra_vmem = 4 * 128 * 4

    # HLO refinement only for dispatch-path groups: lowering a
    # vmap-path group traces the scheme's Python ``compress`` a second
    # time at plan time, breaking the one-trace-per-group contract —
    # and there is no named solver to re-pick for it anyway.
    lower_fn, base_fallbacks = None, ()
    if not batched:
        base_fallbacks = ("hlo-refine-skipped:vmap-path",)
    elif mesh is None:
        def lower_fn(chosen):
            lowered = lower_group(group, xs_a, th_a, mu=1.0,
                                  backend=chosen)
            return lowered.compiler_ir(dialect="hlo").as_hlo_text()

    return _cost.plan_group(
        sig, n_items, arrays, (arrays[1], arrays[0]),
        requested_backend=str(backend) if backend is not None else "off",
        solver=scheme.solver if batched else None,
        registered=registered_backends(scheme.solver if batched
                                       else None),
        gspmd_safe=bool(batched and scheme.gspmd_safe), mesh=mesh,
        item_elems=item_elems, extra_vmem_per_row=extra_vmem,
        lower_fn=lower_fn, base_fallbacks=base_fallbacks)


def _apply_plan(scheme, solver_fn, plan):
    """Re-resolve the group's solver under the planner's choices.

    Only swaps among registered implementations of the *same* solver
    (backend + tile); a vmap-path group (``solver_fn is None``) stays
    on vmap — the plan never flips the grouping identity.
    """
    if plan is None or solver_fn is None:
        return solver_fn
    from repro.kernels.dispatch import lookup as solver_lookup
    fn, _ = solver_lookup(scheme.solver, plan.backend,
                          tile=plan.block_rows)
    return fn if fn is not None else solver_fn


def build_groups(tasks: Sequence[CompressionTask], xs: dict,
                 backend: str | None = None,
                 for_init: bool = False) -> list[list[CompressionTask]]:
    """Partition tasks into groups of equal group signature.

    ``xs`` maps task name → compressible array (or ShapeDtypeStruct).
    Non-groupable tasks come back as singleton groups. Group order
    follows first appearance, so the output is deterministic. With a
    kernel ``backend`` active, dispatch-ready schemes group by their
    ``batch_key()`` (κ and friends become per-item operands) — but only
    when the named solver actually *resolves* in the registry: an
    unregistered name must keep the legacy per-value grouping, or the
    vmap fallback would solve a mixed-hyperparameter group with
    ``group[0]``'s values.
    """
    groups: dict = {}
    order: list = []
    solos: list[list[CompressionTask]] = []
    for t in tasks:
        batched = _task_solver(t.scheme, backend)[0] is not None
        sig = t.group_signature(xs[t.name], batched=batched)
        if for_init and sig is not None:
            # init-only hyperparameters (a DP warm start) are invisible
            # to group_key; the init grouping identity must include them
            ik = t.scheme.init_key()
            sig = None if ik is None else (sig, ik)
        if sig is None:
            solos.append([t])
            continue
        if sig not in groups:
            groups[sig] = []
            order.append(sig)
        groups[sig].append(t)
    return [groups[s] for s in order] + solos


def describe_groups(tasks: Sequence[CompressionTask], xs: dict,
                    mesh: Mesh | None = None,
                    rules: dict | None = None,
                    backend: str | None = None,
                    planner: str | None = None) -> list[dict]:
    """Human/bench-readable summary of the grouping a C step would use.

    With a ``mesh``, each entry also reports how the packed item axis
    would be laid out: ``spec`` is the PartitionSpec of the stacked
    leading axis (``None`` whenever the axis is not sharded — no mesh,
    per-task path, or replication fallback) and ``padding`` is the
    number of zero items appended so the count divides the assigned
    mesh axes (0 when it already divides, or when not sharded).

    ``solver``/``backend`` report kernel dispatch *honestly*: ``solver``
    is the registry name the group's solve will actually go through
    (``None`` = vmapped scheme program) and ``backend`` the resolved
    implementation that will run — e.g. a ``"pallas"`` request off-TPU
    reports ``"interpret"``.

    ``planner="on"`` additionally attaches each multi-task group's
    :class:`repro.analysis.cost.GroupPlan` as a ``plan`` dict (modeled
    roofline terms, chosen backend/tile/chunks/shard_mode, recorded
    fallbacks) — the same cached plan the C step will use, with Θ
    shapes staged via ``jax.eval_shape`` of the scheme init (nothing
    executes). When planned, ``backend`` reports the planner's choice.
    """
    out = []
    for group in build_groups(tasks, xs, backend=backend):
        t0 = group[0]
        sig = t0.group_signature(xs[t0.name])
        grouped = sig is not None and len(group) > 1
        n_items = sum(t.view.item_count(xs[t.name]) for t in group)
        spec, pad = None, 0
        if mesh is not None and grouped:
            entry, pad = items_partition(n_items, mesh, rules)
            spec = P(entry) if entry is not None else None
        solver_fn, actual = _task_solver(t0.scheme, backend)
        shard_mode = None
        if spec is not None:
            # matmul-only solvers (scheme.gspmd_safe) shard under plain
            # GSPMD; everything else keeps the shard_map custom-call
            # workaround (docs/architecture.md)
            shard_mode = ("gspmd" if solver_fn is not None
                          and t0.scheme.gspmd_safe else "shard_map")
        plan_dict = None
        if planner == "on" and grouped:
            counts = [t.view.item_count(xs[t.name]) for t in group]
            thetas = {t.name: jax.eval_shape(t.scheme_init, xs[t.name])
                      for t in group}
            plan = _plan_multi_group(group, xs, thetas, counts,
                                     solver_fn, mesh, rules, backend)
            plan_dict = plan.as_dict()
            if solver_fn is not None:
                actual = plan.backend
        out.append({
            "scheme": t0.scheme.name,
            "item_shape": t0.view.item_shape(xs[t0.name]),
            "tasks": [t.name for t in group],
            "items": n_items,
            # singleton groups run the per-task path even when groupable
            "grouped": grouped,
            "spec": spec,
            "padding": pad,
            "shard_mode": shard_mode,
            "solver": t0.scheme.solver if solver_fn is not None else None,
            "backend": actual,
            "plan": plan_dict,
        })
    return out


def _pad_leading(x, pad: int):
    """Append ``pad`` zero items along axis 0 (the packed item axis)."""
    return jnp.concatenate(
        [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)


def _constrain_leading(tree, mesh, entry):
    """with_sharding_constraint splitting only the leading axis."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.with_sharding_constraint(
            x, stacked_sharding(mesh, entry, x.ndim)), tree)


def _constrain_replicated(tree, mesh):
    """with_sharding_constraint pinning every leaf fully replicated."""
    return jax.tree_util.tree_map(
        lambda x: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, P())), tree)


def _chunk_bounds(n_items: int, n_chunks: int) -> list[tuple[int, int]]:
    """Contiguous item-axis slices splitting ``n_items`` into
    ``n_chunks`` near-equal launches (first chunks take the remainder)."""
    n_chunks = max(1, min(int(n_chunks), n_items))
    base, rem = divmod(n_items, n_chunks)
    bounds, lo = [], 0
    for i in range(n_chunks):
        hi = lo + base + (1 if i < rem else 0)
        bounds.append((lo, hi))
        lo = hi
    return bounds


def _run_group_solve(solve, arrays: tuple, n_items: int,
                     mesh: Mesh | None, rules: dict | None,
                     gspmd: bool = False, n_chunks: int = 1):
    """Run a packed-group solve, optionally sharded over the mesh.

    ``arrays`` are pytrees whose every leaf carries the packed item
    axis; ``solve(*arrays)`` must return a 2-tuple of such pytrees
    (new Θ, decompressed items). Handles the pad → replicate-constrain
    → shard_map → slice dance from the module docstring; ``mesh=None``
    calls ``solve`` directly. Returns ``(theta_packed, a_packed)`` with
    the padding already sliced off.

    ``gspmd=True`` (matmul-only batched solvers — ``scheme.gspmd_safe``)
    bypasses the shard_map workaround: the packed item axis is annotated
    with plain sharding constraints and GSPMD partitions the solve
    itself. Correct only when every op in ``solve`` has an SPMD rule
    (no LAPACK custom calls); padded lanes are still independent items
    computed and discarded.

    ``n_chunks > 1`` (planner-chosen when the packed working set blows
    the VMEM/HBM budget) splits the *unsharded* solve into several
    launches over contiguous item slices and re-concatenates Θ exactly.
    Bit-identical to the single launch: packing (incl. the group-wide
    trailing-dim padding) happened before the split and every batched
    solver is per-item independent. Sharded groups never chunk here —
    the planner records the ``chunking-disabled-under-mesh`` fallback
    instead.
    """
    entry, pad = (None, 0)
    if mesh is not None:
        entry, pad = items_partition(n_items, mesh, rules)

    if entry is not None:
        # padded lanes are independent items computed and discarded, so
        # the surviving slices match mesh=None exactly
        if pad:
            arrays = tuple(
                jax.tree_util.tree_map(lambda x: _pad_leading(x, pad), a)
                for a in arrays)
        if gspmd:
            # plain GSPMD: constrain the packed item axis sharded on the
            # way in and out and let the partitioner split the batched
            # matmuls — no manual region, no custom-call workaround
            arrays = tuple(_constrain_leading(a, mesh, entry)
                           for a in arrays)
            theta_packed, a_packed = solve(*arrays)
            theta_packed = _constrain_leading(theta_packed, mesh, entry)
            a_packed = _constrain_leading(a_packed, mesh, entry)
        else:
            # enter the shard_map boundary from an explicit replicated
            # layout: on jax 0.4.x GSPMD's reshard-into-manual from a
            # dim-sharded concatenate miscompiles (the output comes back
            # psummed over the unmentioned mesh axes), while
            # replicated → manual slices correctly.
            arrays = tuple(_constrain_replicated(a, mesh) for a in arrays)
            # shard_map, not bare GSPMD: each device solves its local
            # items, so schemes built on custom calls (LAPACK svd/qr)
            # partition correctly — the SPMD partitioner has no rule for
            # those and miscompiles sliced uses.
            spec = P(entry)
            theta_packed, a_packed = shard_map(
                solve, mesh, in_specs=(spec,) * len(arrays),
                out_specs=(spec, spec))(*arrays)
    elif n_chunks > 1 and n_items > 1:
        parts = []
        for lo, hi in _chunk_bounds(n_items, n_chunks):
            chunk = tuple(
                jax.tree_util.tree_map(lambda x: x[lo:hi], a)
                for a in arrays)
            parts.append(solve(*chunk))
        theta_packed = jax.tree_util.tree_map(
            lambda *leaves: jnp.concatenate(leaves, axis=0),
            *[p[0] for p in parts])
        a_packed = jnp.concatenate([p[1] for p in parts], axis=0)
    else:
        theta_packed, a_packed = solve(*arrays)

    if pad:
        theta_packed = jax.tree_util.tree_map(
            lambda x: x[:n_items], theta_packed)
        a_packed = a_packed[:n_items]
    return theta_packed, a_packed


def _packed_keys(group: Sequence[CompressionTask], counts: list[int]):
    """One (Σ items, 2) uint32 key array for a ``wants_key`` group.

    The single source of key packing for every grouped path (solver
    operands, vmap fallback, grouped init) — ``CompressionTask
    .item_keys`` derives each slice from task name + within-task index,
    so all paths see identical per-item keys."""
    return jnp.concatenate([t.item_keys(n) for t, n in zip(group, counts)],
                           axis=0)


def _group_operands(group: Sequence[CompressionTask], counts: list[int]):
    """Concatenate each task's per-item solver operands into the packed
    form ``compress_batched`` consumes (mixed-κ: one (Σ items,) array).
    Schemes with ``wants_key`` get their packed per-item PRNG keys
    appended as the LAST operand."""
    per_task = [t.scheme.batch_operands(n) for t, n in zip(group, counts)]
    operands = tuple(jnp.concatenate(parts, axis=0)
                     for parts in zip(*per_task))
    if group[0].scheme.wants_key:
        operands = operands + (_packed_keys(group, counts),)
    return operands


def _group_solve(scheme, solver_fn, mu):
    """The packed-group solve callable — one body shared by the
    executing path (:func:`grouped_compress`) and the lowering path
    (:func:`lower_group`), so what the linter inspects is exactly what
    the C step runs. ``solve(items, packed_theta, *operands) →
    (new_theta, decompressed items)``."""
    def _solve(xi, ti, *ops):
        if solver_fn is not None:
            nt = scheme.compress_batched(solver_fn, xi, ti, ops, mu=mu)
        elif scheme.wants_key:
            (keys,) = ops
            nt = jax.vmap(
                lambda x, th, k: scheme.compress(x, th, mu=mu,
                                                 key=k))(xi, ti, keys)
        else:
            nt = jax.vmap(
                lambda x, th: scheme.compress(x, th, mu=mu))(xi, ti)
        return nt, jax.vmap(scheme.decompress)(nt)

    return _solve


def _pack_group(group: Sequence[CompressionTask], xs: dict, thetas: dict,
                counts: list[int], solver_fn):
    """Build the packed array tuple a group solve consumes.

    Returns ``(arrays, thetas_lead)``: ``arrays`` is ``(items,
    packed_theta, *operands)`` and ``thetas_lead`` the per-task Θs with
    a leading item axis (the slice-back templates). Pure tracing code —
    runs concretely inside the jitted C step and abstractly under
    ``jax.eval_shape`` when lowering."""
    scheme = group[0].scheme
    items = jnp.concatenate(
        [t.view.to_items(xs[t.name]) for t in group], axis=0)
    thetas_lead = [thetas[t.name] if t.view.stacked
                   else add_leading_axis(thetas[t.name])
                   for t in group]
    if solver_fn is not None:
        # batched solvers take Θ leaves padded to the group max
        # trailing shape (mixed-rank factors → R_max, mixed-K
        # codebooks → K_max); the vmap path never mixes shapes
        # (they are part of its grouping identity)
        packed = pack_thetas_padded(thetas_lead)
        operands = _group_operands(group, counts)
    else:
        packed = pack_thetas(thetas_lead)
        operands = ((_packed_keys(group, counts),)
                    if scheme.wants_key else ())
    return (items, packed) + operands, thetas_lead


def lower_group(group: Sequence[CompressionTask], xs: dict, thetas: dict,
                mu: float = 1.0, mesh: Mesh | None = None,
                rules: dict | None = None, backend: str | None = None,
                donate: bool = False, plan=None):
    """Lower one group's packed C solve to HLO **without executing it**.

    The static-analysis hook behind ``repro.analysis.lint``'s HLO layer:
    it stages exactly the program :func:`grouped_compress` would run for
    ``group`` — same packing, same solver resolution, same
    mesh/shard-mode logic — through ``jax.jit(...).lower`` on
    ``ShapeDtypeStruct``s, and returns the ``Lowered`` object (use
    ``.as_text()`` / ``.compiler_ir(dialect="hlo")``).

    ``xs``/``thetas`` may hold real arrays or ``ShapeDtypeStruct``s —
    nothing is materialized either way. ``donate=True`` marks the packed
    Θ input donated, mirroring the engine's donated LC state, so a
    donation-aliasing check sees the engine's buffer story. A singleton
    group lowers the same packed program with one item.

    ``plan`` (a :class:`repro.analysis.cost.GroupPlan`) stages the
    *planner-chosen* program instead — backend/tile re-resolved through
    :func:`_apply_plan` and the chunked launch structure included — so
    the Layer-3 lint rules see exactly what a planner-on C step runs.
    """
    scheme = group[0].scheme
    solver_fn, _ = _task_solver(scheme, backend)
    n_chunks = 1
    if plan is not None:
        n_chunks = plan.n_chunks
        solver_fn = _apply_plan(scheme, solver_fn, plan)
    counts = [t.view.item_count(xs[t.name]) for t in group]
    n_items = sum(counts)

    arrays = jax.eval_shape(
        lambda xs_, thetas_: _pack_group(group, xs_, thetas_, counts,
                                         solver_fn)[0],
        xs, {t.name: thetas[t.name] for t in group})

    solve = _group_solve(scheme, solver_fn, mu)
    gspmd = solver_fn is not None and scheme.gspmd_safe

    def run(items, packed, *ops):
        return _run_group_solve(solve, (items, packed) + ops, n_items,
                                mesh, rules, gspmd=gspmd,
                                n_chunks=n_chunks)

    jitted = jax.jit(run, donate_argnums=(1,) if donate else ())
    return jitted.lower(*arrays)


def compile_group(group: Sequence[CompressionTask], xs: dict,
                  thetas: dict, mesh: Mesh | None = None,
                  rules: dict | None = None, backend: str | None = None,
                  plan=None):
    """AOT-compile one group's packed C solve, cached across boundaries.

    The executable half of the planner cache: μ rides as the FIRST
    traced argument (not baked into the trace like the jitted engine
    path), so ONE compile serves every LC boundary — call the returned
    executable as ``compiled(jnp.float32(mu), *arrays)`` and it returns
    ``(packed_theta, packed_items)``. Executables are cached in
    ``repro.analysis.cost`` keyed by the same group signature as plans;
    repeated boundaries (and jit-cache rebuilds) pay zero
    re-lower/re-trace — ``cost.cache_stats()`` proves it and
    ``bench_roofline`` / the Layer-3 lint hard-assert it.

    ``xs``/``thetas`` must hold concrete arrays (packing runs eagerly).
    Returns ``(compiled, arrays)``.
    """
    from repro.analysis import cost as _cost
    t0 = group[0]
    scheme = t0.scheme
    solver_fn, _ = _task_solver(scheme, backend)
    n_chunks = 1
    if plan is not None:
        n_chunks = plan.n_chunks
        solver_fn = _apply_plan(scheme, solver_fn, plan)
    batched = solver_fn is not None
    sig = t0.group_signature(xs[t0.name], batched=batched)
    counts = [t.view.item_count(xs[t.name]) for t in group]
    n_items = sum(counts)
    arrays = _pack_group(group, xs, thetas, counts, solver_fn)[0]
    gspmd = batched and scheme.gspmd_safe

    def run(mu, items, packed, *ops):
        solve = _group_solve(scheme, solver_fn, mu)
        return _run_group_solve(solve, (items, packed) + ops, n_items,
                                mesh, rules, gspmd=gspmd,
                                n_chunks=n_chunks)

    key = ("exec",) + _cost.plan_key(sig, n_items, arrays, mesh,
                                     str(backend))

    def build():
        mu_sds = jax.ShapeDtypeStruct((), jnp.float32)
        arrays_sds = _abstract(arrays)
        return jax.jit(run).lower(mu_sds, *arrays_sds).compile()

    return _cost.get_executable(key, build), arrays


def solve_task(task: CompressionTask, x, theta, mu,
               backend: str | None = None):
    """One task's C solve, kernel-dispatched when the scheme opts in.

    The per-task twin of the grouped batched path: the same named
    solver runs on the task's own item stack (a single-array view is a
    1-item stack), so ``group_tasks=False`` and singleton groups also
    exercise the kernel path. Falls back to the plain (vmapped when
    stacked) ``scheme.compress``.
    """
    solver_fn, _ = _task_solver(task.scheme, backend)
    if solver_fn is None:
        return task.scheme_compress(x, theta, mu)
    items = task.view.to_items(x)
    ti = theta if task.view.stacked else add_leading_axis(theta)
    n_items = task.view.item_count(x)
    operands = task.scheme.batch_operands(n_items)
    if task.scheme.wants_key:
        operands = operands + (task.item_keys(n_items),)
    nt = task.scheme.compress_batched(solver_fn, items, ti, operands,
                                      mu=mu)
    return nt if task.view.stacked else drop_leading_axis(nt)


def grouped_compress(tasks: Sequence[CompressionTask], xs: dict,
                     thetas: dict, mu, mesh: Mesh | None = None,
                     rules: dict | None = None,
                     backend: str | None = None,
                     planner: str | None = None) -> dict:
    """One C step over all tasks with grouped dispatch.

    Returns ``{task_name: (new_theta, a_arr)}`` where ``a_arr`` is the
    decompressed Δ(Θ) in the task's compressible shape. Must be called
    under jit (it is trace-time machinery, not a runtime scheduler).
    With a ``mesh``, the packed item axis of every multi-task group is
    sharded per the ``"items"`` rule — see the module docstring; the
    numerics are unchanged. With a kernel ``backend``, opted-in schemes
    solve through the dispatch layer's named batched solvers.

    ``planner="on"`` routes every multi-task group through the roofline
    cost model (``repro.analysis.cost``): backend re-picked among the
    solver's registered implementations, Pallas tile rows tuned (TPU
    only), oversized groups chunked into several launches. Results are
    bit-identical to ``planner=None`` by construction — off-TPU the
    planner resolves exactly the static rule and chunked solves
    re-concatenate per-item-independent Θ exactly; plans are cached so
    repeated boundaries replan nothing.
    """
    out = {}
    for group in build_groups(tasks, xs, backend=backend):
        if len(group) == 1:
            # singleton: per-task path (also the non-groupable
            # fallback) — kernel-dispatched when the scheme opts in,
            # but never sharded (nothing to split across tasks).
            t = group[0]
            theta = solve_task(t, xs[t.name], thetas[t.name], mu,
                               backend=backend)
            out[t.name] = (theta, t.scheme_decompress(theta))
            continue

        # equal batched signature ⇒ same class and batch_key; operand-
        # ized hyperparameters (κ) may differ per member and ride in
        # packed per-item arrays, never through group[0]'s attributes
        scheme = group[0].scheme
        solver_fn, _ = _task_solver(scheme, backend)
        counts = [t.view.item_count(xs[t.name]) for t in group]
        n_items = sum(counts)
        n_chunks = 1
        if planner == "on":
            plan = _plan_multi_group(group, xs, thetas, counts,
                                     solver_fn, mesh, rules, backend)
            n_chunks = plan.n_chunks
            solver_fn = _apply_plan(scheme, solver_fn, plan)
        arrays, thetas_lead = _pack_group(group, xs, thetas, counts,
                                          solver_fn)

        new_packed, a_packed = _run_group_solve(
            _group_solve(scheme, solver_fn, mu), arrays, n_items, mesh,
            rules, gspmd=solver_fn is not None and scheme.gspmd_safe,
            n_chunks=n_chunks)

        theta_parts = unpack_thetas(new_packed, counts)
        if solver_fn is not None:
            # trailing-dim padding back off: every task's Θ lands in
            # its own LC-state shapes (live entries lead — see
            # pack_thetas_padded)
            theta_parts = [slice_theta_like(th, old) for th, old
                           in zip(theta_parts, thetas_lead)]
        off = 0
        for t, th, n in zip(group, theta_parts, counts):
            a_arr = t.view.from_items(a_packed[off:off + n])
            off += n
            if not t.view.stacked:
                th = drop_leading_axis(th)
            elif mesh is not None:
                # land the sliced stack where the L step consumes it:
                # the task's own item count decides its spec (exact
                # divisibility only — slices can't be padded)
                t_entry, _ = items_partition(n, mesh, rules,
                                             allow_pad=False)
                if t_entry is not None:
                    th = _constrain_leading(th, mesh, t_entry)
                    a_arr = _constrain_leading(a_arr, mesh, t_entry)
            out[t.name] = (th, a_arr)
    return out


def grouped_init(tasks: Sequence[CompressionTask], xs: dict,
                 mesh: Mesh | None = None,
                 rules: dict | None = None) -> dict:
    """Direct compression Θ^DC = Π(w̄) with grouped dispatch.

    The cold-start twin of :func:`grouped_compress`: tasks group by
    their (non-batched) signature extended with ``scheme.init_key()``
    — ``init`` has no warm start to feed a kernel solver, operand-ized
    hyperparameters like κ are still static here, and init-only
    settings (DP warm starts) must not merge — so each group runs ONE
    vmapped ``scheme.init``, and compile cost at startup is O(groups)
    instead of O(tasks). Returns
    ``{task_name: (theta, a_arr)}``; call under jit. With a ``mesh``
    the packed item axis shards exactly like the C step's.
    """
    out = {}
    for group in build_groups(tasks, xs, for_init=True):
        if len(group) == 1:
            t = group[0]
            theta = t.scheme_init(xs[t.name])
            out[t.name] = (theta, t.scheme_decompress(theta))
            continue

        scheme = group[0].scheme  # identical init_key ⇒ same static cfg
        items = jnp.concatenate(
            [t.view.to_items(xs[t.name]) for t in group], axis=0)
        counts = [t.view.item_count(xs[t.name]) for t in group]
        n_items = sum(counts)

        if scheme.wants_key:
            keys = _packed_keys(group, counts)

            def _solve(xi, ki, scheme=scheme):
                th = jax.vmap(lambda x, k: scheme.init(x, key=k))(xi, ki)
                return th, jax.vmap(scheme.decompress)(th)

            arrays = (items, keys)
        else:
            def _solve(xi, scheme=scheme):
                th = jax.vmap(lambda x: scheme.init(x))(xi)
                return th, jax.vmap(scheme.decompress)(th)

            arrays = (items,)

        theta_packed, a_packed = _run_group_solve(
            _solve, arrays, n_items, mesh, rules)

        theta_parts = unpack_thetas(theta_packed, counts)
        off = 0
        for t, th, n in zip(group, theta_parts, counts):
            a_arr = t.view.from_items(a_packed[off:off + n])
            off += n
            if not t.view.stacked:
                th = drop_leading_axis(th)
            elif mesh is not None:
                t_entry, _ = items_partition(n, mesh, rules,
                                             allow_pad=False)
                if t_entry is not None:
                    th = _constrain_leading(th, mesh, t_entry)
                    a_arr = _constrain_leading(a_arr, mesh, t_entry)
            out[t.name] = (th, a_arr)
    return out
