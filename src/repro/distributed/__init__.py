from repro.distributed.sharding import (
    DEFAULT_RULES, active_mesh, batch_axes, constrain, resolve_spec,
    tree_shardings, use_mesh)

__all__ = [
    "DEFAULT_RULES", "active_mesh", "batch_axes", "constrain",
    "resolve_spec", "tree_shardings", "use_mesh",
]
