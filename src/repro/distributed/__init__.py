from repro.distributed.sharding import (
    DEFAULT_RULES, active_mesh, batch_axes, constrain, items_partition,
    resolve_spec, stacked_sharding, tree_shardings, use_mesh)

__all__ = [
    "DEFAULT_RULES", "active_mesh", "batch_axes", "constrain",
    "items_partition", "resolve_spec", "stacked_sharding",
    "tree_shardings", "use_mesh",
]
