"""Gradient compression for cross-pod data parallelism.

The LC paper compresses *weights*; the same signal-compression machinery
applies to the **gradient exchange** — the only cross-pod (DCN) traffic
in our mesh. We implement error-feedback sign-SGD compression (1-bit
Adam / EF-signSGD family): each pod sends sign(g+e)·mean|g+e| (int8 +
one f32 scale per tensor ≈ 4× less DCN bytes than f32, 32× at 1-bit
packing), and the quantization residual feeds back into the next step,
which preserves convergence (Karimireddy et al., 2019).

``psum_compressed`` is the drop-in for ``jax.lax.psum`` over the pod
axis inside a shard_map'd train step; ``ef_*`` are the pure-math pieces
(unit-tested for the error-feedback contraction property).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ef_compress(g: jnp.ndarray, e: jnp.ndarray):
    """(compressed ĝ, new error) with error feedback: ĝ = Q(g+e)."""
    c = g.astype(jnp.float32) + e
    scale = jnp.mean(jnp.abs(c))
    sign = jnp.sign(c).astype(jnp.int8)
    ghat = sign.astype(jnp.float32) * scale
    return sign, scale, c - ghat


def ef_decompress(sign: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    return sign.astype(jnp.float32) * scale


def compress_tree(grads, ef):
    """Tree version: returns (signs, scales, new_ef)."""
    flat_g, tree = jax.tree_util.tree_flatten(grads)
    flat_e = jax.tree_util.tree_leaves(ef)
    signs, scales, errs = [], [], []
    for g, e in zip(flat_g, flat_e):
        s, sc, er = ef_compress(g, e)
        signs.append(s)
        scales.append(sc)
        errs.append(er)
    unf = tree.unflatten
    return unf(signs), unf(scales), unf(errs)


def psum_compressed(grads, ef, axis_name: str):
    """EF-sign-compressed psum over ``axis_name`` (the pod/DCN axis).

    Each participant contributes sign·scale; the mean of decompressed
    contributions approximates the mean gradient. Returns
    (averaged grads, new error-feedback buffers).
    """
    signs, scales, new_ef = compress_tree(grads, ef)
    n = jax.lax.psum(1, axis_name)

    def combine(s, sc):
        # communicate int8 signs (4× less than f32; 1-bit with packing)
        summed = jax.lax.psum(s.astype(jnp.bfloat16) * sc, axis_name)
        return summed / n

    avg = jax.tree_util.tree_map(combine, signs, scales)
    return avg, new_ef


def init_ef(params):
    return jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)


def compression_ratio(bits_per_elem: float = 8.0,
                      baseline_bits: float = 32.0) -> float:
    return baseline_bits / bits_per_elem
