"""Logical-axis sharding rules with divisibility fallback.

Every tensor dim carries a *logical name* ("batch", "heads_flat", ...).
A rule maps a name to an ordered list of mesh-axis candidates; the
resolver picks, per tensor, the first candidate that (a) exists in the
mesh, (b) divides the dim size, (c) doesn't reuse a mesh axis already
assigned to another dim of the same tensor. Names are resolved in a
global priority order (not dim order) so e.g. KV-head sharding wins the
"model" axis before sequence sharding falls back to it.

This is what makes every (arch × shape × mesh) dry-run cell compile:
n_heads=14 on a 16-way model axis falls back to sharding the fused
``heads*head_dim`` dim; global_batch=1 falls back to sequence sharding;
anything else falls back to replication instead of a GSPMD error.
"""
from __future__ import annotations

import contextlib
import threading

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.7: public API, kwarg `check_vma`
    _shard_map = jax.shard_map
    _SM_CHECK_KW = "check_vma"
except AttributeError:  # jax 0.4.x: experimental module, kwarg `check_rep`
    from jax.experimental.shard_map import shard_map as _shard_map
    _SM_CHECK_KW = "check_rep"


def shard_map(f, mesh, in_specs, out_specs, check_vma=False):
    """Version-tolerant ``shard_map`` (jax 0.4.x ↔ ≥0.7 signature drift)."""
    return _shard_map(f, mesh=mesh, in_specs=in_specs,
                      out_specs=out_specs, **{_SM_CHECK_KW: check_vma})

# candidates: tuples of mesh axis names (joint sharding) tried in order;
# () means replicate.
DEFAULT_RULES: dict[str, list[tuple[str, ...]]] = {
    # data-parallel axes
    "batch":       [("pod", "data"), ("data",), ()],
    # tensor-parallel output dims
    "heads_flat":  [("model",), ()],
    "kv_flat":     [("model",), ()],
    "kv_heads":    [("model",), ()],
    "heads":       [("model",), ()],
    "mlp":         [("model",), ()],
    "vocab":       [("model",), ()],
    "experts":     [("model",), ()],
    "inner":       [("model",), ()],      # SSM/xLSTM expanded channels
    # FSDP: parameters' reduction dims shard over the data axis
    "embed":       [("data",), ()],
    "embed_pod":   [("pod", "data"), ("data",), ()],  # opt-in ZeRO over pods
    # sequence axes
    "kv_seq":      [("model",), ("data",), ()],
    "seq":         [()],
    # packed leading item axis of a grouped C step (core/grouping.py):
    # the stacked items are embarrassingly parallel, so they data-shard
    "items":       [("data",), ()],
    # never sharded
    "layers":      [()],
    "state":       [()],
    "lora":        [()],
    "conv":        [()],
    "gates":       [()],
    "stack":       [()],
    None:          [()],
}

# Serving rules: weights are TP-sharded only ("embed" replicates).
# FSDP (sharding the reduction dim over "data") amortizes over the many
# uses per step in training; in decode it would re-gather every weight
# every token — measured 11.3 GB/step of pure all-gather on
# mixtral-8x7b decode_32k (EXPERIMENTS.md §Perf, cell 2 iteration 1).
SERVE_RULES = None  # initialized below


# greedy assignment priority (earlier names grab mesh axes first)
PRIORITY = [
    "experts", "items", "batch", "heads_flat", "kv_flat", "heads",
    "kv_heads", "mlp", "vocab", "inner", "embed", "embed_pod", "kv_seq",
    "seq",
]


SERVE_RULES = dict(DEFAULT_RULES)
SERVE_RULES["embed"] = [()]
SERVE_RULES["embed_pod"] = [()]


def resolve_spec(names: tuple, shape: tuple, mesh: Mesh,
                 rules: dict | None = None) -> P:
    """Logical dim names + concrete shape → PartitionSpec."""
    rules = rules or DEFAULT_RULES
    assert len(names) == len(shape), (names, shape)
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    order = sorted(
        range(len(names)),
        key=lambda i: PRIORITY.index(names[i]) if names[i] in PRIORITY
        else len(PRIORITY))
    used: set[str] = set()
    entries: list = [None] * len(names)
    for i in order:
        name = names[i]
        for cand in rules.get(name, [()]):
            if not cand:
                entries[i] = None
                break
            if not all(a in mesh_sizes for a in cand):
                continue
            if any(a in used for a in cand):
                continue
            prod = 1
            for a in cand:
                prod *= mesh_sizes[a]
            if shape[i] % prod != 0:
                continue
            entries[i] = cand if len(cand) > 1 else cand[0]
            used.update(cand)
            break
    return P(*entries)


# ----------------------------------------------------------------------
# Packed-item axis of the grouped C step (core/grouping.py). Unlike
# resolve_spec — which can only fall back to replication when a dim
# doesn't divide the mesh axis — an item stack may be *padded*: the items
# are independent (the scheme is vmapped over them), so extra zero items
# change nothing but the shard shapes.
# ----------------------------------------------------------------------
def items_partition(n_items: int, mesh: Mesh, rules: dict | None = None,
                    allow_pad: bool = True) -> tuple:
    """Resolve the ``"items"`` logical axis for a packed stack of
    ``n_items``.

    Returns ``(entry, pad)``: ``entry`` is the PartitionSpec entry for
    the leading axis (a mesh-axis name, a tuple of them, or ``None`` for
    replicate) and ``pad`` is how many zero items to append so the padded
    count divides the assigned mesh axes. With ``allow_pad=False`` only
    exact divisibility shards (used for per-task output specs, where the
    slice must keep the task's true item count).
    """
    rules = rules or DEFAULT_RULES
    mesh_sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    for cand in rules.get("items", [()]):
        if not cand:
            return None, 0
        if not all(a in mesh_sizes for a in cand):
            continue
        prod = 1
        for a in cand:
            prod *= mesh_sizes[a]
        pad = (-n_items) % prod
        if pad and not allow_pad:
            continue
        return (cand if len(cand) > 1 else cand[0]), pad
    return None, 0


def stacked_sharding(mesh: Mesh, entry, ndim: int) -> NamedSharding:
    """NamedSharding that splits only the leading (item) axis."""
    return NamedSharding(mesh, P(entry, *([None] * (ndim - 1))))


def match_shardings(tree, like):
    """Re-lay ``tree``'s leaves onto the shardings of ``like``'s leaves.

    Used by the overlapped trainer when it swaps fresh Δ(Θ)/λ refs into
    the train state mid-L-step: the compiled train step was traced
    against the *old* refs' layouts, so the replacements must land on
    identical shardings or every subsequent microbatch pays a resharding
    (or worse, a recompile). ``jax.device_put`` with a sharding is
    async — the swap itself never stalls the pipeline. Leaves whose
    sharding already matches (the common case: the C step's per-task
    output constraints) pass through untouched.
    """
    def put(x, y):
        want = getattr(y, "sharding", None)
        if want is None or getattr(x, "sharding", None) == want:
            return x
        return jax.device_put(x, want)

    return jax.tree_util.tree_map(put, tree, like)


# ----------------------------------------------------------------------
# Active-mesh context so model code can constrain activations without
# threading mesh/rules through every call.
# ----------------------------------------------------------------------
_CTX = threading.local()


@contextlib.contextmanager
def use_mesh(mesh: Mesh | None, rules: dict | None = None):
    prev = getattr(_CTX, "state", None)
    _CTX.state = (mesh, rules or DEFAULT_RULES) if mesh is not None else None
    try:
        yield
    finally:
        _CTX.state = prev


def active_mesh() -> Mesh | None:
    st = getattr(_CTX, "state", None)
    return st[0] if st else None


def constrain(x, names: tuple):
    """with_sharding_constraint against the active mesh (no-op if none)."""
    st = getattr(_CTX, "state", None)
    if st is None:
        return x
    mesh, rules = st
    spec = resolve_spec(names, x.shape, mesh, rules)
    return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, spec))


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Mesh axes that carry the batch (everything except 'model')."""
    return tuple(n for n in mesh.axis_names if n != "model")


def tree_shardings(axes_tree, shapes_tree, mesh: Mesh,
                   rules: dict | None = None):
    """Pytree of logical-name tuples + matching shapes → NamedShardings."""
    return jax.tree_util.tree_map(
        lambda names, shape: NamedSharding(
            mesh, resolve_spec(tuple(names), tuple(shape), mesh, rules)),
        axes_tree, shapes_tree,
        is_leaf=lambda x: isinstance(x, (tuple, list)) and
        all(isinstance(e, (str, type(None))) for e in x))
