"""Scenario matrix: short LC loops over every reduced architecture
config × scheme family, §7 monitors asserted per cell.

    PYTHONPATH=src python -m benchmarks.run --only matrix --artifact .

Each row is one cell run by ``benchmarks.matrix_common.run_cell`` — the
exact code path ``pytest -m matrix`` exercises. A monitor violation
(loss not decreasing, C step increasing its own objective, non-finite
λ, ratio ≤ 1) raises and fails the whole bench; deliberately
unsupported cells appear as ``status: "skipped"`` rows with a reason
string, never silently dropped. ``MATRIX_ARCHS`` / ``MATRIX_FAMILIES``
(comma-separated env vars) subset the enumeration for smoke CI.
"""
from __future__ import annotations

import logging
import sys

from benchmarks.matrix_common import (
    MonitorViolation, enumerate_cells, run_cell)


def run() -> list[dict]:
    logging.disable(logging.INFO)  # trainer per-step records are noisy
    cells = enumerate_cells()
    rows, failures = [], []
    for i, (arch, family) in enumerate(cells):
        print(f"# [{i + 1}/{len(cells)}] {arch}/{family}",
              file=sys.stderr, flush=True)
        try:
            rows.append(run_cell(arch, family))
        except MonitorViolation as e:
            failures.append(str(e))
            rows.append({
                "name": f"matrix/{arch}/{family}", "us_per_call": 0.0,
                "derived": "MONITOR-FAIL " + "; ".join(e.violations),
                "status": "failed", "arch": arch, "family": family,
                "violations": e.violations,
            })
    skipped = [r for r in rows if r["status"] == "skipped"]
    for r in skipped:
        print(f"# skipped {r['name']}: {r['reason']}", file=sys.stderr)
    if failures:
        # hard failure AFTER the full sweep so one broken cell doesn't
        # hide the status of the rest (the raise fails the bench run)
        raise MonitorViolation(
            f"{len(failures)}/{len(cells)} cells",
            [v for f in failures for v in f.splitlines()])
    return rows


if __name__ == "__main__":
    for r in run():
        print(f"{r['name']},{r['us_per_call']:.1f},{r['derived']}")
