"""Paper runtime claim: C steps are cheap relative to L steps. Measures
us/call for every C-step solver vs weight count (and the Pallas kernels
in interpret mode vs their jnp references for correctness-path parity).

Also measures the grouped C-step engine against per-task dispatch on a
mixed prune+quantize multi-layer config: grouped traces ONE vmapped
scheme program per (scheme, shape) group instead of one per task, so
both compile time and steady-state dispatch drop as the task count
grows (the paper's "C steps can be run in parallel", made concrete).

The kernel-vs-jnp column times the dispatch layer's named batched
solvers (``kmeans_lloyd``, ``topk_mask`` with per-item mixed κ) on one
packed group: the ``jnp`` backend against the Pallas items-grid kernels
(compiled on TPU; interpret mode elsewhere — slow but the same program,
with correctness parity asserted inline).

``--overlap`` adds the end-to-end LC-loop column: the full ``LCTrainer``
run, serial (``overlap="off"``) vs double-buffered pipeline
(``overlap="on"``), on a ≥8-task per-matrix workload — the trainer-level
payoff of the async L/C overlap. ``--json PATH`` writes every row to a
JSON file next to the CSV on stdout (CI writes ``BENCH_cstep.json``
via ``benchmarks.run --artifact`` so the perf trajectory records).

    PYTHONPATH=src python -m benchmarks.bench_cstep --overlap --json out.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp

from repro.core import (AsVector, CompressionTask, LCAlgorithm,
                        exponential_mu_schedule)
from repro.core.schemes import (
    AdaptiveQuantization, ConstraintL0Pruning, LowRank, Ternarize,
    optimal_codebook_dp)
from repro.kernels.kmeans import ops as kops
from repro.kernels.prune import ops as pops
from repro.launch.mesh import make_cstep_mesh


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


# ----------------------------------------------------------------------
# grouped vs per-task C-step dispatch
# ----------------------------------------------------------------------
def _grouped_vs_pertask(n_layers: int = 6, p_quant: int = 1 << 15,
                        p_prune: int = 1 << 14) -> list[dict]:
    """2·n_layers tasks (≥ 8): per-layer k-means quantization of the
    weight vectors + per-layer top-κ pruning — the mixed config a
    per-layer compression plan produces."""
    key = jax.random.PRNGKey(0)
    params = {
        f"l{i}": {
            "w": jax.random.normal(jax.random.fold_in(key, i), (p_quant,)),
            "p": jax.random.normal(jax.random.fold_in(key, 100 + i),
                                   (p_prune,)),
        } for i in range(n_layers)}

    def make(group_tasks, mesh=None):
        tasks = (
            [CompressionTask(f"q{i}", rf"l{i}/w$", AsVector(),
                             AdaptiveQuantization(k=16, iters=10))
             for i in range(n_layers)]
            + [CompressionTask(f"pr{i}", rf"l{i}/p$", AsVector(),
                               ConstraintL0Pruning(kappa=p_prune // 20))
               for i in range(n_layers)])
        # donate=False: the bench reuses `st` across repetitions, which
        # donated buffers would forbid on accelerators
        return LCAlgorithm(tasks, exponential_mu_schedule(1e-2, 1.2, 2),
                           group_tasks=group_tasks, donate=False,
                           mesh=mesh)

    schedule_len = 30        # μ steps in a paper-realistic LC run
    # sharded column: items axes split over every local device ("data");
    # on a 1-device host this degrades to an annotated (1,1)-mesh no-op
    # but still measures the constraint/padding overhead of the path.
    mesh = make_cstep_mesh()
    n_data = mesh.devices.shape[0]
    rows = []
    results = {}
    for label, group, m in (("grouped", True, None),
                            ("pertask", False, None),
                            (f"sharded-data{n_data}", True, mesh)):
        lc = make(group, m)
        st = lc.init(params)
        t0 = time.time()
        out = lc.c_step(params, st)
        jax.block_until_ready(out)
        first_call_ms = (time.time() - t0) * 1e3   # trace+compile+run
        us = _time(lambda: lc.c_step(params, st), reps=5)
        # one compile per LC run (μ is a traced scalar), then one C step
        # per μ — the cost an actual `LCAlgorithm.run` pays:
        lc_run_ms = first_call_ms + (schedule_len - 1) * us / 1e3
        results["sharded" if m is not None else label] = lc_run_ms
        n_groups = len(lc.group_summary(params)) if group \
            else len(lc.tasks)
        layout = "" if m is None else " " + "; ".join(
            f"spec={g['spec']} pad={g['padding']}"
            for g in lc.group_summary(params) if g["grouped"])
        rows.append({
            "name": f"cstep/dispatch-{label}/tasks={2 * n_layers}",
            "us_per_call": us,
            "derived": f"compile+first={first_call_ms:.0f}ms "
                       f"lc_run({schedule_len} mu)={lc_run_ms:.0f}ms "
                       f"traced_programs={n_groups}{layout}"})
    speedup = results["pertask"] / max(results["grouped"], 1e-9)
    rows.append({
        "name": f"cstep/dispatch-speedup/tasks={2 * n_layers}",
        "us_per_call": speedup,
        "derived": f"lc_run total x{speedup:.2f} "
                   f"(grouped wins: {speedup > 1.0})"})
    shard_x = results["grouped"] / max(results["sharded"], 1e-9)
    rows.append({
        "name": f"cstep/dispatch-sharded-vs-replicated/data={n_data}",
        "us_per_call": shard_x,
        "derived": f"lc_run grouped/sharded x{shard_x:.2f} "
                   f"(devices={n_data})"})
    return rows


# ----------------------------------------------------------------------
# kernel dispatch: batched Pallas solvers vs the batched jnp solvers
# ----------------------------------------------------------------------
def _kernel_vs_jnp(n_items: int = 8, p: int = 1 << 12) -> list[dict]:
    """The dispatch layer's backends on one packed group: ``jnp`` (the
    bit-identical vmap-equivalent) vs ``interpret`` (the Pallas
    items-grid kernel, emulated — on TPU the same rows measure the
    compiled kernel). Correctness parity is asserted inline so the
    trajectory never records a fast-but-wrong kernel."""
    import numpy as np

    from repro.kernels.dispatch import resolve_backend

    key = jax.random.PRNGKey(7)
    w = jax.random.normal(key, (n_items, p))
    cb0 = jnp.sort(jax.random.normal(jax.random.fold_in(key, 1),
                                     (n_items, 16)), axis=-1)
    kappa = jnp.arange(1, n_items + 1, dtype=jnp.int32) * (p // 20)
    kernel = resolve_backend("pallas")   # "pallas" on TPU else "interpret"

    rows = []
    res = {}
    for impl in ("jnp", kernel):
        us = _time(jax.jit(lambda w_, c_: kops.kmeans_batched(
            w_, c_, iters=10, impl=impl)), w, cb0)
        res[f"km-{impl}"] = us
        rows.append({
            "name": f"cstep/kernel-kmeans-{impl}/items={n_items}/P={p}",
            "us_per_call": us,
            "derived": "batched items-grid lloyd x10"})
        us = _time(jax.jit(lambda w_, k_: pops.topk_mask_batched(
            w_, k_, impl=impl)), w, kappa)
        res[f"tk-{impl}"] = us
        rows.append({
            "name": f"cstep/kernel-topk-{impl}/items={n_items}/P={p}",
            "us_per_call": us,
            "derived": "batched bisection; per-item (mixed) kappa"})
    # parity gate: masks identical, codebooks within documented atol
    mj = pops.topk_mask_batched(w, kappa, impl="jnp")
    mk = pops.topk_mask_batched(w, kappa, impl=kernel)
    np.testing.assert_array_equal(np.asarray(mj), np.asarray(mk))
    cj, _ = kops.kmeans_batched(w, cb0, iters=10, impl="jnp")
    ck, _ = kops.kmeans_batched(w, cb0, iters=10, impl=kernel)
    np.testing.assert_allclose(np.asarray(cj), np.asarray(ck), atol=1e-3)
    for op in ("km", "tk"):
        x = res[f"{op}-jnp"] / max(res[f"{op}-{kernel}"], 1e-9)
        rows.append({
            "name": f"cstep/kernel-vs-jnp-{op}/backend={kernel}",
            "us_per_call": x,
            "derived": f"jnp/{kernel} x{x:.3f} (parity asserted; "
                       f"interpret mode is the emulated-TPU CI path)"})
    return rows


# ----------------------------------------------------------------------
# end-to-end LC loop: serial vs overlapped trainer
# ----------------------------------------------------------------------
def _overlapped_vs_serial(n_mu: int = 6, steps_per_l: int = 8) -> list[dict]:
    """Full ``LCTrainer.run`` wall clock, serial vs double-buffered
    pipeline, on a per-matrix quantization plan (14 tasks ≥ 8). Each
    trainer runs twice and the second (jit-warm) run is timed, so the
    column compares the loops, not the compiler."""
    from repro.configs import get_config, reduced_config
    from repro.data import TokenStream
    from repro.launch.steps import init_train_state, lc_param_paths
    from repro.runtime import LCTrainer, TrainerConfig

    cfg = reduced_config(get_config("phi3-mini-3.8b")).with_(
        pattern_reps=2)
    key = jax.random.PRNGKey(0)

    def make(overlap):
        data = TokenStream(cfg.vocab_size, 2, 16)
        shapes = jax.eval_shape(
            lambda k: init_train_state(k, cfg)["params"], key)
        paths = [p for p in lc_param_paths(shapes)
                 if p.startswith("stages/")]
        tasks = [CompressionTask(f"q{i}", rf"^{p}$", AsVector(),
                                 AdaptiveQuantization(k=16, iters=10))
                 for i, p in enumerate(paths)]
        assert len(tasks) >= 8, len(tasks)
        lc = LCAlgorithm(tasks, exponential_mu_schedule(1e-2, 1.5, n_mu))
        return LCTrainer(cfg, lc, data, tcfg=TrainerConfig(
            steps_per_l=steps_per_l, overlap=overlap)), len(tasks)

    rows, wall = [], {}
    for mode in ("off", "on"):
        trainer, n_tasks = make(mode)
        trainer.run(key)              # compile warm-up
        t0 = time.time()
        trainer.run(key)
        wall[mode] = (time.time() - t0) * 1e3
        mean_c = sum(h["c_step_ms"] for h in
                     trainer.history[-n_mu:]) / n_mu
        rows.append({
            "name": f"cstep/lc-loop-overlap-{mode}/tasks={n_tasks}",
            "us_per_call": wall[mode] * 1e3,
            "derived": f"lc_run({n_mu} mu x {steps_per_l} microbatch)="
                       f"{wall[mode]:.0f}ms mean_c_step={mean_c:.1f}ms"})
    speedup = wall["off"] / max(wall["on"], 1e-9)
    rows.append({
        "name": "cstep/lc-loop-overlap-speedup",
        "us_per_call": speedup,
        "derived": f"serial/overlapped x{speedup:.3f} "
                   f"(overlapped wins: {speedup > 1.0})"})
    return rows


def run(overlap: bool = False) -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows = _grouped_vs_pertask() + _kernel_vs_jnp()
    if overlap:
        rows = _overlapped_vs_serial() + rows
    for p in (1 << 16, 1 << 20):
        w = jax.random.normal(key, (p,))
        q = AdaptiveQuantization(k=16, iters=10)
        th = q.init(w)
        us = _time(jax.jit(lambda w_: q.compress(w_, th)), w)
        rows.append({"name": f"cstep/kmeans16/P={p}", "us_per_call": us,
                     "derived": "compare-count Lloyd x10"})

        pr = ConstraintL0Pruning(kappa=p // 20)
        us = _time(jax.jit(lambda w_: pr.compress(w_, None)), w)
        rows.append({"name": f"cstep/prune-l0/P={p}", "us_per_call": us,
                     "derived": "top_k"})

        if p <= (1 << 16):  # interpret-mode python overhead at 1M+
            us = _time(lambda w_: pops.topk_mask(w_, p // 20,
                                                 use_pallas=True), w)
            rows.append({"name": f"cstep/prune-bisect/P={p}",
                         "us_per_call": us,
                         "derived": "pallas interpret (TPU path)"})

        t = Ternarize()
        us = _time(jax.jit(lambda w_: t.compress(w_, None)), w)
        rows.append({"name": f"cstep/ternary/P={p}", "us_per_call": us,
                     "derived": "sort+cumsum"})

    w2 = jax.random.normal(key, (1024, 512))
    lr = LowRank(target_rank=32, randomized=False)
    us = _time(jax.jit(lambda w_: lr.compress(w_, None)), w2)
    rows.append({"name": "cstep/svd-1024x512", "us_per_call": us,
                 "derived": "exact svd"})
    lrr = LowRank(target_rank=32, randomized=True)
    us = _time(jax.jit(lambda w_: lrr.compress(w_, None)), w2)
    rows.append({"name": "cstep/rsvd-1024x512", "us_per_call": us,
                 "derived": "randomized (Halko) — the sharded path"})

    w1 = jax.random.normal(key, (1 << 18,))
    us = _time(lambda w_: optimal_codebook_dp(w_, 8, bins=1024), w1)
    rows.append({"name": "cstep/dp-optimal-k8", "us_per_call": us,
                 "derived": "histogram DP (exact on bins)"})
    return rows


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--overlap", action="store_true",
                    help="add the end-to-end serial-vs-overlapped "
                         "LC-loop column (runs the full trainer)")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON")
    args = ap.parse_args()
    rows = run(overlap=args.overlap)
    print("name,us_per_call,derived")
    for r in rows:
        derived = str(r["derived"]).replace(",", ";")
        print(f"{r['name']},{r['us_per_call']:.1f},{derived}", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
