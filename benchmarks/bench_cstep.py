"""Paper runtime claim: C steps are cheap relative to L steps. Measures
us/call for every C-step solver vs weight count (and the Pallas kernels
in interpret mode vs their jnp references for correctness-path parity).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.core.schemes import (
    AdaptiveQuantization, ConstraintL0Pruning, LowRank, Ternarize,
    optimal_codebook_dp)
from repro.kernels.kmeans import ops as kops
from repro.kernels.prune import ops as pops


def _time(fn, *args, reps=3):
    fn(*args)  # compile
    t0 = time.time()
    for _ in range(reps):
        jax.block_until_ready(fn(*args))
    return (time.time() - t0) / reps * 1e6


def run() -> list[dict]:
    key = jax.random.PRNGKey(0)
    rows = []
    for p in (1 << 16, 1 << 20):
        w = jax.random.normal(key, (p,))
        q = AdaptiveQuantization(k=16, iters=10)
        th = q.init(w)
        us = _time(jax.jit(lambda w_: q.compress(w_, th)), w)
        rows.append({"name": f"cstep/kmeans16/P={p}", "us_per_call": us,
                     "derived": "searchsorted Lloyd x10"})

        pr = ConstraintL0Pruning(kappa=p // 20)
        us = _time(jax.jit(lambda w_: pr.compress(w_, None)), w)
        rows.append({"name": f"cstep/prune-l0/P={p}", "us_per_call": us,
                     "derived": "top_k"})

        if p <= (1 << 16):  # interpret-mode python overhead at 1M+
            us = _time(lambda w_: pops.topk_mask(w_, p // 20,
                                                 use_pallas=True), w)
            rows.append({"name": f"cstep/prune-bisect/P={p}",
                         "us_per_call": us,
                         "derived": "pallas interpret (TPU path)"})

        t = Ternarize()
        us = _time(jax.jit(lambda w_: t.compress(w_, None)), w)
        rows.append({"name": f"cstep/ternary/P={p}", "us_per_call": us,
                     "derived": "sort+cumsum"})

    w2 = jax.random.normal(key, (1024, 512))
    lr = LowRank(target_rank=32, randomized=False)
    us = _time(jax.jit(lambda w_: lr.compress(w_, None)), w2)
    rows.append({"name": "cstep/svd-1024x512", "us_per_call": us,
                 "derived": "exact svd"})
    lrr = LowRank(target_rank=32, randomized=True)
    us = _time(jax.jit(lambda w_: lrr.compress(w_, None)), w2)
    rows.append({"name": "cstep/rsvd-1024x512", "us_per_call": us,
                 "derived": "randomized (Halko) — the sharded path"})

    w1 = jax.random.normal(key, (1 << 18,))
    us = _time(lambda w_: optimal_codebook_dp(w_, 8, bins=1024), w1)
    rows.append({"name": "cstep/dp-optimal-k8", "us_per_call": us,
                 "derived": "histogram DP (exact on bins)"})
    return rows
