"""Shared harness: the paper's LeNet300 showcase, offline.

LeNet300 = 784→300→100→10 MLP. MNIST is unavailable in this container,
so the data is the teacher-classification task from data/pipeline.py
(learnable to ~0 train error, like MNIST for LeNet300) — reproduction
targets the paper's *relative* claims: LC ≥ direct compression at every
ratio, monotone tradeoff curves, mix-and-match tasks (DESIGN.md §8.4).
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import LCAlgorithm
from repro.data import gaussian_blobs

DIMS = (784, 300, 100, 10)


def init_mlp(key, dims=DIMS):
    p = {}
    ks = jax.random.split(key, len(dims))
    for i in range(len(dims) - 1):
        p[f"l{i}"] = {
            "w": jax.random.normal(ks[i], (dims[i], dims[i + 1]))
            / np.sqrt(dims[i]),
            "b": jnp.zeros((dims[i + 1],)),
        }
    return p


def mlp_apply(params, x):
    h = x
    n = len(params)
    for i in range(n):
        h = h @ params[f"l{i}"]["w"] + params[f"l{i}"]["b"]
        if i < n - 1:
            h = jnp.tanh(h)
    return h


def ce_loss(params, x, y):
    logits = mlp_apply(params, x)
    return -jnp.mean(jax.nn.log_softmax(logits)[jnp.arange(y.size), y])


def error_rate(params, x, y) -> float:
    pred = jnp.argmax(mlp_apply(params, x), axis=-1)
    return float(jnp.mean(pred != y))


@dataclass
class Problem:
    params: dict            # the trained reference model w̄
    x_train: jnp.ndarray
    y_train: jnp.ndarray
    x_test: jnp.ndarray
    y_test: jnp.ndarray
    ref_test_err: float
    ref_train_err: float


_CACHE: dict = {}


def reference_problem(n_train=4096, n_test=1024, steps=400,
                      lr=0.05, seed=0) -> Problem:
    """Train the reference (uncompressed) model once; memoized."""
    key = (n_train, n_test, steps, seed)
    if key in _CACHE:
        return _CACHE[key]
    # σ=5 ⇒ reference test error ≈ 1.9% — the LeNet300/MNIST regime
    # (paper: 2.13%), with visible direct-compression degradation
    x, y = gaussian_blobs(n_train + n_test, d=DIMS[0],
                          classes=DIMS[-1], sigma=5.0, seed=seed)
    xtr, ytr = x[:n_train], y[:n_train]
    xte, yte = x[n_train:], y[n_train:]
    params = init_mlp(jax.random.PRNGKey(seed + 1))

    opt_step = jax.jit(lambda p, x_, y_: jax.tree_util.tree_map(
        lambda a, g: a - lr * g, p, jax.grad(ce_loss)(p, x_, y_)))
    for i in range(steps):
        b = (i * 256) % (n_train - 256)
        params = opt_step(params, xtr[b:b + 256], ytr[b:b + 256])
    prob = Problem(params, xtr, ytr, xte, yte,
                   error_rate(params, xte, yte),
                   error_rate(params, xtr, ytr))
    _CACHE[key] = prob
    return prob


def sgd_l_step_factory(prob: Problem, iters=40, lr0=0.05, decay=0.98,
                       momentum=0.9, batch=256):
    """The paper's Listing-2 L step: SGD + Nesterov momentum, lr decayed
    per LC step, loss = CE + LC penalty."""
    def l_step(params, lc, k):
        lr = lr0 * (decay ** k)
        mu = lc["mu"]

        refs = [(lc["tasks"][t]["a"], lc["tasks"][t]["lam"])
                for t in lc["tasks"]]

        def total_loss(p, x, y):
            loss = ce_loss(p, x, y)
            for a, lam in refs:
                for path, a_leaf in a.items():
                    node = p
                    for part in path.split("/"):
                        node = node[part]
                    d = node - a_leaf - lam[path] / mu
                    loss = loss + 0.5 * mu * jnp.sum(d * d)
            return loss

        grad_fn = jax.jit(jax.grad(total_loss))
        mom = jax.tree_util.tree_map(jnp.zeros_like, params)
        n = prob.x_train.shape[0]
        for i in range(iters):
            b = (i * batch) % (n - batch)
            g = grad_fn(params, prob.x_train[b:b + batch],
                        prob.y_train[b:b + batch])
            mom = jax.tree_util.tree_map(
                lambda m, g_: momentum * m + g_, mom, g)
            upd = jax.tree_util.tree_map(
                lambda g_, m: g_ + momentum * m, g, mom)  # nesterov
            params = jax.tree_util.tree_map(
                lambda p_, u: p_ - lr * u, params, upd)
        return params
    return l_step


def run_lc(prob: Problem, tasks, mu0=9e-5, a=1.3, n_steps=20,
           iters_per_l=40, lr0=0.05) -> dict:
    """Full LC run (paper Fig. 2); returns errors + compression ratio."""
    lc = LCAlgorithm(tasks, [mu0 * a**k for k in range(n_steps)],
                     l_step=sgd_l_step_factory(prob, iters=iters_per_l,
                                               lr0=lr0))
    t0 = time.time()
    state, lc_state, hist = lc.run(
        jax.tree_util.tree_map(jnp.copy, prob.params),
        params_of=lambda s: s)
    wall = time.time() - t0
    compressed = lc.apply_compression(state)
    return {
        "test_err": error_rate(compressed, prob.x_test, prob.y_test),
        "train_err": error_rate(compressed, prob.x_train, prob.y_train),
        "ratio": hist[-1].compression_ratio,
        "wall_s": wall,
        "lc": lc, "state": state, "lc_state": lc_state,
        "compressed": compressed,
    }


def per_layer_tasks(scheme_factory) -> list:
    """Paper Table-2 "quantize all layers": one task (own Θ) per layer."""
    from repro.core import AsVector, CompressionTask
    return [CompressionTask(f"t{i}", rf"l{i}/w$", AsVector(),
                            scheme_factory())
            for i in range(len(DIMS) - 1)]


def direct_compress(prob: Problem, tasks) -> dict:
    """Θ^DC = Π(w̄) with no retraining — the paper's DC baseline."""
    lc = LCAlgorithm(tasks, [1e-4])
    lc_state = lc.init(prob.params)
    lc._last_lc = lc_state
    compressed = lc.apply_compression(prob.params)
    return {
        "test_err": error_rate(compressed, prob.x_test, prob.y_test),
        "train_err": error_rate(compressed, prob.x_train, prob.y_train),
        "ratio": lc.compression_ratio(prob.params, lc_state),
    }
