"""§Perf variant comparison from results/perf/*.json: paper-faithful
baseline vs beyond-paper variants for the three hillclimbed cells."""
from __future__ import annotations

import glob
import json
import os


def run() -> list[dict]:
    rows = []
    files = sorted(glob.glob("results/perf/*.json"))
    if not files:
        return [{"name": "perf/missing", "us_per_call": 0.0,
                 "derived": "run launch.dryrun --variant ... --out "
                            "results/perf first"}]
    for f in files:
        d = json.load(open(f))
        if d.get("status") != "ok":
            continue
        variant = d.get("variant") or "baseline"
        t = max(d["t_compute_s"], d["t_memory_s"], d["t_collective_s"])
        rows.append({
            "name": f"perf/{d['arch']}/{d['shape']}/{variant}",
            "us_per_call": t * 1e6,
            "derived": (f"bottleneck={d['bottleneck']} "
                        f"tm={d['t_memory_s']:.3e} "
                        f"tl={d['t_collective_s']:.3e}"),
        })
    return rows
