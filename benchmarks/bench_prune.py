"""Paper Fig. 3 (right): ℓ0-constraint LC pruning sweep vs direct
magnitude pruning at matched κ."""
from __future__ import annotations

import time

import jax

from repro.core import AsVector, CompressionTask
from repro.core.schemes import ConstraintL0Pruning

from benchmarks.common import (
    DIMS, direct_compress, reference_problem, run_lc)


def _total_weights():
    return sum(DIMS[i] * DIMS[i + 1] for i in range(len(DIMS) - 1))


def tasks_for(kappa):
    return [CompressionTask(
        "p", r"l\d/w$", AsVector(), ConstraintL0Pruning(kappa=kappa))]


def run() -> list[dict]:
    prob = reference_problem()
    p = _total_weights()
    rows = []
    for frac in (0.2, 0.05, 0.01):
        kappa = max(1, int(p * frac))
        dc = direct_compress(prob, tasks_for(kappa))
        t0 = time.time()
        lc = run_lc(prob, tasks_for(kappa), n_steps=20, iters_per_l=40,
                    mu0=9e-5, a=1.3)
        us = (time.time() - t0) * 1e6
        rows.append({
            "name": f"prune/keep={frac:.0%}",
            "us_per_call": us,
            "derived": (f"lc_err={lc['test_err']:.4f} "
                        f"dc_err={dc['test_err']:.4f} "
                        f"kappa={kappa} "
                        f"lc<=dc={lc['test_err'] <= dc['test_err'] + 0.02}"),
        })
    return rows
