"""Roofline bench: predicted-vs-measured per-group C-step time through
the group planner + AOT executable cache.

Self-contained (no dry-run artifacts needed): live multi-task groups
are built from the scenario matrix's own task derivation
(``matrix_common.build_tasks`` over reduced configs from
``enumerate_cells``), each group is planned through the roofline cost
model (``repro.analysis.cost``), AOT-compiled once via
``core.grouping.compile_group``, and executed over ``BOUNDARIES``
repeated μ boundaries. Per group the row reports the plan's modeled
time next to the measured wall time of the compiled executable.

Two HARD asserts (the PR's cache contract — CI runs this in the
planner-smoke job):

* re-entering ``compile_group`` at every boundary after the first must
  hit the executable cache — zero re-lowers/re-compiles;
* re-planning the same groups must hit the plan cache — zero re-plans.

``ROOFLINE_ARCHS`` / ``ROOFLINE_FAMILIES`` (comma-separated env vars)
shrink the sweep; the bench caps itself at ``MAX_GROUPS`` groups and
still asserts at least ``MIN_GROUPS`` were planned (the acceptance
floor), logging any cap in the row stream.
"""
from __future__ import annotations

import os
import time

MIN_GROUPS = 8
MAX_GROUPS = 12
BOUNDARIES = 3
REPEATS = 3

_DEFAULT_ARCHS = ("deepseek-moe-16b", "phi3-mini-3.8b")
_DEFAULT_FAMILIES = ("quantize", "prune", "lowrank", "rankselect")


def _env_list(name: str, default) -> list[str]:
    v = os.environ.get(name, "").strip()
    return [s for s in v.split(",") if s] if v else list(default)


def _collect_groups():
    """Yield (cell, group, xs, thetas, backend) for every multi-task
    group of the selected matrix cells, up to MAX_GROUPS."""
    import jax
    from benchmarks import matrix_common
    from repro.configs import get_config, reduced_config
    from repro.core.algorithm import LCAlgorithm
    from repro.core.grouping import build_groups
    from repro.models import init_params

    archs = _env_list("ROOFLINE_ARCHS", _DEFAULT_ARCHS)
    families = _env_list("ROOFLINE_FAMILIES", _DEFAULT_FAMILIES)
    cells = matrix_common.enumerate_cells(archs, families)
    out, capped = [], False
    for arch, family in cells:
        if len(out) >= MAX_GROUPS:
            capped = True
            break
        cfg = reduced_config(get_config(arch))
        tasks = matrix_common.build_tasks(cfg, family)
        if not tasks:
            continue
        params = init_params(jax.random.PRNGKey(0), cfg)
        algo = LCAlgorithm(tasks, [1e-3]).resolve(params)
        xs_all = {t.name: t.compressible(params) for t in algo.tasks}
        for group in build_groups(algo.tasks, xs_all, backend="auto"):
            if len(group) < 2:
                continue
            if len(out) >= MAX_GROUPS:
                capped = True
                break
            xs = {t.name: xs_all[t.name] for t in group}
            thetas = {t.name: t.scheme_init(xs[t.name]) for t in group}
            out.append((f"{arch}/{family}", group, xs, thetas))
    return out, capped


def _measure_ms(compiled, mu_values, arrays) -> float:
    """Median-of-min wall ms for one executable call across the μ
    boundaries (the same executable serves every μ — it is traced)."""
    import jax
    import jax.numpy as jnp

    best = []
    for mu in mu_values:
        times = []
        for _ in range(REPEATS):
            t0 = time.perf_counter()
            res = compiled(jnp.float32(mu), *arrays)
            jax.block_until_ready(res)
            times.append(time.perf_counter() - t0)
        best.append(min(times))
    best.sort()
    return best[len(best) // 2] * 1e3


def run() -> list[dict]:
    from repro.analysis import cost
    from repro.core.grouping import (
        _plan_multi_group, _task_solver, compile_group)

    cost.clear_caches()
    hw = cost.detect_hardware()
    groups, capped = _collect_groups()
    mu_values = [1e-3 * 2.0**k for k in range(BOUNDARIES)]

    rows = []
    for cell, group, xs, thetas in groups:
        t0 = group[0]
        counts = [t.view.item_count(xs[t.name]) for t in group]
        solver_fn, _ = _task_solver(t0.scheme, "auto")
        plan = _plan_multi_group(group, xs, thetas, counts, solver_fn,
                                 None, None, "auto")

        # boundary 1 compiles; boundaries 2.. must hit the exec cache
        stats0 = cost.cache_stats()
        compiled, arrays = compile_group(group, xs, thetas,
                                         backend="auto", plan=plan)
        after_first = cost.cache_stats()
        for _ in range(1, BOUNDARIES):
            compiled, arrays = compile_group(group, xs, thetas,
                                             backend="auto", plan=plan)
        stats1 = cost.cache_stats()
        relowers = stats1["exec_misses"] - after_first["exec_misses"]
        assert relowers == 0, (
            f"{cell}: {relowers} executable re-compile(s) across "
            f"{BOUNDARIES} boundaries — exec cache key unstable")
        assert stats1["exec_hits"] - stats0["exec_hits"] \
            >= BOUNDARIES - 1, f"{cell}: exec cache never hit"

        measured_ms = _measure_ms(compiled, mu_values, arrays)
        name = (f"roofline/{cell}/"
                f"{'x'.join(str(d) for d in plan_item_shape(group, xs))}"
                f"@{sum(counts)}")
        rows.append({
            "name": name,
            "us_per_call": measured_ms * 1e3,
            "derived": (f"pred={plan.modeled_ms:.4f}ms "
                        f"meas={measured_ms:.4f}ms "
                        f"bound={plan.bottleneck} "
                        f"backend={plan.backend} chunks={plan.n_chunks} "
                        f"src={plan.source}"),
            "predicted_ms": plan.modeled_ms,
            "measured_ms": measured_ms,
            "bottleneck": plan.bottleneck,
            "plan": plan.as_dict(),
            "tasks": [t.name for t in group],
            "n_items": sum(counts),
            "boundaries": BOUNDARIES,
        })

    # replan sweep: every group planned again must HIT the plan cache
    before = cost.cache_stats()
    for cell, group, xs, thetas in groups:
        counts = [t.view.item_count(xs[t.name]) for t in group]
        solver_fn, _ = _task_solver(group[0].scheme, "auto")
        _plan_multi_group(group, xs, thetas, counts, solver_fn,
                          None, None, "auto")
    after = cost.cache_stats()
    replans = after["plan_misses"] - before["plan_misses"]
    assert replans == 0, (
        f"{replans} re-plan(s) on identical groups — plan cache key "
        "unstable")

    assert len(rows) >= MIN_GROUPS, (
        f"only {len(rows)} multi-task groups planned "
        f"(need ≥{MIN_GROUPS}): widen ROOFLINE_ARCHS/FAMILIES")
    stats = cost.cache_stats()
    rows.append({
        "name": "roofline/cache",
        "us_per_call": 0.0,
        "derived": (f"groups={len(rows)} hw={hw.name} "
                    f"plan {stats['plan_hits']}h/"
                    f"{stats['plan_misses']}m exec "
                    f"{stats['exec_hits']}h/{stats['exec_misses']}m "
                    f"relowers=0 replans=0"
                    + (" CAPPED" if capped else "")),
        "cache_stats": stats,
        "hardware": hw.name,
        "capped": capped,
    })
    return rows


def plan_item_shape(group, xs):
    return group[0].view.item_shape(xs[group[0].name])
