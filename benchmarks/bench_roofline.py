"""Roofline summary from the dry-run artifacts (results/dryrun/*.json):
per (arch × shape × mesh): three terms, bottleneck, modeled step time.
``us_per_call`` = modeled step time (max of the three terms)."""
from __future__ import annotations

import glob
import json
import os


def run() -> list[dict]:
    rows = []
    files = sorted(glob.glob("results/dryrun/*.json"))
    if not files:
        return [{"name": "roofline/missing", "us_per_call": 0.0,
                 "derived": "run: python -m repro.launch.dryrun --all"}]
    for f in files:
        d = json.load(open(f))
        cell = f"{d['arch']}/{d['shape']}/{d['mesh']}"
        if d["status"] != "ok":
            rows.append({"name": f"roofline/{cell}", "us_per_call": 0.0,
                         "derived": d["status"]})
            continue
        t = max(d["t_compute_s"], d["t_memory_s"], d["t_collective_s"])
        rows.append({
            "name": f"roofline/{cell}",
            "us_per_call": t * 1e6,
            "derived": (f"bottleneck={d['bottleneck']} "
                        f"tc={d['t_compute_s']:.2e} "
                        f"tm={d['t_memory_s']:.2e} "
                        f"tl={d['t_collective_s']:.2e} "
                        f"rooffrac={d['roofline_fraction']:.4f}"),
        })
    return rows
