"""Paper Table 2: six mix-and-match compression tasks on the
LeNet300-analog — the flexibility showcase. Each row = one
compression_tasks structure, verbatim in spirit."""
from __future__ import annotations

import time

from repro.core import AsIs, AsVector, CompressionTask
from repro.core.schemes import (
    AdaptiveQuantization, AdditiveCombination, ConstraintL0Pruning,
    LowRank, RankSelection)

from benchmarks.common import DIMS, reference_problem, run_lc


def _p_total():
    return sum(DIMS[i] * DIMS[i + 1] for i in range(len(DIMS) - 1))


def showcase_rows():
    p = _p_total()
    from benchmarks.common import per_layer_tasks
    return [
        ("quantize-all-k2",
         per_layer_tasks(lambda: AdaptiveQuantization(k=2))),
        ("quantize-l1-l3", [CompressionTask(
            "q13", r"l[02]/w$", AsVector(), AdaptiveQuantization(k=2))]),
        ("prune-5pct", [CompressionTask(
            "p", r"l\d/w$", AsVector(),
            ConstraintL0Pruning(kappa=int(0.05 * p)))]),
        ("prune1pct+quant-additive", [CompressionTask(
            "pq", r"l\d/w$", AsVector(),
            AdditiveCombination([
                ConstraintL0Pruning(kappa=int(0.01 * p)),
                AdaptiveQuantization(k=2)], iters=2))]),
        ("prune-l1/lowrank-l2/quant-l3", [
            CompressionTask("p1", r"l0/w$", AsVector(),
                            ConstraintL0Pruning(kappa=5000)),
            CompressionTask("lr2", r"l1/w$", AsIs(), LowRank(10)),
            CompressionTask("q3", r"l2/w$", AsVector(),
                            AdaptiveQuantization(k=2))]),
        ("rank-selection-a1e-6", [CompressionTask(
            "rs", r"l\d/w$", AsIs(), RankSelection(alpha=1e-6))]),
    ]


def run() -> list[dict]:
    prob = reference_problem()
    rows = [{"name": "showcase/reference", "us_per_call": 0.0,
             "derived": (f"train_err={prob.ref_train_err:.4f} "
                         f"test_err={prob.ref_test_err:.4f}")}]
    for name, tasks in showcase_rows():
        t0 = time.time()
        lc = run_lc(prob, tasks, n_steps=20, iters_per_l=40,
                    a=1.4 if "lowrank" in name or "rank" in name else 1.3)
        us = (time.time() - t0) * 1e6
        rows.append({
            "name": f"showcase/{name}",
            "us_per_call": us,
            "derived": (f"train_err={lc['train_err']:.4f} "
                        f"test_err={lc['test_err']:.4f} "
                        f"ratio={lc['ratio']:.1f}x"),
        })
    return rows
