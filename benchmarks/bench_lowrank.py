"""Paper Fig. 4: automatic rank selection — sweeping λ(α) traces the
error-vs-FLOPs tradeoff curve (rank, params, FLOPs per α)."""
from __future__ import annotations

import time

import jax

from repro.core import AsIs, CompressionTask
from repro.core.schemes import RankSelection

from benchmarks.common import DIMS, reference_problem, run_lc


def tasks_for(alpha):
    return [CompressionTask(
        "rs", r"l\d/w$", AsIs(), RankSelection(alpha=alpha))]


def run() -> list[dict]:
    prob = reference_problem()
    rows = []
    prev_flops = None
    for alpha in (1e-7, 1e-5, 1e-3):
        t0 = time.time()
        lc = run_lc(prob, tasks_for(alpha), n_steps=16, iters_per_l=40,
                    mu0=9e-5, a=1.4, lr0=0.03)
        us = (time.time() - t0) * 1e6
        # selected ranks → FLOPs of the factored model
        ranks = []
        flops = 0.0
        for t in lc["lc"].tasks:
            th = lc["lc_state"]["tasks"][t.name]["theta"]
            r = int(th["rank"])
            ranks.append(r)
            m, n = th["u"].shape[0], th["v"].shape[0]
            flops += 2.0 * r * (m + n)
        dense_flops = sum(2.0 * DIMS[i] * DIMS[i + 1]
                          for i in range(len(DIMS) - 1))
        rows.append({
            "name": f"lowrank/alpha={alpha:g}",
            "us_per_call": us,
            "derived": (f"test_err={lc['test_err']:.4f} ranks={ranks} "
                        f"flops_frac={flops / dense_flops:.3f}"),
        })
        prev_flops = flops
    return rows
