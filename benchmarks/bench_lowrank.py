"""Low-rank C-step benchmarks.

Two claims are measured:

1. **Batched vs vmap (per-task) low-rank engine** — the tentpole of the
   matmul-only dispatch solvers (`kernels/lowrank`): ≥8 mixed-rank
   `LowRank` tasks solved as ONE packed `lowrank_rsvd` launch
   (`cstep_backend="jnp"`) against the legacy per-task exact-SVD path
   (`cstep_backend="off"`, one LAPACK program per rank group).
   Correctness parity is asserted inline: reconstruction distortion
   within 1e-4 relative of the exact-SVD (Eckart–Young) reference, and
   `RankSelection` choosing ranks identical to the exact-spectrum path
   on the same suite — the trajectory never records a fast-but-wrong
   solver.
2. **Paper Fig. 4** — automatic rank selection: sweeping λ(α) traces
   the error-vs-FLOPs tradeoff curve (rank, params, FLOPs per α).

``--json PATH`` writes the rows as JSON; CI runs this module through
``benchmarks.run --artifact`` which records ``BENCH_lowrank.json``
alongside ``BENCH_cstep.json``.

    PYTHONPATH=src python -m benchmarks.bench_lowrank --json out.json
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import AsIs, CompressionTask
from repro.core.schemes import LowRank, RankSelection

from benchmarks.common import DIMS, reference_problem, run_lc

# the bench suite: matrices with a controlled decaying spectrum — the
# regime the randomized range finder is built for (σ_i = BASE^i + FLOOR;
# the floor keeps every tail energy meaningfully nonzero so the relative
# parity check is honest, not 0/0)
M, N = 1024, 768
N_TASKS = 8
RANKS = tuple(4 * (i + 1) for i in range(N_TASKS))        # 4..32 mixed
ALPHAS = tuple(10.0 ** (-3 - 0.3 * i) for i in range(N_TASKS))
SPEC_BASE, SPEC_FLOOR = 0.93, 3e-2


def _suite_params():
    key = jax.random.PRNGKey(0)
    ks = jax.random.split(key, 2)
    k = min(M, N)
    u, _ = jnp.linalg.qr(jax.random.normal(ks[0], (N_TASKS, M, k)))
    v, _ = jnp.linalg.qr(jax.random.normal(ks[1], (N_TASKS, N, k)))
    sig = SPEC_BASE ** jnp.arange(k, dtype=jnp.float32) + SPEC_FLOOR
    w = jnp.einsum("imk,k,ink->imn", u, sig, v)
    return {f"l{i}": w[i] for i in range(N_TASKS)}


def _time_cstep(lc, params, st, reps=2):
    """(steady us/call, compile+first ms, last solved state) — the last
    rep's state doubles as the parity-check input, so no extra solve."""
    t0 = time.time()
    jax.block_until_ready(lc.c_step(params, st))
    first_ms = (time.time() - t0) * 1e3
    t0 = time.time()
    for _ in range(reps):
        out = lc.c_step(params, st)
        jax.block_until_ready(out)
    return (time.time() - t0) / reps * 1e6, first_ms, out


def _exact_tail(w, r):
    s = np.linalg.svd(np.asarray(w), compute_uv=False)
    return float((s[r:] ** 2).sum())


def _batched_vs_vmap(params) -> list[dict]:
    """Mixed-rank LowRank suite: one packed rsvd launch vs the per-task
    exact-SVD path, with inline distortion parity."""
    from repro.core import LCAlgorithm

    def tasks():
        return [CompressionTask(f"lr{i}", f"^l{i}$", AsIs(), LowRank(r))
                for i, r in enumerate(RANKS)]

    rows, res, states = [], {}, {}
    for label, backend in (("vmap", "off"), ("batched", "jnp")):
        lc = LCAlgorithm(tasks(), [1e-2], cstep_backend=backend,
                         donate=False)
        st = lc.init(params)
        us, first_ms, states[label] = _time_cstep(lc, params, st)
        res[label] = us
        n_groups = len(lc.group_summary(params))
        rows.append({
            "name": f"lowrank/cstep-{label}/tasks={N_TASKS}/{M}x{N}",
            "us_per_call": us,
            "derived": f"compile+first={first_ms:.0f}ms "
                       f"groups={n_groups} mixed ranks {RANKS[0]}.."
                       f"{RANKS[-1]}"})
    # parity gate: ‖W − ΔΘ‖² within 1e-4 relative of the exact-SVD
    # reference for every task (acceptance criterion)
    worst = 0.0
    for i, r in enumerate(RANKS):
        th = states["batched"]["tasks"][f"lr{i}"]["theta"]
        d = float(jnp.sum((params[f"l{i}"] - th["u"] @ th["v"].T) ** 2))
        d_ref = _exact_tail(params[f"l{i}"], r)
        rel = (d - d_ref) / d_ref
        worst = max(worst, rel)
        assert rel <= 1e-4, (i, r, d, d_ref)
    speedup = res["vmap"] / max(res["batched"], 1e-9)
    rows.append({
        "name": f"lowrank/batched-vs-vmap-speedup/tasks={N_TASKS}",
        "us_per_call": speedup,
        "derived": f"x{speedup:.2f} (>=3x wanted: {speedup >= 3.0}); "
                   f"worst rel distortion excess {worst:.2e} (<=1e-4 "
                   f"asserted)"})
    return rows


def _rank_select_parity(params) -> list[dict]:
    """Mixed-α RankSelection suite: one packed rank_select launch vs
    the per-task exact-spectrum path — selected ranks must be
    IDENTICAL (bit-identity of factors is not required: SVD
    sign/rotation ambiguity)."""
    from repro.core import LCAlgorithm

    def tasks():
        return [CompressionTask(f"rs{i}", f"^l{i}$", AsIs(),
                                RankSelection(alpha=a, max_rank=32))
                for i, a in enumerate(ALPHAS)]

    rows, res, states = [], {}, {}
    for label, backend in (("vmap", "off"), ("batched", "jnp")):
        lc = LCAlgorithm(tasks(), [1.0], cstep_backend=backend,
                         donate=False)
        st = lc.init(params)
        us, first_ms, states[label] = _time_cstep(lc, params, st)
        res[label] = us
        n_groups = len(lc.group_summary(params))
        rows.append({
            "name": f"lowrank/rank-select-{label}/tasks={N_TASKS}/"
                    f"{M}x{N}",
            "us_per_call": us,
            "derived": f"compile+first={first_ms:.0f}ms "
                       f"groups={n_groups} mixed alpha"})
    ranks_b, ranks_v = [], []
    for i in range(N_TASKS):
        ranks_b.append(int(states["batched"]["tasks"][f"rs{i}"]
                           ["theta"]["rank"]))
        ranks_v.append(int(states["vmap"]["tasks"][f"rs{i}"]
                           ["theta"]["rank"]))
    assert ranks_b == ranks_v, (ranks_b, ranks_v)   # acceptance gate
    speedup = res["vmap"] / max(res["batched"], 1e-9)
    rows.append({
        "name": f"lowrank/rank-select-speedup/tasks={N_TASKS}",
        "us_per_call": speedup,
        "derived": f"x{speedup:.2f}; selected ranks identical "
                   f"{ranks_b}"})
    return rows


def tasks_for(alpha):
    return [CompressionTask(
        "rs", r"l\d/w$", AsIs(), RankSelection(alpha=alpha))]


def _fig4_alpha_sweep() -> list[dict]:
    prob = reference_problem()
    rows = []
    for alpha in (1e-7, 1e-5, 1e-3):
        t0 = time.time()
        lc = run_lc(prob, tasks_for(alpha), n_steps=16, iters_per_l=40,
                    mu0=9e-5, a=1.4, lr0=0.03)
        us = (time.time() - t0) * 1e6
        # selected ranks → FLOPs of the factored model
        ranks = []
        flops = 0.0
        for t in lc["lc"].tasks:
            th = lc["lc_state"]["tasks"][t.name]["theta"]
            r = int(th["rank"])
            ranks.append(r)
            m, n = th["u"].shape[0], th["v"].shape[0]
            flops += 2.0 * r * (m + n)
        dense_flops = sum(2.0 * DIMS[i] * DIMS[i + 1]
                          for i in range(len(DIMS) - 1))
        rows.append({
            "name": f"lowrank/alpha={alpha:g}",
            "us_per_call": us,
            "derived": (f"test_err={lc['test_err']:.4f} ranks={ranks} "
                        f"flops_frac={flops / dense_flops:.3f}"),
        })
    return rows


def run() -> list[dict]:
    params = _suite_params()          # one set of QRs for both columns
    return (_batched_vs_vmap(params) + _rank_select_parity(params)
            + _fig4_alpha_sweep())


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="also write the rows as JSON")
    args = ap.parse_args()
    rows = run()
    print("name,us_per_call,derived")
    for r in rows:
        derived = str(r["derived"]).replace(",", ";")
        print(f"{r['name']},{r['us_per_call']:.1f},{derived}", flush=True)
    if args.json:
        with open(args.json, "w") as f:
            json.dump(rows, f, indent=2)


if __name__ == "__main__":
    main()
