"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only quantize,prune,...]

Prints ``name,us_per_call,derived`` CSV.
"""
from __future__ import annotations

import argparse
import sys
import time


MODULES = ["quantize", "prune", "lowrank", "showcase", "cstep", "serve",
           "roofline", "perf_variants"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    args = ap.parse_args()
    names = args.only.split(",") if args.only else MODULES

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}",
                         fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the suite going
            print(f"bench_{name},0,ERROR {type(e).__name__}: {e}")
            failures += 1
            continue
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.1f},{derived}",
                  flush=True)
        print(f"# bench_{name} done in {time.time()-t0:.1f}s",
              file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
