"""Benchmark driver — one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--only quantize,prune,...]
                                            [--artifact DIR]

Prints ``name,us_per_call,derived`` CSV. ``--artifact DIR`` additionally
writes one ``BENCH_<module>.json`` per module — the machine-readable
perf-trajectory record CI uploads (rows + host/backend metadata), so
regressions in e.g. the C-step dispatch columns are diffable across
commits.
"""
from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time


MODULES = ["quantize", "prune", "lowrank", "showcase", "cstep", "serve",
           "roofline", "perf_variants", "matrix"]


def _write_artifact(directory: str, name: str, rows: list,
                    elapsed_s: float) -> None:
    import jax

    payload = {
        "bench": name,
        "jax_backend": jax.default_backend(),
        "device_count": jax.device_count(),
        "platform": platform.platform(),
        "elapsed_s": round(elapsed_s, 3),
        "rows": rows,
    }
    path = os.path.join(directory, f"BENCH_{name}.json")
    with open(path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"# wrote {path}", file=sys.stderr)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated subset of: " + ",".join(MODULES))
    ap.add_argument("--artifact", default=None, metavar="DIR",
                    help="also write BENCH_<module>.json per module")
    args = ap.parse_args()
    names = args.only.split(",") if args.only else MODULES

    print("name,us_per_call,derived")
    failures = 0
    for name in names:
        mod = __import__(f"benchmarks.bench_{name}",
                         fromlist=["run"])
        t0 = time.time()
        try:
            rows = mod.run()
        except Exception as e:  # keep the suite going
            print(f"bench_{name},0,ERROR {type(e).__name__}: {e}")
            failures += 1
            continue
        for r in rows:
            derived = str(r["derived"]).replace(",", ";")
            print(f"{r['name']},{r['us_per_call']:.1f},{derived}",
                  flush=True)
        elapsed = time.time() - t0
        print(f"# bench_{name} done in {elapsed:.1f}s", file=sys.stderr)
        if args.artifact:
            _write_artifact(args.artifact, name, rows, elapsed)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
