"""Scenario-matrix cell runner shared by bench_matrix and pytest.

One *cell* = (reduced architecture config) × (scheme family). For each
cell we auto-derive a compression-task assignment from the model's param
tree, run a short LC loop through the production ``LCTrainer``, and
assert the paper's §7 monitors as HARD failures:

* L-step loss decrease — cross-entropy on a fixed eval batch must drop
  from init to the end of the LC loop;
* C-step ``shifted_distortion`` decrease — the trainer's per-boundary
  ``c_step_violations`` list must stay empty for every LC step;
* finite multipliers — every λ leaf finite at the end of the loop;
* ``compression_ratio`` > 1 — the Θ storage accounting must actually
  compress.

Violations raise :class:`MonitorViolation` (all of them listed, not just
the first), so a broken scheme/architecture combination fails loudly in
both ``benchmarks.run --only matrix`` and ``pytest -m matrix`` — the two
entry points run literally this module.

Task derivation rules (see docs/architecture.md "The scenario matrix"):

* norm vectors and 1-D items (biases, conv/dt offsets, SSM ``D``) are
  never compressed;
* a leaf inside a scanned stage carries a leading ``(reps,)`` stack axis
  (``plan_stages`` says which stages scan) — compressed per item via
  ``AsStacked``;
* MoE expert tensors ``(E, m, n)`` / ``(L, E, m, n)`` get per-expert
  views (``AsStacked(stack_ndim=...)``), one codebook/rank per expert;
* an item is *matrix-eligible* (low-rank / rank-selection) only when it
  is 2-D with both dims ≥ ``MATRIX_MIN_DIM`` — SSM conv kernels
  ``(d_conv, d)``, mLSTM gate stacks ``(d, 2)`` and other thin items are
  prune/quantize-only, and ≥3-D non-expert items (sLSTM recurrent
  blocks) flatten to vectors.
"""
from __future__ import annotations

import os
import re
import time
from dataclasses import dataclass

MATRIX_MIN_DIM = 8        # smallest dim for an item to count as a matrix
FAMILIES = ("prune", "quantize", "lowrank", "rankselect", "additive")

#: cells deliberately left unsupported: {(arch, family): reason}. Every
#: entry is surfaced as an explicit skip row in BENCH_matrix.json and a
#: pytest.skip — never silently dropped. (Currently empty: every
#: registered arch exposes ≥1 compressible leaf for every family.)
UNSUPPORTED: dict[tuple[str, str], str] = {}


class MonitorViolation(AssertionError):
    """One or more §7 monitors failed for a matrix cell."""

    def __init__(self, cell: str, violations: list[str]):
        self.cell = cell
        self.violations = list(violations)
        super().__init__(
            f"cell {cell}: §7 monitor violations:\n  - "
            + "\n  - ".join(violations))


# ----------------------------------------------------------------------
# Cell enumeration
# ----------------------------------------------------------------------
def enumerate_cells(archs=None, families=None) -> list[tuple[str, str]]:
    """All (arch, family) cells, honoring MATRIX_ARCHS / MATRIX_FAMILIES
    env subsets (comma-separated; used by the CI matrix-smoke job)."""
    from repro.configs import ARCHS

    def _env(name, default):
        v = os.environ.get(name, "").strip()
        return [s for s in v.split(",") if s] if v else list(default)

    archs = list(archs) if archs is not None else _env("MATRIX_ARCHS",
                                                       ARCHS)
    families = (list(families) if families is not None
                else _env("MATRIX_FAMILIES", FAMILIES))
    for a in archs:
        if a not in ARCHS:
            raise KeyError(f"unknown arch {a!r}; known: {ARCHS}")
    for f in families:
        if f not in FAMILIES:
            raise KeyError(f"unknown family {f!r}; known: {FAMILIES}")
    return [(a, f) for a in archs for f in families]


# ----------------------------------------------------------------------
# Leaf classification
# ----------------------------------------------------------------------
@dataclass(frozen=True)
class LeafInfo:
    path: str                 # slash-joined param path
    shape: tuple              # full leaf shape
    kind: str                 # "matrix" | "vector" | "skip"
    stack_ndim: int           # leading axes merged into the item stack
    item_shape: tuple         # shape of one compressed item
    reason: str = ""          # why kind == "skip"

    @property
    def item_size(self) -> int:
        n = 1
        for d in self.item_shape:
            n *= int(d)
        return n


def leaf_plan(cfg) -> list[LeafInfo]:
    """Classify every parameter leaf of ``cfg`` (shapes only, no init)."""
    import jax
    from repro.core.tasks import flatten_params
    from repro.models import init_params
    from repro.models.transformer import plan_stages

    shapes = flatten_params(jax.eval_shape(
        lambda: init_params(jax.random.PRNGKey(0), cfg)))
    scan_stages = {f"s{si}" for si, st in enumerate(plan_stages(cfg))
                   if st["kind"] == "scan"}

    infos = []
    for path, leaf in shapes.items():
        parts = path.split("/")
        scanned = (len(parts) >= 2 and parts[0] == "stages"
                   and parts[1] in scan_stages)
        # MoE expert weights keep a per-expert axis on top of the scan
        # axis: ffn/w_{gate,up,down} is (E, m, n) per layer
        expert = ("/ffn/" in path and parts[-1].startswith("w_")
                  and leaf.ndim - (1 if scanned else 0) == 3)
        stack_ndim = (1 if scanned else 0) + (1 if expert else 0)
        item_shape = tuple(leaf.shape[stack_ndim:])

        def info(kind, reason=""):
            return LeafInfo(path, tuple(leaf.shape), kind,
                            max(stack_ndim, 1) if stack_ndim else 0,
                            item_shape, reason)

        if "norm" in parts[-1]:
            infos.append(info("skip", "norm parameter"))
        elif len(item_shape) <= 1:
            infos.append(info("skip", "scalar/bias item"))
        elif (len(item_shape) == 2
                and min(item_shape) >= MATRIX_MIN_DIM):
            infos.append(info("matrix"))
        else:
            # thin 2-D items (conv kernels, gate stacks) and ≥3-D
            # non-expert items (recurrent blocks) — vector schemes only
            infos.append(info("vector"))
    return infos


# ----------------------------------------------------------------------
# Scheme-family → per-leaf task derivation
# ----------------------------------------------------------------------
def _vector_view(info: LeafInfo):
    from repro.core.views import AsStacked, AsVector
    if info.stack_ndim:
        return AsStacked("vector", stack_ndim=info.stack_ndim)
    return AsVector()


def _matrix_view(info: LeafInfo):
    from repro.core.views import AsIs, AsStacked
    if info.stack_ndim:
        return AsStacked("matrix", stack_ndim=info.stack_ndim)
    return AsIs()


def _scheme_and_view(info: LeafInfo, family: str):
    from repro.core.schemes import (
        AdaptiveQuantization, AdditiveCombination, ConstraintL0Pruning,
        LowRank, RankSelection)

    if family == "prune":
        return (ConstraintL0Pruning(max(1, info.item_size // 4)),
                _vector_view(info))
    if family == "quantize":
        return AdaptiveQuantization(k=4, iters=8), _vector_view(info)
    if family == "additive":
        # quantized base + sparse residual (paper Table 1 bottom)
        return (AdditiveCombination(
            [AdaptiveQuantization(k=2, iters=5),
             ConstraintL0Pruning(max(1, info.item_size // 8))],
            iters=2), _vector_view(info))
    m, n = info.item_shape
    if family == "lowrank":
        return LowRank(max(1, min(m, n) // 4)), _matrix_view(info)
    if family == "rankselect":
        # max_rank ≤ min(m,n)//4 bounds storage at ≤ half the dense
        # bits, so ratio > 1 holds for ANY selected rank
        return (RankSelection(alpha=1e-4, cost="storage",
                              max_rank=max(1, min(m, n) // 4)),
                _matrix_view(info))
    raise KeyError(f"unknown scheme family {family!r}")


def build_tasks(cfg, family: str):
    """One CompressionTask per eligible leaf of ``cfg`` for ``family``."""
    from repro.core.tasks import CompressionTask

    tasks = []
    for info in leaf_plan(cfg):
        if info.kind == "skip":
            continue
        if family in ("lowrank", "rankselect") and info.kind != "matrix":
            continue
        scheme, view = _scheme_and_view(info, family)
        tasks.append(CompressionTask(
            name=f"{info.path.replace('/', '.')}:{family}",
            pattern="^" + re.escape(info.path) + "$",
            view=view, scheme=scheme))
    return tasks


# ----------------------------------------------------------------------
# The cell runner
# ----------------------------------------------------------------------
def _make_data(cfg, batch: int, seq: int):
    from repro.data.pipeline import TokenStream, embedding_stream
    if cfg.input_mode == "tokens":
        return TokenStream(cfg.vocab_size, batch, seq)
    return embedding_stream(batch, seq, cfg.d_input, cfg.vocab_size)


def _eval_ce(params, batch, cfg) -> float:
    from repro.models import loss_fn
    _, metrics = loss_fn(params, batch, cfg)
    return float(metrics["ce"])


def run_lc_cell(cfg, tasks, *, cell: str = "cell", n_lc_steps: int = 2,
                steps_per_l: int = 3, lr: float = 3e-3,
                batch: int = 2, seq: int = 16, mu0: float = 1e-3,
                seed: int = 0, cstep_backend: str | None = None) -> dict:
    """Run a short LC loop with the given tasks and assert §7 monitors.

    The low-level entry point: ``tasks`` is injectable so the monitor
    plumbing itself is testable with a deliberately-broken scheme
    (tests/test_scenario_matrix.py). Returns the cell's metrics dict;
    raises :class:`MonitorViolation` listing every failed monitor.
    """
    import jax
    import numpy as np
    from repro.core.algorithm import LCAlgorithm, exponential_mu_schedule
    from repro.runtime.trainer import LCTrainer, TrainerConfig

    data = _make_data(cfg, batch, seq)
    batch_at = data.batch_at if hasattr(data, "batch_at") else data
    lc = LCAlgorithm(tasks, exponential_mu_schedule(mu0, 2.0, n_lc_steps))
    trainer = LCTrainer(cfg, lc, data, tcfg=TrainerConfig(
        steps_per_l=steps_per_l, lr=lr, cstep_backend=cstep_backend))

    key = jax.random.PRNGKey(seed)
    eval_batch = batch_at(0)
    # init_state(key) is deterministic in key, so this init is exactly
    # the one trainer.run(key) starts from — ce0 is the true pre-LC loss
    ce0 = _eval_ce(trainer.init_state(key)["params"], eval_batch, cfg)
    t0 = time.time()
    state, lc_state = trainer.run(key, n_lc_steps=n_lc_steps)
    wall_s = time.time() - t0
    ce1 = _eval_ce(state["params"], eval_batch, cfg)

    violations = []
    if not (np.isfinite(ce1) and ce1 < ce0):
        violations.append(
            f"l_step_loss: eval ce did not decrease ({ce0:.6g} → "
            f"{ce1:.6g})")
    for rec in trainer.history:
        if rec["c_step_violations"]:
            violations.append(
                f"c_step_shifted_distortion increased at LC step "
                f"{rec['lc_step']} for tasks {rec['c_step_violations']}")
        if not np.isfinite(rec["loss"]):
            violations.append(
                f"train loss not finite at LC step {rec['lc_step']}")
    for t in lc.tasks:
        for p, lam in lc_state["tasks"][t.name]["lam"].items():
            if not bool(np.all(np.isfinite(np.asarray(lam)))):
                violations.append(f"lambda_finite: non-finite λ for {p}")
    ratio = float(trainer.history[-1]["compression_ratio"]) \
        if trainer.history else float("nan")
    if not (np.isfinite(ratio) and ratio > 1.0):
        violations.append(
            f"compression_ratio not > 1 (got {ratio:.6g})")
    if violations:
        raise MonitorViolation(cell, violations)

    dist_total = float(sum(trainer.history[-1]["distortion"].values()))
    return {
        "name": cell,
        "us_per_call": wall_s * 1e6,
        "derived": (f"ce {ce0:.3f}->{ce1:.3f}; dist={dist_total:.4g}; "
                    f"ratio={ratio:.1f}x; tasks={len(lc.tasks)}"),
        "status": "ok",
        "wall_s": round(wall_s, 3),
        "ce_init": ce0,
        "ce_final": ce1,
        "distortion": dist_total,
        "compression_ratio": ratio,
        "n_tasks": len(lc.tasks),
        "lc_steps": n_lc_steps,
    }


def run_cell(arch: str, family: str, **kw) -> dict:
    """Run one (arch, family) matrix cell on the reduced smoke config."""
    from repro.configs import get_config, reduced_config

    cell = f"matrix/{arch}/{family}"
    reason = UNSUPPORTED.get((arch, family))
    if reason is not None:
        return {"name": cell, "us_per_call": 0.0,
                "derived": f"SKIP {reason}", "status": "skipped",
                "arch": arch, "family": family, "reason": reason}
    cfg = reduced_config(get_config(arch))
    # low-rank families demand the EXACT per-item SVD (dispatch off):
    # the batched randomized solver carries a documented ≤1e-4
    # relative-distortion budget, which legitimately exceeds the strict
    # §7 monotonicity tolerance once the LC loop converges — the §7
    # contract is stated for exact projections. Randomized-vs-exact
    # parity is covered by tests/test_lowrank_dispatch.py at its own
    # tolerance; here the monitors stay strict.
    if family in ("lowrank", "rankselect"):
        kw.setdefault("cstep_backend", "off")
    tasks = build_tasks(cfg, family)
    if not tasks:
        return {"name": cell, "us_per_call": 0.0,
                "derived": "SKIP no eligible leaves", "status": "skipped",
                "arch": arch, "family": family,
                "reason": f"no {family}-eligible leaves in param tree"}
    row = run_lc_cell(cfg, tasks, cell=cell, **kw)
    row["arch"] = arch
    row["family"] = family
    return row
