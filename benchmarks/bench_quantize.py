"""Paper Fig. 3 (left): LC quantization vs direct quantization across
codebook sizes — LC must dominate the DC (quantize-only) curve."""
from __future__ import annotations

import time

from repro.core.schemes import AdaptiveQuantization

from benchmarks.common import (
    direct_compress, per_layer_tasks, reference_problem, run_lc)


def tasks_for(k):
    return per_layer_tasks(lambda: AdaptiveQuantization(k=k, iters=20))


def run() -> list[dict]:
    prob = reference_problem()
    rows = [{"name": "quantize/reference", "us_per_call": 0.0,
             "derived": f"test_err={prob.ref_test_err:.4f}"}]
    for k in (2, 4, 16):
        dc = direct_compress(prob, tasks_for(k))
        t0 = time.time()
        lc = run_lc(prob, tasks_for(k), n_steps=20, iters_per_l=40)
        us = (time.time() - t0) * 1e6
        rows.append({
            "name": f"quantize/K={k}",
            "us_per_call": us,
            "derived": (f"lc_err={lc['test_err']:.4f} "
                        f"dc_err={dc['test_err']:.4f} "
                        f"ratio={lc['ratio']:.1f}x "
                        f"lc<=dc={lc['test_err'] <= dc['test_err'] + 0.02}"),
        })
    return rows
