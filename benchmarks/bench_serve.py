"""Compressed serving under synthetic heavy traffic.

Two sections:

* **kernel microbench** — dense GEMM vs codebook-dequant GEMM (jnp and
  the packed pallas kernel in interpret mode), all timed the same way.
* **traffic harness** — a tiny float32 transformer served by the
  continuous-batching :class:`ServingEngine` over a seeded Poisson
  arrival trace with mixed prompt/generation lengths, once per weight
  form: dense, 4-bit quantized, low-rank factored, pruned-sparse (each
  bridged from a real LC state via ``load_compressed_for_serving``).
  Rows report measured tokens/sec, p50/p99 request latency, modeled
  decode HBM bytes per token, and the HBM-roofline tokens/sec ceiling.

Hard asserts (the bench doubles as an integration check): every
compressed form greedy-decodes the *identical* token stream to its
dequantized/densified counterpart, and every engine run compiles each
of its three programs exactly once (zero retraces across the
mixed-length trace).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.analysis.roofline import HBM_BW
from repro.configs.base import LayerSpec, ModelConfig
from repro.core import AsIs, AsVector, CompressionTask, LCAlgorithm
from repro.core.schemes import (
    AdaptiveQuantization, ConstraintL0Pruning, LowRank)
from repro.kernels.quant_matmul import ops as qops
from repro.models.transformer import init_params
from repro.runtime import compressed as cforms
from repro.runtime.server import (
    Request, ServingEngine, densified_for_serving,
    load_compressed_for_serving)


def _time_us(fn, *args, iters: int = 10) -> float:
    jax.block_until_ready(fn(*args))          # warmup/compile
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.block_until_ready(out)
    return (time.perf_counter() - t0) / iters * 1e6


def _kernel_rows() -> list[dict]:
    kx, kw, kc = jax.random.split(jax.random.PRNGKey(0), 3)
    m, k, n, c = 8, 1024, 1024, 16
    x = jax.random.normal(kx, (m, k), jnp.float32)
    w = jax.random.normal(kw, (k, n), jnp.float32)
    cb = jnp.sort(jax.random.normal(kc, (c,)))
    idx = qops.pack_quantized(w, cb)
    packed = qops.pack4(idx)

    us_dense = _time_us(jax.jit(lambda a, b: a @ b), x, w)
    us_deq = _time_us(
        jax.jit(lambda a, i, cbk: a @ cbk[i.astype(jnp.int32)]),
        x, idx, cb)
    us_packed = _time_us(
        jax.jit(lambda a, p, cbk: qops.matmul_packed(a, p, cbk)),
        x, packed, cb)

    bytes_dense = k * n * 2              # bf16 weights
    bytes_quant = k * n * 1 + c * 4      # uint8 idx + codebook
    bytes_pack4 = k * n // 2 + c * 4     # two indices per byte
    return [
        {"name": "serve/dense-gemm-8x1024x1024", "us_per_call": us_dense,
         "derived": f"bf16 weight bytes={bytes_dense}"},
        {"name": "serve/dequant-gemm-jnp", "us_per_call": us_deq,
         "derived": (f"uint8+codebook bytes={bytes_quant} "
                     f"hbm_ratio={bytes_dense / bytes_quant:.2f}x")},
        {"name": "serve/dequant-gemm-pallas-interpret",
         "us_per_call": us_packed,
         "derived": (f"4-bit packed bytes={bytes_pack4} "
                     f"hbm_ratio={bytes_dense / bytes_pack4:.2f}x "
                     "(interpret mode on CPU; wall time is the "
                     "correctness path, the ratio is the TPU story)")},
    ]


# ----------------------------------------------------------------------
# Traffic harness
# ----------------------------------------------------------------------
def _serve_config() -> ModelConfig:
    # float32 end to end: compressed vs densified parity must be exact
    # token equality, which bf16 accumulation order would not guarantee
    return ModelConfig(
        name="bench-serve", d_model=64, n_heads=2, n_kv_heads=2,
        head_dim=16, d_ff=128, vocab_size=256,
        pattern=(LayerSpec("attn", "dense"),
                 LayerSpec("attn", "dense", window=8)),
        pattern_reps=1, attn_chunk_q=8, attn_chunk_kv=8,
        dtype="float32")


def _poisson_trace(rng, n_requests: int, rate_hz: float) -> list[Request]:
    t = 0.0
    reqs = []
    for i in range(n_requests):
        t += float(rng.exponential(1.0 / rate_hz))
        s = int(rng.integers(8, 48))
        reqs.append(Request(
            id=i, prompt=rng.integers(1, 255, size=s).astype(np.int32),
            max_new=int(rng.integers(4, 24)), arrival=t))
    return reqs


def _forms_under_test(params):
    """(form name, serving params, densified-counterpart params)."""
    out = [("dense-f32", params, None)]
    specs = {
        "quant4": CompressionTask(
            "q", r"ffn/w_", AsVector(), AdaptiveQuantization(k=16)),
        "lowrank": CompressionTask(
            "lr", r"ffn/w_", AsIs(), LowRank(8)),
        "sparse": CompressionTask(
            "pr", r"ffn/w_", AsVector(),
            ConstraintL0Pruning(kappa=6000)),
    }
    for form, task in specs.items():
        algo = LCAlgorithm([task], [1e-4])
        state = algo.init(params)
        serving, _ = load_compressed_for_serving(params, state,
                                                 algo.tasks)
        reference = densified_for_serving(params, state, algo.tasks)
        out.append((form, serving, reference))
    return out


def _run_trace(cfg, params, reqs, *, slots=4, max_len=96,
               prefill_chunk=8):
    eng = ServingEngine(cfg, params, slots=slots, max_len=max_len,
                        prefill_chunk=prefill_chunk)
    out = eng.run(list(reqs))
    assert not out["rejected"], [r.id for r in out["rejected"]]
    for prog, n in eng.trace_counts.items():
        assert n == 1, (
            f"{prog} traced {n}x across the mixed-length trace — "
            "continuous batching must never recompile after warmup")
    tokens = {f.id: f.tokens for f in out["finished"]}
    return tokens, out["stats"]


def _traffic_rows() -> list[dict]:
    cfg = _serve_config()
    params = init_params(jax.random.PRNGKey(0), cfg)
    reqs = _poisson_trace(np.random.default_rng(42), n_requests=10,
                          rate_hz=50.0)

    rows = []
    for form, serving, reference in _forms_under_test(params):
        tokens, stats = _run_trace(cfg, serving, reqs)
        if reference is not None:
            ref_tokens, _ = _run_trace(cfg, reference, reqs)
            for rid, toks in tokens.items():
                assert np.array_equal(toks, ref_tokens[rid]), (
                    f"{form}: request {rid} diverged from its "
                    "densified counterpart")
        hbm = cforms.tree_weight_bytes(serving)
        ceiling = HBM_BW / hbm
        rows.append({
            "name": f"serve/traffic-{form}",
            "us_per_call": 1e6 / max(stats["tokens_per_sec"], 1e-9),
            "derived": (
                f"tokens_per_sec={stats['tokens_per_sec']:.1f} "
                f"p50_latency_s={stats['p50_latency_s']:.4f} "
                f"p99_latency_s={stats['p99_latency_s']:.4f} "
                f"hbm_bytes_per_tok={hbm} "
                f"roofline_ceiling_tok_s={ceiling:.0f} "
                f"requests={stats['requests']} parity=ok retraces=0"),
        })
    return rows


def run() -> list[dict]:
    return _kernel_rows() + _traffic_rows()
