"""Compressed serving: codebook-dequant GEMM vs dense — wall time on CPU
(interpret mode, correctness path) + the modeled TPU HBM-traffic ratio
that drives the decode roofline (the deployable win of the paper)."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp

from repro.kernels.quant_matmul import ops as qops


def run() -> list[dict]:
    key = jax.random.PRNGKey(0)
    m, k, n, c = 8, 1024, 1024, 16
    x = jax.random.normal(key, (m, k), jnp.float32)
    w = jax.random.normal(key, (k, n), jnp.float32)
    cb = jnp.sort(jax.random.normal(key, (c,)))
    idx = qops.pack_quantized(w, cb)

    dense = jax.jit(lambda a, b: a @ b)
    jax.block_until_ready(dense(x, w))
    t0 = time.time()
    for _ in range(10):
        jax.block_until_ready(dense(x, w))
    us_dense = (time.time() - t0) / 10 * 1e6

    deq = jax.jit(lambda a, i, cbk: a @ cbk[i.astype(jnp.int32)])
    jax.block_until_ready(deq(x, idx, cb))
    t0 = time.time()
    for _ in range(10):
        jax.block_until_ready(deq(x, idx, cb))
    us_deq = (time.time() - t0) / 10 * 1e6

    # modeled HBM traffic for a decode-shape matmul (weights dominate)
    bytes_dense = k * n * 2              # bf16 weights
    bytes_quant = k * n * 1 + c * 4      # uint8 idx + codebook
    rows = [
        {"name": "serve/dense-gemm-8x1024x1024", "us_per_call": us_dense,
         "derived": f"bf16 weight bytes={bytes_dense}"},
        {"name": "serve/dequant-gemm-jnp", "us_per_call": us_deq,
         "derived": (f"uint8+codebook bytes={bytes_quant} "
                     f"hbm_ratio={bytes_dense / bytes_quant:.2f}x "
                     "(4-bit pack → 4x)")},
    ]
    y = qops.matmul(x, idx, cb, use_pallas=True)
    rows.append({"name": "serve/dequant-gemm-pallas-interpret",
                 "us_per_call": 0.0,
                 "derived": "validated vs ref in tests/test_kernels.py"})
    return rows
